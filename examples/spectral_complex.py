"""Complex-GEMM application: spectral filtering  Y = F^H diag(h) F X.

This is the class of HPC workload the paper targets (complex matrix products
dominating runtime).  The three complex products run on the Ozaki-II int8
emulation; on TPU v5e this is the *only* double-precision path (no f64
hardware), and per the paper's model it is also faster than native ZGEMM on
every GPU in Table I.

    PYTHONPATH=src python examples/spectral_complex.py
"""
import numpy as np
import jax.numpy as jnp

import repro
from repro.core import GemmPolicy, PreparedOperand, gemm_prepared
from repro.core.perfmodel import B200, TPU_V5E, complex_tflops, select_formulation


def dft_matrix(n: int) -> np.ndarray:
    i = np.arange(n)
    return np.exp(-2j * np.pi * np.outer(i, i) / n) / np.sqrt(n)


def main():
    n, batch = 192, 64
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((n, batch)) + 1j * rng.standard_normal((n, batch)))
    f = dft_matrix(n)
    h = np.exp(-0.5 * (np.arange(n) / n) ** 2)  # low-pass response

    # The plan builder can pick the Fig. 1 strategy from the SIII-C model
    # (same mode as the ozaki2_cgemm calls below, so the print matches what
    # formulation='auto' actually selects):
    form = select_formulation(n, batch, n, 14, mode="accu")
    print(f"perfmodel-selected formulation @ ({n},{n},{batch}): {form}")

    # scope the drop-in API once; every matmul below routes through it
    policy = GemmPolicy(backend="ozaki2_c128", n_moduli=14, mode="accu",
                        formulation="auto")

    def emul(a, b):
        with repro.use_policy(policy):
            return np.asarray(repro.linalg.matmul(jnp.asarray(a), jnp.asarray(b)))

    spec = emul(f, x)                       # F X
    filt = h[:, None] * spec                # diag(h) F X
    y = emul(f.conj().T, filt)              # F^H diag(h) F X

    ref = f.conj().T @ (h[:, None] * (f @ x))
    err = np.max(np.abs(y - ref)) / np.max(np.abs(ref))
    print(f"spectral filter (n={n}, batch={batch}) emulated-vs-native rel err: {err:.2e}")

    # F and F^H are fixed across batches: residue-cast them once and amortize
    # step 1 of the scheme over every application (fast mode).
    pf = PreparedOperand(jnp.asarray(f), 14, side="left")
    pfh = PreparedOperand(jnp.asarray(f.conj().T), 14, side="left")
    y2 = np.asarray(
        gemm_prepared(pfh, jnp.asarray(h[:, None] * np.asarray(
            gemm_prepared(pf, jnp.asarray(x)))))
    )
    err2 = np.max(np.abs(y2 - ref)) / np.max(np.abs(ref))
    print(f"  prepared-operand (amortized F, F^H) rel err: {err2:.2e}")

    flops = 2 * 8 * n * n * batch
    for hw in (TPU_V5E, B200):
        tf = complex_tflops(16384, 16384, 16384, 14, hw, "accu")
        print(f"  projected {hw.name} ZGEMM-emulation throughput @16k^3: {tf:.0f} TFLOPS")
    print(f"  (this demo ran {flops/1e6:.1f} MFLOP of complex work)")


if __name__ == "__main__":
    main()
