"""Quickstart: Ozaki-II emulated GEMM as a drop-in high-precision matmul.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import ozaki2_cgemm, ozaki2_gemm
from repro.core.perfmodel import TPU_V5E, complex_tflops


def main():
    rng = np.random.default_rng(0)
    m = k = n = 256

    # ---- real f64 GEMM emulated on int8 arithmetic -------------------------
    a = rng.standard_normal((m, k))
    b = rng.standard_normal((k, n))
    c = np.asarray(ozaki2_gemm(jnp.asarray(a), jnp.asarray(b)))  # default N=16
    ref = a.astype(np.longdouble) @ b.astype(np.longdouble)
    print("DGEMM emulation max rel err:",
          float(np.max(np.abs(c - ref) / np.abs(ref).max())))

    # ---- the paper's contribution: complex GEMM ---------------------------
    az = (a + 1j * rng.standard_normal((m, k))).astype(np.complex128)
    bz = (b + 1j * rng.standard_normal((k, n))).astype(np.complex128)
    cz = np.asarray(ozaki2_cgemm(jnp.asarray(az), jnp.asarray(bz)))  # N=14
    refz = az.astype(np.clongdouble) @ bz.astype(np.clongdouble)
    print("ZGEMM emulation max rel err:",
          float(np.max(np.abs(cz - refz) / np.abs(refz).max())))
    print("native ZGEMM    max rel err:",
          float(np.max(np.abs(az @ bz - refz) / np.abs(refz).max())))

    # fewer moduli = faster & less accurate; more = beyond-native accuracy
    for nm in (10, 13, 16):
        czn = np.asarray(ozaki2_cgemm(jnp.asarray(az), jnp.asarray(bz), nm))
        err = float(np.max(np.abs(czn - refz) / np.abs(refz).max()))
        tf = complex_tflops(16384, 16384, 16384, nm, TPU_V5E)
        print(f"  N={nm:2d}: err={err:.2e}   projected v5e ZGEMM @16k^3: {tf:6.1f} TFLOPS"
              f"  (v5e has NO native f64 at all)")


if __name__ == "__main__":
    main()
