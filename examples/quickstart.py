"""Quickstart: `repro.linalg` — a drop-in high-precision matmul, scoped by
`repro.use_policy` (the library analog of the paper's LD_PRELOAD deployment).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

import repro
from repro.core import GemmPolicy
from repro.core.perfmodel import TPU_V5E, complex_tflops


def main():
    rng = np.random.default_rng(0)
    m = k = n = 256

    # ---- real f64 GEMM emulated on int8 arithmetic -------------------------
    # One policy object answers every static question: what to emulate
    # (backend), how precisely (n_moduli/mode), and WHERE to run it
    # (execution: jnp reference | modulus-batched Pallas kernels).
    a = jnp.asarray(rng.standard_normal((m, k)))
    b = jnp.asarray(rng.standard_normal((k, n)))
    with repro.use_policy(GemmPolicy(backend="ozaki2_f64")):
        c = np.asarray(repro.linalg.matmul(a, b))  # default N=16
    ref = np.asarray(a, np.longdouble) @ np.asarray(b, np.longdouble)
    print("DGEMM emulation max rel err:",
          float(np.max(np.abs(c - ref) / np.abs(ref).max())))

    # ---- the paper's contribution: complex GEMM ---------------------------
    az = jnp.asarray(a + 1j * rng.standard_normal((m, k)), jnp.complex128)
    bz = jnp.asarray(b + 1j * rng.standard_normal((k, n)), jnp.complex128)
    cz = np.asarray(repro.linalg.zgemm(az, bz))  # BLAS-shaped wrapper, N=14
    refz = np.asarray(az, np.clongdouble) @ np.asarray(bz, np.clongdouble)
    print("ZGEMM emulation max rel err:",
          float(np.max(np.abs(cz - refz) / np.abs(refz).max())))
    print("native ZGEMM    max rel err:",
          float(np.max(np.abs(np.asarray(az @ bz) - refz) / np.abs(refz).max())))

    # ---- same policy, Pallas kernel execution -----------------------------
    # execution="kernel" runs the modulus-batched TPU pipeline (interpret
    # mode on this CPU container): 4 pallas_calls per GEMM at any N, and for
    # f32-grade dtypes bitwise-identical to the reference execution.
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    kpol = GemmPolicy(backend="ozaki2_f32", execution="kernel")
    with repro.use_policy(kpol):
        ck = np.asarray(repro.linalg.matmul(a32, b32))
    cr = np.asarray(
        repro.linalg.matmul(
            a32, b32, policy=GemmPolicy(backend="ozaki2_f32")
        )
    )
    print("kernel path bitwise == reference (f32):", bool((ck == cr).all()))

    # ---- same policy, FP8 (e4m3) engine -----------------------------------
    # execution="fp8" runs the residue products on the fp8 engine
    # (arXiv:2603.10634 variant): residues split into balanced base-16
    # digits — exact in e4m3 — so the pipeline stays bitwise identical to
    # the int8 kernels; what changes is the engine the MACs run on (and the
    # perfmodel pricing: 4 digit-GEMM volumes at the hardware's e4m3 rate).
    fpol = GemmPolicy(backend="ozaki2_f32", execution="fp8")
    with repro.use_policy(fpol):
        cf = np.asarray(repro.linalg.matmul(a32, b32))
    print("fp8 engine bitwise == int8 kernels:", bool((cf == ck).all()))

    # fewer moduli = faster & less accurate; more = beyond-native accuracy
    for nm in (10, 13, 16):
        with repro.use_policy(GemmPolicy(backend="ozaki2_c128", n_moduli=nm)):
            czn = np.asarray(repro.linalg.matmul(az, bz))
        err = float(np.max(np.abs(czn - refz) / np.abs(refz).max()))
        tf = complex_tflops(16384, 16384, 16384, nm, TPU_V5E)
        print(f"  N={nm:2d}: err={err:.2e}   projected v5e ZGEMM @16k^3: {tf:6.1f} TFLOPS"
              f"  (v5e has NO native f64 at all)")

    # ---- same policy, sharded over the mesh --------------------------------
    # execution="sharded" runs the kernel pipeline under shard_map: the N
    # residue planes shard over the mesh's 'residue' axis (falling back to
    # 'model'), m/n shard like a normal GEMM, and the single communication
    # is one psum of the reconstructed output in its exact partial form —
    # so the result is bitwise identical to execution="kernel" on EVERY
    # mesh shape.  Run with
    #   XLA_FLAGS=--xla_force_host_platform_device_count=8
    # to watch it span 8 host devices; on one device the mesh is trivial
    # but the full sharded machinery still runs (and still bit-matches).
    import jax

    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1, residue=len(jax.devices()))
    spol = GemmPolicy(backend="ozaki2_f32", execution="sharded")
    with repro.use_policy(spol, mesh=mesh):   # or GemmPolicy(mesh=mesh)
        cs = np.asarray(repro.linalg.matmul(a32, b32))
    print(f"sharded over {len(jax.devices())} device(s) bitwise == kernel:",
          bool((cs == ck).all()))


if __name__ == "__main__":
    main()
