"""Batched serving example: prefill + KV-cache decode with sampling.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen2.5-32b] \
        [--backend ozaki2_f32] [--execution kernel] \
        [--prepare] [--prepared-dir DIR]

(uses the reduced config of the chosen architecture on CPU)

With an emulated --backend, the whole model is routed onto the selected
GemmPolicy via a `repro.use_policy` scope around config construction — the
context-scoped drop-in deployment path.  --prepare residue-casts the weights
once at engine construction; --prepared-dir persists those planes so a
restarted server restores them instead of re-preparing.
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

import contextlib
import dataclasses

import repro
from repro.configs import ARCHS, get_reduced
from repro.core import GemmPolicy
from repro.models import Model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2.5-32b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--backend", default="native",
                    choices=["native", "ozaki2_f32", "ozaki2_f64",
                             "ozaki2_c64", "ozaki2_c128"])
    ap.add_argument("--execution", default="reference",
                    choices=["reference", "kernel", "per_modulus_kernel"])
    ap.add_argument("--prepare", action="store_true",
                    help="residue-cast the weights once at construction")
    ap.add_argument("--prepared-dir", default=None,
                    help="persist/restore the prepared residue planes here")
    args = ap.parse_args()

    scope = contextlib.nullcontext()
    if args.backend != "native":
        scope = repro.use_policy(
            GemmPolicy(backend=args.backend, execution=args.execution)
        )
    with scope:
        # the config pins the ambient policy at construction, so every
        # matmul in the model runs on the selected backend/execution
        cfg = get_reduced(args.arch)
    if args.backend != "native":
        cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    npre = cfg.n_prefix_embeds if cfg.frontend else 0
    cache_len = args.prompt_len + npre + args.new_tokens
    eng = ServeEngine(model, params, cache_len=cache_len, batch_size=args.batch,
                      prepare=args.prepare, prepared_dir=args.prepared_dir)

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
        )
    }
    if cfg.frontend:
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, npre, cfg.d_model)) * 0.02, jnp.float32
        )
    t0 = time.perf_counter()
    toks = eng.generate(batch, args.new_tokens, args.temperature, jax.random.PRNGKey(1))
    dt = time.perf_counter() - t0
    total = args.batch * args.new_tokens
    print(f"{args.arch}: generated {toks.shape} in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(toks[0, :16]))


if __name__ == "__main__":
    main()
