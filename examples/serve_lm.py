"""Batched serving example: prefill + KV-cache decode with sampling.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen2.5-32b]
(uses the reduced config of the chosen architecture on CPU)
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import ARCHS, get_reduced
from repro.models import Model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2.5-32b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    npre = cfg.n_prefix_embeds if cfg.frontend else 0
    cache_len = args.prompt_len + npre + args.new_tokens
    eng = ServeEngine(model, params, cache_len=cache_len, batch_size=args.batch)

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
        )
    }
    if cfg.frontend:
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, npre, cfg.d_model)) * 0.02, jnp.float32
        )
    t0 = time.perf_counter()
    toks = eng.generate(batch, args.new_tokens, args.temperature, jax.random.PRNGKey(1))
    dt = time.perf_counter() - t0
    total = args.batch * args.new_tokens
    print(f"{args.arch}: generated {toks.shape} in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(toks[0, :16]))


if __name__ == "__main__":
    main()
