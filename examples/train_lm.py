"""End-to-end training driver (deliverable b).

Default preset trains a small decoder LM for a few hundred steps on CPU
with checkpointing + auto-resume; `--preset 100m` is the full ~100M-param
configuration for real hardware; `--emulated` routes every matmul through
the paper's Ozaki-II int8 emulation backend.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--preset small]
"""
import argparse
import dataclasses

import repro  # noqa: F401
from repro.core.policy import GemmPolicy
from repro.data import DataConfig
from repro.models import Model, ModelConfig
from repro.optim import AdamWConfig
from repro.train import TrainLoopConfig, train_loop

PRESETS = {
    # ~2.5M params: a few hundred steps run in minutes on this CPU container
    "small": ModelConfig(
        name="train-small", n_layers=4, d_model=128, vocab=2048,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=512, mlp="swiglu",
    ),
    # ~100M params (the deliverable-scale config; sized for real hardware)
    "100m": ModelConfig(
        name="train-100m", n_layers=12, d_model=768, vocab=32768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, mlp="swiglu",
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="small")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--emulated", action="store_true",
                    help="run every matmul on the Ozaki-II int8 backend")
    ap.add_argument("--execution", default="reference",
                    choices=["reference", "kernel", "per_modulus_kernel"],
                    help="residue backend for --emulated (GemmPolicy axis)")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    if args.emulated:
        cfg = dataclasses.replace(
            cfg,
            gemm_policy=GemmPolicy(backend="ozaki2_f32", n_moduli=8,
                                   execution=args.execution),
            dtype="float32",
        )
    model = Model(cfg)
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    loop = TrainLoopConfig(
        steps=args.steps, warmup=max(10, args.steps // 20), log_every=20,
        ckpt_every=100, ckpt_dir=args.ckpt_dir,
    )
    params, hist = train_loop(
        model, data, loop, AdamWConfig(lr=args.lr, grad_clip=5.0)
    )
    print(f"done: loss {hist[0]:.3f} -> {hist[-1]:.3f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
