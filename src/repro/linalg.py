"""`repro.linalg` — drop-in matmul routed by the ambient :class:`GemmPolicy`.

This is the library analog of the paper's deployment story: the reference
implementation LD_PRELOAD-interposes cuBLAS so unmodified applications run
the CGEMM/ZGEMM emulation.  Here the interposition point is one function —

    >>> import jax.numpy as jnp
    >>> import repro
    >>> from repro.core import GemmPolicy
    >>> a = jnp.eye(2, dtype=jnp.complex64)
    >>> b = jnp.ones((2, 2), jnp.complex64)
    >>> with repro.use_policy(GemmPolicy(backend="ozaki2_c64", n_moduli=5,
    ...                                  execution="kernel")):
    ...     y = repro.linalg.matmul(a, b)          # batched Pallas path
    >>> (y.dtype.name, bool(jnp.all(y == b)))
    ('complex64', True)

— and everything above it (`repro.models` layers, the serve engine, the
training step) calls `linalg.matmul`, so one `use_policy` scope (or one
`gemm_policy` config field) moves a whole model between the native path,
the jnp reference emulation, the modulus-batched Pallas kernels, the
sharded pipeline and the fp8 engine.

Policy scoping and jit
----------------------

`use_policy` pushes onto a thread-local stack; `current_policy()` reads the
top (default: the native policy).  Policies are frozen/hashable, and
`matmul` captures the ambient policy *at trace time* — inside `jax.jit` the
captured policy is baked into the compiled computation like any other
static.  Enter `use_policy` before tracing (or pass `policy=` explicitly /
pin it in a `ModelConfig`, which resolves the ambient policy once at config
construction); re-entering a different policy after a function was traced
does not retrace it.  `matmul_jit` is provided for eager callers: it jits
per (shapes, policy) with the policy as an explicit static argument.

BLAS-shaped wrappers
--------------------

`sgemm`/`dgemm`/`cgemm`/`zgemm` coerce the operands to the routine's
compute dtype and force the matching ``ozaki2_*`` backend while inheriting
every other knob (mode, execution, formulation, n_block, ...) from the
ambient or given policy — `cgemm(a, b)` is always the emulated complex64
product, whatever the ambient backend field says.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading

import jax
import jax.numpy as jnp

from .core.executor import PreparedOperand
from .core.policy import (
    BACKEND_FOR_DTYPE,
    GemmPolicy,
    NATIVE,
    current_mesh,
    emulated_matmul,
    policy_matmul,
    prepare_weights,
    use_mesh,
)

__all__ = [
    "GemmPolicy",
    "PreparedOperand",
    "cgemm",
    "current_mesh",
    "current_policy",
    "dgemm",
    "matmul",
    "matmul_jit",
    "prepare_weights",
    "sgemm",
    "use_mesh",
    "use_policy",
    "zgemm",
]

_STATE = threading.local()


def current_policy() -> GemmPolicy:
    """The innermost active `use_policy` policy (default: native)."""
    stack = getattr(_STATE, "stack", None)
    return stack[-1] if stack else NATIVE


@contextlib.contextmanager
def use_policy(policy: GemmPolicy, *, mesh=None, calibration=None):
    """Scope every `linalg.matmul` (and model/serve/train matmul resolved at
    config construction) in this thread to `policy`.

    Accepts a backend name as shorthand: ``use_policy("ozaki2_c64")``.
    Nestable; the innermost scope wins.  The policy must be hashable (it is
    captured as a jit static).  `mesh` additionally scopes the thread-local
    default mesh (`use_mesh`) a ``GemmPolicy(execution="sharded",
    mesh=None)`` resolves at trace time — one context manager distributes
    every matmul in a model over the mesh.  `calibration` (a
    `repro.tune.Calibration` or cache-file path) additionally scopes the
    thread-local calibration (`repro.use_calibration`), so the 'auto' plan
    selections price against the measured hardware and the kernels launch
    the autotuned block shapes while tracing inside the scope.

    Example — the ambient scope routes matmuls, nesting overrides it::

        >>> import jax.numpy as jnp
        >>> import repro
        >>> from repro.core import GemmPolicy
        >>> repro.current_policy().backend
        'native'
        >>> with repro.use_policy("ozaki2_f64"):         # name shorthand
        ...     outer = repro.current_policy().backend
        ...     with repro.use_policy(GemmPolicy(backend="ozaki2_f32",
        ...                                      execution="fp8")):
        ...         inner = repro.current_policy().execution
        >>> (outer, inner, repro.current_policy().backend)
        ('ozaki2_f64', 'fp8', 'native')
    """
    if isinstance(policy, str):
        policy = GemmPolicy(backend=policy)
    if not isinstance(policy, GemmPolicy):
        raise TypeError(
            f"use_policy expects a GemmPolicy (or backend name); got "
            f"{type(policy).__name__}"
        )
    hash(policy)  # fail fast: the policy rides in jit-static slots
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    stack.append(policy)
    try:
        with contextlib.ExitStack() as scopes:
            if mesh is not None:
                scopes.enter_context(use_mesh(mesh))
            if calibration is not None:
                from .tune.cache import use_calibration

                scopes.enter_context(use_calibration(calibration))
            yield policy
    finally:
        stack.pop()


@contextlib.contextmanager
def _no_ambient_policy():
    """Temporarily clear the ambient stack.

    Import-time construction of registry configs must be scope-independent
    (a module first imported inside a `use_policy` scope would otherwise pin
    that scope's policy into its module-level CONFIG forever); the configs
    registry re-pins the ambient policy at lookup instead.
    """
    stack = getattr(_STATE, "stack", None)
    _STATE.stack = []
    try:
        yield
    finally:
        _STATE.stack = stack if stack is not None else []


def matmul(x, w, *, policy: GemmPolicy | None = None, rtol: float | None = None):
    """Drop-in `jnp.matmul(x, w)` under `policy` (default: the ambient
    `use_policy` scope; native when none is active).

    x: (..., m, k); w: (k, n), a batched (..., k, n) array, or a right-side
    `PreparedOperand` (residues cast once — the serving fast path).
    Differentiable through the emulated custom VJP; jit-compatible (the
    policy is trace-time static).

    `rtol` is shorthand for ``dataclasses.replace(policy, rtol=rtol)``: the
    accuracy-adaptive axis (arXiv:2602.02549).  The moduli count — and with
    ``mode="auto"`` the scaling mode — is then resolved per call as the
    cheapest plan whose componentwise error bound provably meets the
    tolerance (see `repro.core.accuracy`).

    Example — an f64-grade product emulated on int8 arithmetic::

        >>> import jax.numpy as jnp
        >>> import repro
        >>> from repro.core import GemmPolicy
        >>> a = jnp.eye(3, dtype=jnp.float64) * 4.0
        >>> b = jnp.full((3, 2), 2.5)
        >>> y = repro.linalg.matmul(
        ...     a, b, policy=GemmPolicy(backend="ozaki2_f64", n_moduli=6))
        >>> bool(jnp.all(y == 10.0))       # exact: power-of-two operands
        True

    Example — ask for a tolerance instead of a moduli count; a looser
    target provably needs fewer moduli (fewer int8 GEMMs)::

        >>> pol = GemmPolicy(backend="ozaki2_f64")
        >>> y6 = repro.linalg.matmul(a, b, policy=pol, rtol=1e-6)
        >>> y14 = repro.linalg.matmul(a, b, policy=pol, rtol=1e-14)
        >>> bool(jnp.allclose(y6, y14))
        True
    """
    policy = current_policy() if policy is None else policy
    if rtol is not None:
        policy = dataclasses.replace(policy, rtol=rtol)
    if isinstance(w, PreparedOperand):
        return policy_matmul(x, w, policy)
    if getattr(x, "ndim", 0) < 2 or getattr(w, "ndim", 0) < 2:
        raise ValueError(
            "linalg.matmul expects matrix operands (ndim >= 2); got shapes "
            f"{getattr(x, 'shape', None)} @ {getattr(w, 'shape', None)}"
        )
    if w.ndim == 2:
        return policy_matmul(x, w, policy)
    # batched weight: the executor's run_plan vectorizes over leading dims
    if policy.backend == "native":
        y = jnp.matmul(x, w)
        return y if policy.out_dtype is None else y.astype(policy.out_dtype)
    if policy.is_adaptive:
        # resolve statically (one plan for every batch element); the 2D
        # fast path above additionally probes the concrete operands
        policy = policy.resolve_adaptive(x.shape[-2], x.shape[-1], w.shape[-1])
    return emulated_matmul(x, w, policy)


@functools.partial(jax.jit, static_argnames=("policy",))
def _matmul_jit(x, w, *, policy):
    return matmul(x, w, policy=policy)


def matmul_jit(x, w, *, policy: GemmPolicy | None = None):
    """`matmul` behind a (shapes, policy)-cached `jax.jit` for eager callers.

    The ambient policy — and, for a mesh-less sharded policy, the ambient
    `use_mesh` mesh — is resolved *before* jit so the context scopes can
    never leak stale into the compilation cache (a policy that resolved
    mesh A at first trace must not silently serve mesh B's scope from the
    cache).
    """
    policy = current_policy() if policy is None else policy
    if policy.execution == "sharded" and policy.mesh is None:
        policy = dataclasses.replace(policy, mesh=current_mesh())
    return _matmul_jit(x, w, policy=policy)


def _blas(routine: str, dtype, x, w, policy: GemmPolicy | None):
    base = current_policy() if policy is None else policy
    dt = jnp.dtype(dtype)
    pol = dataclasses.replace(base, backend=BACKEND_FOR_DTYPE[dt.name])
    if isinstance(w, PreparedOperand):
        if jnp.dtype(w.dtype) != dt:
            raise ValueError(
                f"{routine} computes in {dt.name} but the prepared operand "
                f"was cast for {w.dtype}"
            )
        return matmul(x, w, policy=pol)
    return matmul(x.astype(dt), w.astype(dt), policy=pol)


def sgemm(x, w, *, policy: GemmPolicy | None = None):
    """Emulated SGEMM: f32 compute, every other knob (mode, execution,
    n_block, ...) inherited from `policy` / the ambient scope.

    Coerces both operands to float32 and forces ``backend="ozaki2_f32"`` —
    `sgemm(a, b)` is always the emulated f32 product, whatever the ambient
    backend field says.

    >>> import jax.numpy as jnp, repro
    >>> repro.linalg.sgemm(jnp.eye(2), jnp.ones((2, 2))).dtype.name
    'float32'
    """
    return _blas("sgemm", jnp.float32, x, w, policy)


def dgemm(x, w, *, policy: GemmPolicy | None = None):
    """Emulated DGEMM: f64 compute, every other knob from the policy.
    On the kernel/fp8 executions the output is f64-shaped but f32-grade
    (the Pallas cast quantizes through f32).

    >>> import jax.numpy as jnp, repro
    >>> repro.linalg.dgemm(jnp.eye(2), jnp.ones((2, 2))).dtype.name
    'float64'
    """
    return _blas("dgemm", jnp.float64, x, w, policy)


def cgemm(x, w, *, policy: GemmPolicy | None = None):
    """Emulated CGEMM (paper SIII): complex64 compute; the complex product
    strategy is the policy's `formulation` (Fig. 1), default Karatsuba.

    >>> import jax.numpy as jnp, repro
    >>> a = jnp.eye(2) * (1 + 1j)
    >>> repro.linalg.cgemm(a, a).dtype.name
    'complex64'
    """
    return _blas("cgemm", jnp.complex64, x, w, policy)


def zgemm(x, w, *, policy: GemmPolicy | None = None):
    """Emulated ZGEMM (paper SIII): complex128 compute — the headline
    routine on hardware with no native f64 (TPU v5e).

    >>> import jax.numpy as jnp, repro
    >>> a = jnp.eye(2, dtype=jnp.complex128) * 2j
    >>> y = repro.linalg.zgemm(a, a)
    >>> (y.dtype.name, complex(y[0, 0]))
    ('complex128', (-4+0j))
    """
    return _blas("zgemm", jnp.complex128, x, w, policy)
