"""python -m repro.analysis — certify the traced pipeline across the matrix.

Traces the *real* deployment path (`repro.linalg.matmul` under each
`GemmPolicy`, plus a tiny-model train step fwd+bwd) across an
execution x dtype x mode matrix at smoke shapes — including adaptive
``mode="auto"`` rows with per-dtype rtol targets whose resolved plans the
`AccuracyPass` certifies against the `core.accuracy` bound — runs every
analysis pass the policy's backend mandates (``backend.analyze(plan,
shape)``), the static CRT partial-split certificate, and the source lints
— and exits nonzero if any finding survives.  CI runs this as the `tier1-analysis`
job::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \\
        PYTHONPATH=src python -m repro.analysis --matrix smoke

With a single device the sharded rows run on a degenerate 1-device mesh
(the passes still certify the collective layout of the traced program).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

#: smoke-matrix GEMM shape (matches the tier-1 fast profile)
SMOKE_SHAPE = (32, 96, 24)

#: small-but-valid moduli counts per compute dtype (the tier-1 profile)
N_MODULI = {"float32": 5, "float64": 6, "complex64": 5, "complex128": 6}

DTYPES = ("float32", "float64", "complex64", "complex128")
MODES = ("fast", "accu")

#: adaptive rows: requested componentwise tolerance per compute dtype
#: (mode="auto" resolves the cheapest (mode, n_moduli) meeting it; the
#: AccuracyPass then certifies the resolved plan's static bound)
ADAPTIVE_RTOL = {
    "float32": 1e-4,
    "float64": 1e-9,
    "complex64": 1e-4,
    "complex128": 1e-9,
}


def _mesh_for(execution: str):
    """A (data, model, residue) mesh for sharded rows: 2-way residue when
    the host exposes >=2 devices, else degenerate 1x1x1."""
    if execution != "sharded":
        return None
    import jax
    import numpy as np
    from jax.sharding import Mesh

    r = 2 if jax.device_count() >= 2 else 1
    devices = np.asarray(jax.devices()[:r]).reshape(1, 1, r)
    return Mesh(devices, ("data", "model", "residue"))


def _run_matmul_row(execution, dtype_name, mode, shape, rtol=None):
    import jax
    import jax.numpy as jnp

    from repro import linalg
    from repro.analysis import certify_partial_split, run_passes
    from repro.core.policy import BACKEND_FOR_DTYPE, GemmPolicy

    m, k, n = shape
    kwargs = dict(
        backend=BACKEND_FOR_DTYPE[dtype_name],
        mode=mode,
        execution=execution,
        interpret=True,
    )
    if rtol is None:
        kwargs["n_moduli"] = N_MODULI[dtype_name]
    else:
        # adaptive row (mode="auto"): the policy resolves its own
        # (mode, n_moduli); the AccuracyPass certifies the resolved plan
        kwargs["rtol"] = rtol
    mesh = _mesh_for(execution)
    if mesh is not None:
        kwargs["mesh"] = mesh
    policy = GemmPolicy(**kwargs)
    if policy.is_adaptive:
        policy = policy.resolve_adaptive(m, k, n)
    plan = policy.plan_for(m, k, n)
    backend = policy.execution_backend()
    passes = backend.analyze(plan, (m, k, n))

    a = jnp.zeros((m, k), jnp.dtype(dtype_name))
    b = jnp.zeros((k, n), jnp.dtype(dtype_name))
    jaxpr = jax.make_jaxpr(
        lambda x, w: linalg.matmul(x, w, policy=policy)
    )(a, b)
    findings = run_passes(passes, jaxpr)
    findings += certify_partial_split(plan.ctx.moduli)
    return findings, [p.name for p in passes], plan


def _run_model_row(execution):
    """Trace a tiny-model train step (fwd+bwd under `use_policy`) and run
    the shape-independent passes (overflow, collectives, scan indices)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis import run_passes
    from repro.core.policy import GemmPolicy
    from repro.models import Model
    from repro.models.config import ModelConfig
    from repro.optim import AdamWConfig
    from repro.train.step import init_state, make_train_step

    policy = GemmPolicy(
        backend="ozaki2_f32", n_moduli=4, execution=execution, interpret=True
    )
    cfg = ModelConfig(
        name="analysis-tiny", n_layers=2, d_model=32, vocab=64, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, dtype="float32", remat=True,
        gemm_policy=policy,
    )
    model = Model(cfg)
    opt = AdamWConfig()
    step, _ = make_train_step(model, opt, donate=False)
    params, opt_state = init_state(model, opt, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.asarray(
            np.zeros((2, 16), dtype=np.int32), jnp.int32
        )
    }
    jaxpr = jax.make_jaxpr(step)(params, opt_state, batch)
    backend = policy.execution_backend()
    plan = policy.plan_for(*SMOKE_SHAPE)
    # no launch expectation: the step runs many GEMM shapes
    passes = backend.analyze(plan, None)
    return run_passes(passes, jaxpr), [p.name for p in passes]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static certification of the residue-emulation stack",
    )
    ap.add_argument("--matrix", choices=["smoke"], default="smoke",
                    help="shape profile for the traced matrix (smoke: the "
                         "tier-1 fast dims %s)" % (SMOKE_SHAPE,))
    ap.add_argument("--executions", nargs="+", default=None,
                    help="subset of GemmPolicy executions (default: all)")
    ap.add_argument("--dtypes", nargs="+", default=None, choices=DTYPES,
                    help="subset of compute dtypes (default: all four)")
    ap.add_argument("--modes", nargs="+", default=None, choices=MODES,
                    help="subset of scaling modes (default: fast and accu)")
    ap.add_argument("--shape", nargs=3, type=int, metavar=("M", "K", "N"),
                    default=None, help="override the matrix GEMM shape")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="repro.tune calibration cache to load before "
                         "tracing: the matrix then certifies the *tuned* "
                         "configuration — measured-HW 'auto' plans and "
                         "autotuned Pallas blocks (an unusable cache is an "
                         "error here: silently certifying the untuned "
                         "config would defeat the point)")
    ap.add_argument("--skip-model", action="store_true",
                    help="skip the model fwd+bwd rows")
    ap.add_argument("--skip-lint", action="store_true",
                    help="skip the source-level policy-surface lints")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every clean row, not just a summary")
    args = ap.parse_args(argv)

    import repro  # noqa: F401 - enables x64; the matrix certifies under it
    from repro.analysis import lint_repo
    from repro.core.policy import EXECUTIONS

    if args.calibration is not None:
        import warnings

        from repro.tune.cache import load_calibration

        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            try:
                cal = load_calibration(args.calibration)
            except RuntimeWarning as w:
                cal = None
                reason = f" ({w})"
            else:
                reason = ""
        if cal is None:
            ap.error(
                f"--calibration {args.calibration}: cache unusable{reason}"
            )
        repro.set_calibration(cal)
        print(
            f"repro.analysis: calibration loaded ({cal.device_kind} "
            f"x{cal.device_count}, {len(cal.blocks)} tuned block slots)"
        )

    executions = tuple(args.executions or EXECUTIONS)
    unknown = set(executions) - set(EXECUTIONS)
    if unknown:
        ap.error(f"unknown executions {sorted(unknown)}; valid: {EXECUTIONS}")
    dtypes = tuple(args.dtypes or DTYPES)
    modes = tuple(args.modes or MODES)
    shape = tuple(args.shape) if args.shape else SMOKE_SHAPE

    all_findings = []
    rows = clean = 0
    for execution in executions:
        for dtype_name in dtypes:
            for mode in modes:
                rows += 1
                label = f"{execution:>18s} x {dtype_name:>10s} x {mode}"
                try:
                    findings, pass_names, _ = _run_matmul_row(
                        execution, dtype_name, mode, shape
                    )
                except Exception as exc:  # row must trace to certify
                    print(f"ERROR {label}: trace failed: {exc!r}")
                    all_findings.append(exc)
                    continue
                if findings:
                    print(f"FAIL  {label}")
                    for f in findings:
                        print(f"      {f}")
                    all_findings.extend(findings)
                else:
                    clean += 1
                    if args.verbose:
                        print(f"ok    {label}  [{', '.join(pass_names)}]")

        # adaptive rows: mode="auto" + per-dtype rtol; the resolved plan's
        # static accuracy bound is certified by the AccuracyPass
        for dtype_name in dtypes:
            rows += 1
            rtol = ADAPTIVE_RTOL[dtype_name]
            label = f"{execution:>18s} x {dtype_name:>10s} x auto(rtol={rtol:g})"
            try:
                findings, pass_names, plan = _run_matmul_row(
                    execution, dtype_name, "auto", shape, rtol=rtol
                )
            except Exception as exc:
                print(f"ERROR {label}: trace failed: {exc!r}")
                all_findings.append(exc)
                continue
            resolved = f"-> {plan.mode}/N={plan.n_moduli}"
            if findings:
                print(f"FAIL  {label} {resolved}")
                for f in findings:
                    print(f"      {f}")
                all_findings.extend(findings)
            else:
                clean += 1
                if args.verbose:
                    print(
                        f"ok    {label} {resolved}  "
                        f"[{', '.join(pass_names)}]"
                    )

    if not args.skip_model:
        for execution in ("kernel",):
            rows += 1
            label = f"{'model fwd+bwd':>18s} x {execution}"
            try:
                findings, pass_names = _run_model_row(execution)
            except Exception as exc:
                print(f"ERROR {label}: trace failed: {exc!r}")
                all_findings.append(exc)
                continue
            if findings:
                print(f"FAIL  {label}")
                for f in findings:
                    print(f"      {f}")
                all_findings.extend(findings)
            else:
                clean += 1
                if args.verbose:
                    print(f"ok    {label}  [{', '.join(pass_names)}]")

    if not args.skip_lint:
        rows += 1
        root = Path(__file__).resolve().parents[3]
        findings = lint_repo(root)
        if findings:
            print(f"FAIL  {'source lints':>18s} ({root})")
            for f in findings:
                print(f"      {f}")
            all_findings.extend(findings)
        else:
            clean += 1
            if args.verbose:
                print(f"ok    {'source lints':>18s}")

    import jax

    print(
        f"repro.analysis: {clean}/{rows} rows certified clean "
        f"({len(all_findings)} findings) on {jax.device_count()} device(s)"
    )
    return 1 if all_findings else 0


if __name__ == "__main__":
    sys.exit(main())
