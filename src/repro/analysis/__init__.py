"""repro.analysis — static certification of the residue-emulation stack.

A pass framework over traced jaxprs plus source-level lints, wired into CI
(`python -m repro.analysis --matrix smoke`).  Four jaxpr passes certify the
invariants every engine must uphold (see docs/static_analysis.md):

* :class:`OverflowPass` — int8 residue dots within ``K_CHUNK_LIMIT``, fp8
  digit dots within ``FP8_K_CHUNK_LIMIT``, CRT partial f64 dots within the
  exact 2^53 window (paper SIII-A accumulation bound);
* :class:`CollectiveSafetyPass` — only >=32-bit (exact) arrays cross the
  mesh in collectives;
* :class:`LaunchCountPass` — `pallas_call` count equals the perfmodel's
  `kernel_launch_count` prediction;
* :class:`ScanIndexWidthPass` — no s64 index feeds indexing primitives
  inside scan bodies (the SPMD partitioner-crash bug class of PRs 5/6);
* :class:`AccuracyPass` — a plan declaring an accuracy contract
  (``EmulationPlan.rtol``, stamped by adaptive ``GemmPolicy(rtol=...)`` /
  ``mode="auto"`` policies) must have static `core.accuracy.rel_bound`
  <= the declared tolerance at the row's contraction length.

Every residue backend exposes ``analyze(plan, shape=None)`` returning the
pass suite for its engine; `passes_for_backend` is the shared resolver.

Example::

    import jax, jax.numpy as jnp
    from repro.analysis import CollectiveSafetyPass

    jaxpr = jax.make_jaxpr(jnp.matmul)(
        jnp.zeros((8, 4)), jnp.zeros((4, 2)))
    assert CollectiveSafetyPass().run(jaxpr) == []   # nothing crosses a mesh
"""
from .jaxprs import (  # noqa: F401
    EqnContext,
    count_pallas_calls,
    count_pallas_launches,
    count_primitive,
    iter_eqns,
    iter_subjaxprs,
)
from .lint import (  # noqa: F401
    EXECUTION_CLIS,
    execution_choices,
    lint_policy_surface,
    lint_repo,
)
from .passes import (  # noqa: F401
    COLLECTIVE_PRIMS,
    AccuracyPass,
    CollectiveSafetyPass,
    Finding,
    LaunchCountPass,
    OverflowPass,
    ScanIndexWidthPass,
    certify_launch_count,
    certify_partial_split,
    collect_collectives,
    expected_launch_count,
    passes_for_backend,
    run_passes,
)

__all__ = [
    "AccuracyPass",
    "EqnContext",
    "Finding",
    "OverflowPass",
    "CollectiveSafetyPass",
    "LaunchCountPass",
    "ScanIndexWidthPass",
    "COLLECTIVE_PRIMS",
    "EXECUTION_CLIS",
    "collect_collectives",
    "certify_launch_count",
    "certify_partial_split",
    "count_pallas_calls",
    "count_pallas_launches",
    "count_primitive",
    "execution_choices",
    "expected_launch_count",
    "iter_eqns",
    "iter_subjaxprs",
    "lint_policy_surface",
    "lint_repo",
    "passes_for_backend",
    "run_passes",
]
