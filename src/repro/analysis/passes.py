"""Jaxpr-level analysis passes certifying the residue pipeline's invariants.

Each pass is a small object with a ``name`` and a ``run(jaxpr) ->
list[Finding]`` method; ``jaxpr`` is whatever `jax.make_jaxpr` returned (a
ClosedJaxpr) or any open Jaxpr.  An empty list is a certificate; a finding
names the violated invariant and where it was found.  The passes:

``OverflowPass``
    The paper SIII-A accumulation bound, proved from shapes/dtypes/consts
    of the traced program instead of trusted from the chunking code:

    * every `dot_general` whose operands are int8 residue planes must have
      effective contraction length <= ``K_CHUNK_LIMIT`` (2^17): with
      |residue| <= 127 the int32 accumulator stays < 2^31, so no silent
      wraparound.  Inside a `pallas_call` the *effective* contraction is
      the per-block contraction times the innermost grid axis, because all
      of this repo's mod-GEMM kernels iterate K as the last grid dimension
      and accumulate in scratch across it.
    * every fp8 (float8_e4m3*) dot must have effective contraction
      <= 2 * ``FP8_K_CHUNK_LIMIT``: balanced base-16 digits are bounded by
      8, so digit products are <= 64 and eff_k * 64 <= 2^23 keeps the f32
      accumulator exact (< 2^24).  The factor 2 admits the Karatsuba /
      cross-term dots, which concatenate two digit planes along K.
    * every f64 `dot_general` whose operand magnitudes are *provable*
      (from consts, or int8/fp8 inputs converted to f64) must satisfy
      |lhs| * |rhs| * eff_k <= 2^53 — the exact-f64-integer window the CRT
      partial-split reconstruction relies on.  Unprovable f64/f32/bf16
      dots are out of scope (ordinary float compute) and never flagged.

``CollectiveSafetyPass``
    No low-precision array may cross the mesh: any collective
    (psum/pmax/pmin/all_gather/all_to_all/ppermute/reduce_scatter/...)
    with an operand dtype narrower than 4 bytes is a finding.  The sharded
    pipeline's contract is that only exact f64 CRT partials (and int32
    exponent scalars) are communicated.

``LaunchCountPass``
    `pallas_call` eqn count must equal the perfmodel's
    ``kernel_launch_count(...)`` for the policy under analysis (use
    :func:`expected_launch_count` to derive the expectation from a
    backend + plan + shape).

``AccuracyPass``
    A plan carrying a declared tolerance (``EmulationPlan.rtol``, stamped
    by `GemmPolicy(rtol=...)` / ``mode="auto"``) must *provably* meet it:
    the static `core.accuracy.rel_bound` for (dtype, mode, n_moduli, k,
    formulation) must be <= the declared rtol.  Static check — the traced
    jaxpr is not consulted (quantization is the only inexact step and every
    execution is bitwise-identical to the reference, so the bound depends
    only on the plan), but the pass runs in the same suite so a
    ``--matrix`` row with an rtol column is certified alongside its
    overflow/launch invariants.

``ScanIndexWidthPass``
    Flags s64 indices feeding `dynamic_slice` / `dynamic_update_slice` /
    `gather` / `scatter*` inside `scan` bodies — the exact SPMD
    partitioner-crash bug class fixed by hand in PRs 5 and 6 (a Python-int
    carry index weakly typed to int64 under x64).  The fix is always an
    explicit ``jnp.int32`` index.

:func:`passes_for_backend` assembles the suite for a residue backend (the
``analyze(plan, shape)`` hook on every backend delegates here), and
:func:`certify_partial_split` statically certifies the CRT partial-split
tables themselves.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .jaxprs import EqnContext, count_primitive, iter_eqns, unwrap

__all__ = [
    "Finding",
    "AccuracyPass",
    "OverflowPass",
    "CollectiveSafetyPass",
    "LaunchCountPass",
    "ScanIndexWidthPass",
    "COLLECTIVE_PRIMS",
    "collect_collectives",
    "certify_partial_split",
    "certify_launch_count",
    "expected_launch_count",
    "passes_for_backend",
    "run_passes",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant found by a pass.

    ``pass_name``  the pass that produced it;
    ``message``    human-readable description naming the bound violated;
    ``primitive``  the jaxpr primitive at fault (None for static checks);
    ``path``       enclosing primitive names, outermost first.
    """

    pass_name: str
    message: str
    primitive: str | None = None
    path: tuple = ()

    def __str__(self) -> str:
        where = "/".join(self.path + ((self.primitive,) if self.primitive else ()))
        return f"[{self.pass_name}] {where or '<static>'}: {self.message}"


class JaxprPass:
    """Base class: iterate every (eqn, context) and collect findings."""

    name = "pass"

    def run(self, jaxpr) -> list:
        findings: list[Finding] = []
        for eqn, ctx in iter_eqns(jaxpr):
            self.visit(eqn, ctx, findings)
        return findings

    def visit(self, eqn, ctx: EqnContext, findings: list) -> None:
        raise NotImplementedError


def _default_k_limit() -> int:
    from ..core.moduli import K_CHUNK_LIMIT

    return K_CHUNK_LIMIT


def _default_fp8_limit() -> int:
    try:
        from ..kernels.fp8_mod_gemm import FP8_K_CHUNK_LIMIT

        return FP8_K_CHUNK_LIMIT
    except Exception:  # pragma: no cover - fp8 kernels unavailable
        return 1 << 16


def _abs_bound(val) -> float | None:
    """max|val| for a concrete numeric array, None if not provable."""
    try:
        arr = np.asarray(val)
    except Exception:
        return None
    if arr.size == 0:
        return 0.0
    if arr.dtype.kind not in "iufb":
        return None
    arr = arr.astype(np.float64)
    if not np.all(np.isfinite(arr)):
        return None
    return float(np.max(np.abs(arr)))


# dtype-derived magnitude bounds: int8 residue planes are symmetric residues
# (|r| <= 127 by construction, and 127 is the dtype bound anyway); fp8 e4m3
# operands in this codebase are balanced base-16 digits, |d| <= 8 — that
# invariant comes from kernels/fp8_mod_gemm._digits and is assumed here.
_FP8_DIGIT_BOUND = 8.0


def _dtype_bound(dtype) -> float | None:
    dt = np.dtype(dtype) if not hasattr(dtype, "kind") else dtype
    name = getattr(dt, "name", str(dt))
    if name == "int8":
        return 127.0
    if name == "uint8":
        return 255.0
    if name == "bool":
        return 1.0
    if name.startswith("float8"):
        return _FP8_DIGIT_BOUND
    return None


def _is_int8(dtype) -> bool:
    name = getattr(dtype, "name", str(dtype))
    return name in ("int8", "uint8")


def _is_fp8(dtype) -> bool:
    name = getattr(dtype, "name", str(dtype))
    return name.startswith("float8")


@dataclasses.dataclass(frozen=True)
class OverflowPass:
    """Overflow/exactness certifier (paper SIII-A accumulation bound)."""

    k_limit: int | None = None
    fp8_limit: int | None = None
    f64_exact: float = 2.0**53

    name = "overflow"

    def run(self, jaxpr) -> list:
        open_jaxpr, consts = unwrap(jaxpr)
        findings: list[Finding] = []
        k_limit = self.k_limit if self.k_limit is not None else _default_k_limit()
        fp8_limit = (
            self.fp8_limit if self.fp8_limit is not None else _default_fp8_limit()
        )
        self._walk(open_jaxpr, consts, None, (), k_limit, fp8_limit, findings)
        return findings

    # -- bound environment ------------------------------------------------
    @staticmethod
    def _bound_of(atom, bounds: dict) -> float | None:
        if hasattr(atom, "val"):  # Literal
            return _abs_bound(atom.val)
        try:
            if atom in bounds:
                return bounds[atom]
        except TypeError:  # unhashable atom
            pass
        aval = getattr(atom, "aval", None)
        dt = getattr(aval, "dtype", None)
        return _dtype_bound(dt) if dt is not None else None

    # propagation through shape/dtype-preserving ops keeps bounds provable
    # across the convert-to-f64 step in front of the CRT partial dots
    _PRESERVING = frozenset(
        {
            "convert_element_type",
            "reshape",
            "transpose",
            "broadcast_in_dim",
            "squeeze",
            "expand_dims",
            "slice",
            "dynamic_slice",
            "rev",
            "neg",
            "abs",
            "copy",
            "device_put",
            "stop_gradient",
            "reduce_precision",
        }
    )

    def _walk(self, jaxpr, consts, grid, path, k_limit, fp8_limit, findings):
        bounds: dict = {}
        if consts is not None:
            for var, val in zip(jaxpr.constvars, consts):
                b = _abs_bound(val)
                if b is not None:
                    bounds[var] = b

        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            if prim == "dot_general":
                self._check_dot(
                    eqn, bounds, grid, path, k_limit, fp8_limit, findings
                )
            elif prim in self._PRESERVING and eqn.invars:
                b = self._bound_of(eqn.invars[0], bounds)
                if b is not None:
                    bounds[eqn.outvars[0]] = b
            elif prim == "concatenate":
                bs = [self._bound_of(v, bounds) for v in eqn.invars]
                if all(b is not None for b in bs):
                    bounds[eqn.outvars[0]] = max(bs)

            # recurse into nested jaxprs (pjit/shard_map/scan/cond/pallas)
            sub_grid = grid
            if prim == "pallas_call":
                from .jaxprs import pallas_grid

                sub_grid = pallas_grid(eqn.params)
            from .jaxprs import _closed_subjaxprs

            for v in eqn.params.values():
                for sub, sub_consts in _closed_subjaxprs(v):
                    self._walk(
                        sub,
                        sub_consts,
                        sub_grid,
                        path + (prim,),
                        k_limit,
                        fp8_limit,
                        findings,
                    )

    def _check_dot(self, eqn, bounds, grid, path, k_limit, fp8_limit, findings):
        lhs, rhs = eqn.invars[:2]
        (lhs_contract, _), _ = eqn.params["dimension_numbers"]
        contraction = 1
        for axis in lhs_contract:
            contraction *= int(lhs.aval.shape[axis])
        # inside a pallas kernel the innermost grid axis accumulates into
        # scratch across steps (K is always the last grid dim in this
        # repo's mod-GEMM kernels) — that is the true contraction length
        eff = contraction * (grid[-1] if grid else 1)
        ldt = lhs.aval.dtype
        rdt = rhs.aval.dtype

        if _is_int8(ldt) and _is_int8(rdt):
            if eff > k_limit:
                findings.append(
                    Finding(
                        self.name,
                        f"int8 dot_general accumulates effective K={eff} > "
                        f"K_CHUNK_LIMIT={k_limit}; 127^2 * K no longer fits "
                        "the exact int32 window (paper SIII-A bound)",
                        primitive="dot_general",
                        path=path,
                    )
                )
        elif _is_fp8(ldt) and _is_fp8(rdt):
            if eff > 2 * fp8_limit:
                findings.append(
                    Finding(
                        self.name,
                        f"fp8 dot_general accumulates effective K={eff} > "
                        f"2*FP8_K_CHUNK_LIMIT={2 * fp8_limit}; digit products "
                        "(<=64) would leave the exact f32 window (2^24)",
                        primitive="dot_general",
                        path=path,
                    )
                )
        else:
            out_dt = eqn.outvars[0].aval.dtype
            if getattr(out_dt, "name", str(out_dt)) == "float64":
                lb = self._bound_of(lhs, bounds)
                rb = self._bound_of(rhs, bounds)
                if lb is not None and rb is not None:
                    worst = lb * rb * eff
                    if worst > self.f64_exact:
                        findings.append(
                            Finding(
                                self.name,
                                f"f64 dot_general partial sum bounded by "
                                f"{lb:g} * {rb:g} * K={eff} = {worst:.3g} > "
                                "2^53: CRT partial-combine would round",
                                primitive="dot_general",
                                path=path,
                            )
                        )


#: collective primitives whose operands cross the mesh (jaxpr-level names)
COLLECTIVE_PRIMS = frozenset(
    {
        "psum",
        "psum2",
        "pmax",
        "pmin",
        "pmean",
        "all_gather",
        "all_reduce",
        "all_to_all",
        "ppermute",
        "pbroadcast",
        "reduce_scatter",
    }
)


@dataclasses.dataclass(frozen=True)
class CollectiveSafetyPass(JaxprPass):
    """No int8/fp8/low-precision array may flow into a collective."""

    min_itemsize: int = 4

    name = "collective-safety"

    def visit(self, eqn, ctx, findings):
        if eqn.primitive.name not in COLLECTIVE_PRIMS:
            return
        for v in eqn.invars:
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is None:
                continue
            if np.dtype(dt).itemsize < self.min_itemsize:
                findings.append(
                    Finding(
                        self.name,
                        f"{dt} array crosses the mesh via "
                        f"`{eqn.primitive.name}`; only exact f64 CRT "
                        "partials (and >=32-bit scalars) may be "
                        "communicated",
                        primitive=eqn.primitive.name,
                        path=ctx.path,
                    )
                )


def collect_collectives(jaxpr) -> list:
    """All collective eqns in `jaxpr` as (primitive_name, [operand dtypes]).

    Positive-evidence helper for tests: e.g. assert an f64 psum exists in a
    sharded trace (the CollectiveSafetyPass alone would also pass on a
    program with no communication at all).
    """
    out = []
    for eqn, _ in iter_eqns(jaxpr):
        if eqn.primitive.name in COLLECTIVE_PRIMS:
            dtypes = [
                getattr(getattr(v, "aval", None), "dtype", None)
                for v in eqn.invars
            ]
            out.append((eqn.primitive.name, dtypes))
    return out


@dataclasses.dataclass(frozen=True)
class LaunchCountPass:
    """pallas_call count must equal the perfmodel's prediction."""

    expected: int

    name = "launch-count"

    def run(self, jaxpr) -> list:
        open_jaxpr, _ = unwrap(jaxpr)
        got = count_primitive(open_jaxpr, "pallas_call")
        if got != self.expected:
            return [
                Finding(
                    self.name,
                    f"traced program has {got} pallas_call launches, "
                    f"perfmodel.kernel_launch_count predicts {self.expected}",
                    primitive="pallas_call",
                )
            ]
        return []


@dataclasses.dataclass(frozen=True)
class AccuracyPass:
    """The plan's static error bound must meet its declared tolerance.

    ``plan`` is the :class:`~repro.core.plan.EmulationPlan` under analysis
    and ``k`` the contraction length of the certified GEMM; ``rtol``
    defaults to the plan's own declared contract (``plan.rtol``).  The
    check is `core.accuracy.rel_bound(...) <= rtol` — purely static, since
    quantization is the scheme's only inexact step and every execution
    backend is bitwise-identical to the reference (PR 5/6 invariant), so
    the componentwise bound depends on the plan alone, not the trace.
    A plan with no declared rtol trivially certifies (empty suite result).
    """

    plan: object
    k: int
    rtol: float | None = None

    name = "accuracy"

    def run(self, jaxpr) -> list:
        del jaxpr  # static check; see class docstring
        rtol = self.rtol if self.rtol is not None else self.plan.rtol
        if rtol is None:
            return []
        from ..core.accuracy import rel_bound

        bound = rel_bound(
            self.plan.dtype,
            self.plan.mode,
            self.plan.n_moduli,
            int(self.k),
            formulation=self.plan.formulation,
            out_dtype=self.plan.out_dtype,
        )
        if bound > rtol:
            return [
                Finding(
                    self.name,
                    f"plan ({self.plan.dtype}, mode={self.plan.mode}, "
                    f"N={self.plan.n_moduli}, {self.plan.formulation}) has "
                    f"static componentwise bound {bound:.3g} at k={self.k} "
                    f"> declared rtol={rtol:.3g}",
                )
            ]
        return []


# primitives that consume index operands, and which invars are indices
_INDEXED_PRIMS = {
    "dynamic_slice": slice(1, None),
    "dynamic_update_slice": slice(2, None),
    "gather": slice(1, 2),
    "scatter": slice(1, 2),
    "scatter-add": slice(1, 2),
    "scatter-mul": slice(1, 2),
    "scatter-min": slice(1, 2),
    "scatter-max": slice(1, 2),
}


@dataclasses.dataclass(frozen=True)
class ScanIndexWidthPass(JaxprPass):
    """No s64 index may feed indexing primitives inside a scan body.

    Under x64 (this repo enables it globally for the f64 CRT arithmetic) a
    Python-int scan carry weakly types to int64; an s64 index feeding
    dynamic_slice/gather inside the scanned body crashes the SPMD
    partitioner on sharded meshes (the PR 5/6 bug class).  Use
    ``jnp.int32`` indices in scan carries.
    """

    name = "scan-index-width"

    def visit(self, eqn, ctx, findings):
        idx = _INDEXED_PRIMS.get(eqn.primitive.name)
        if idx is None or not ctx.in_scan_body:
            return
        for v in eqn.invars[idx]:
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and getattr(dt, "name", str(dt)) == "int64":
                findings.append(
                    Finding(
                        self.name,
                        f"int64 index feeds `{eqn.primitive.name}` inside a "
                        "scan body; use an explicit jnp.int32 index (s64 "
                        "scan-carried indices crash the SPMD partitioner "
                        "under x64)",
                        primitive=eqn.primitive.name,
                        path=ctx.path,
                    )
                )


def certify_partial_split(moduli, u=None, part_bits=None) -> list:
    """Statically certify the CRT partial-split tables for `moduli`.

    Checks (see core/crt.partial_split): every entry of the combine table
    ``u`` is a nonnegative integer below ``2**part_bits``, and the worst
    partial sum ``max(u) * 127 * N`` stays within the exact f64 integer
    window (2^53) — so `partial_combine`'s f64 tensordot is exact for any
    residue inputs.  Pass `u` / `part_bits` explicitly to audit a foreign
    table; by default the tables are recomputed from `moduli`.
    """
    from ..core import crt

    moduli = tuple(int(q) for q in moduli)
    if u is None or part_bits is None:
        u_tab, _, pb = crt.partial_split(moduli)
        u = u_tab if u is None else u
        part_bits = pb if part_bits is None else part_bits
    u = np.asarray(u, dtype=np.float64)
    n = len(moduli)
    findings: list[Finding] = []
    name = "overflow"
    if np.any(u < 0) or np.any(u != np.floor(u)):
        findings.append(
            Finding(name, "partial-split table u has non-integer or negative "
                          "entries; f64 reconstruction is not exact")
        )
    if u.size and float(np.max(u)) >= 2.0 ** int(part_bits):
        findings.append(
            Finding(
                name,
                f"partial-split table entry {np.max(u):.0f} >= 2^part_bits="
                f"2^{part_bits}; parts are wider than the split claims",
            )
        )
    worst = (float(np.max(u)) if u.size else 0.0) * 127.0 * n
    if worst > 2.0**53:
        findings.append(
            Finding(
                name,
                f"worst CRT partial sum max(u)*127*N = {worst:.3g} > 2^53; "
                "partial_combine's f64 accumulation would round",
            )
        )
    return findings


def expected_launch_count(backend, plan, shape, prepared: bool = False):
    """perfmodel launch-count prediction for `backend` executing `plan` at
    ``shape = (m, k, n)``; None when no static prediction applies."""
    from ..core import perfmodel

    m, k, n = shape
    if not getattr(backend, "uses_pallas", True):
        return 0
    engine = getattr(backend, "engine", "int8")
    chunk_limit = _default_fp8_limit() if engine == "fp8" else _default_k_limit()
    fused = bool(getattr(backend, "megakernel", False))
    shard_factors = getattr(backend, "shard_factors", None)
    n_local = n
    if callable(shard_factors):
        _, nd, r = shard_factors(m, n)
        n_local = -(-n // nd)
        # the sharded fused worker only engages on m/n-only meshes; on a
        # residue mesh it falls back to the composed kernel pipeline
        fused = fused and r == 1
    n_chunks = max(1, -(-k // chunk_limit))
    n_blocks = len(list(plan.n_block_slices(n_local)))
    formulation = plan.formulation if plan.is_complex else "real"
    return perfmodel.kernel_launch_count(
        plan.n_moduli,
        formulation,
        modulus_batched=getattr(backend, "modulus_batched", False),
        fused_karatsuba=getattr(backend, "fused_karatsuba", False),
        n_chunks=n_chunks,
        n_blocks=n_blocks,
        prepared=prepared,
        fused=fused,
    )


def certify_launch_count(expected: int, fn, *args, **kwargs) -> list:
    """Trace fn(*args, **kwargs) and run LaunchCountPass(expected) on it."""
    import jax

    jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    return LaunchCountPass(expected=expected).run(jaxpr)


def passes_for_backend(backend, plan, shape=None) -> tuple:
    """The analysis suite certifying `backend` executing `plan`.

    Always includes the overflow, collective-safety, and scan-index-width
    passes (with the chunk limits of the backend's engine); when `shape`
    is given, also a LaunchCountPass pinned to the perfmodel prediction
    and — for a plan declaring an accuracy contract (``plan.rtol``) — an
    AccuracyPass certifying the static bound at the shape's contraction
    length.  Backends expose this as ``backend.analyze(plan, shape)``.
    """
    passes = [
        OverflowPass(
            k_limit=_default_k_limit(), fp8_limit=_default_fp8_limit()
        ),
        CollectiveSafetyPass(),
        ScanIndexWidthPass(),
    ]
    if shape is not None:
        expected = expected_launch_count(backend, plan, shape)
        if expected is not None:
            passes.append(LaunchCountPass(expected=expected))
        if getattr(plan, "rtol", None) is not None:
            passes.append(AccuracyPass(plan=plan, k=shape[1]))
    return tuple(passes)


def run_passes(passes, jaxpr) -> list:
    """Run every pass over `jaxpr`, concatenating findings."""
    findings: list[Finding] = []
    for p in passes:
        findings.extend(p.run(jaxpr))
    return findings
