"""Source-level lints keeping the policy surface in sync across the repo.

Unlike the jaxpr passes (which certify traced programs), these lints parse
files: the README must document every `GemmPolicy` execution and field, and
every CLI that exposes an ``--execution`` flag must offer exactly the
executions `GemmPolicy` accepts, and must also expose the accuracy-adaptive
``--rtol`` axis — a new engine (or policy axis) that forgets to update a
launcher (or a launcher advertising an execution the policy rejects) is a
finding, not a runtime surprise.

`tests/test_docs.py` delegates its README-vs-code sync check here, and the
`python -m repro.analysis` CLI runs :func:`lint_repo` alongside the jaxpr
matrix.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

from .passes import Finding

__all__ = ["execution_choices", "has_flag", "lint_policy_surface", "lint_repo"]

#: CLIs that must expose the full execution axis
EXECUTION_CLIS = (
    "src/repro/launch/train.py",
    "src/repro/launch/dryrun.py",
    "src/repro/launch/serve.py",
    "benchmarks/bench_throughput.py",
)

_LINT = "policy-surface"


def execution_choices(path) -> list | None:
    """The ``choices=[...]`` of the ``--execution`` argparse flag in `path`,
    or None if the file defines no such flag with literal choices."""
    tree = ast.parse(Path(path).read_text(), filename=str(path))
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            continue
        if not (node.args
                and isinstance(node.args[0], ast.Constant)
                and node.args[0].value == "--execution"):
            continue
        for kw in node.keywords:
            if kw.arg == "choices" and isinstance(kw.value, (ast.List, ast.Tuple)):
                vals = [
                    el.value
                    for el in kw.value.elts
                    if isinstance(el, ast.Constant)
                ]
                return vals
    return None


def has_flag(path, flag: str) -> bool:
    """True if `path` defines an ``add_argument("<flag>", ...)`` call."""
    tree = ast.parse(Path(path).read_text(), filename=str(path))
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and node.args[0].value == flag):
            return True
    return False


def lint_policy_surface(root) -> list:
    """README + CLI surface vs `GemmPolicy`'s literal execution axis."""
    from ..core import policy as policy_mod
    from ..core.policy import EXECUTIONS, GemmPolicy

    root = Path(root)
    findings: list[Finding] = []

    # the typing literal and the runtime tuple must agree (the tuple is
    # what validation and the CLIs key off; the literal is what IDEs see)
    import typing

    literal = typing.get_args(getattr(policy_mod, "Execution", None))
    if literal and set(literal) != set(EXECUTIONS):
        findings.append(
            Finding(
                _LINT,
                "core/policy.py: Execution literal "
                f"{sorted(literal)} != EXECUTIONS {sorted(EXECUTIONS)}",
            )
        )

    readme = (root / "README.md").read_text()
    for ex in EXECUTIONS:
        if f"`{ex}`" not in readme:
            findings.append(
                Finding(
                    _LINT,
                    f"README.md does not document execution `{ex}` "
                    "(every GemmPolicy execution must appear in backticks)",
                )
            )
    for field in dataclasses.fields(GemmPolicy):
        if field.name not in readme:
            findings.append(
                Finding(
                    _LINT,
                    f"README.md does not mention GemmPolicy field "
                    f"`{field.name}`",
                )
            )

    for rel in EXECUTION_CLIS:
        path = root / rel
        if not path.exists():
            findings.append(Finding(_LINT, f"{rel}: file not found"))
            continue
        choices = execution_choices(path)
        if choices is None:
            findings.append(
                Finding(
                    _LINT,
                    f"{rel}: no --execution argument with literal choices",
                )
            )
        elif set(choices) != set(EXECUTIONS):
            missing = sorted(set(EXECUTIONS) - set(choices))
            extra = sorted(set(choices) - set(EXECUTIONS))
            detail = []
            if missing:
                detail.append(f"missing {missing}")
            if extra:
                detail.append(f"unknown {extra}")
            findings.append(
                Finding(
                    _LINT,
                    f"{rel}: --execution choices out of sync with "
                    f"GemmPolicy.EXECUTIONS ({'; '.join(detail)})",
                )
            )
        # the accuracy-adaptive axis must ride along everywhere the
        # execution axis does: every launcher exposes --rtol
        if not has_flag(path, "--rtol"):
            findings.append(
                Finding(
                    _LINT,
                    f"{rel}: no --rtol argument (the adaptive accuracy "
                    "axis, GemmPolicy(rtol=...), must be exposed by every "
                    "execution CLI)",
                )
            )
    return findings


def lint_repo(root) -> list:
    """All source lints for the repo rooted at `root`."""
    return lint_policy_surface(root)
