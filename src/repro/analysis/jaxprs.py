"""Jaxpr walking utilities shared by every analysis pass.

The traced programs this repo certifies are deeply nested: `pjit` bodies
hold `shard_map` bodies hold `pallas_call` kernel jaxprs hold `cond`
sub-jaxprs.  The helpers here generalize the launch-count walker that used
to live in `kernels/common.py` (re-exported from there for compat) into a
single recursive traversal that also tracks *where* an equation lives:

  * `iter_subjaxprs(value)`  — duck-typed extraction of any jaxpr nested in
    an eqn param value (ClosedJaxpr, Jaxpr, or lists/tuples of either);
  * `iter_eqns(jaxpr)`       — depth-first traversal yielding every eqn in
    every nesting level together with an :class:`EqnContext` (the enclosing
    primitive path, whether the eqn sits inside a `scan` body, the grid of
    the enclosing `pallas_call`, and the const bindings of its jaxpr);
  * `count_primitive(jaxpr, name)` / `count_pallas_calls(fn, *args)` — the
    launch-count primitives used by the certifier and the CI smoke bench.

Everything duck-types `jax.core` objects (ClosedJaxpr: has ``.jaxpr`` and
``.consts``; Jaxpr: has ``.eqns`` and ``.invars``) so it survives jax
module reshuffles, exactly like the original `kernels/common` walker.
"""
from __future__ import annotations

import dataclasses

import jax


def _is_closed(v) -> bool:
    return hasattr(v, "jaxpr") and hasattr(v, "consts")


def _is_open(v) -> bool:
    return hasattr(v, "eqns") and hasattr(v, "invars")


def unwrap(jaxpr):
    """(ClosedJaxpr | Jaxpr) -> (open Jaxpr, consts | None)."""
    if _is_closed(jaxpr):
        return jaxpr.jaxpr, list(jaxpr.consts)
    return jaxpr, None


def iter_subjaxprs(v):
    """Yield any (open) jaxprs nested inside an eqn-param value (duck-typed
    so it survives jax.core module reshuffles)."""
    if _is_closed(v):  # ClosedJaxpr
        yield v.jaxpr
    elif _is_open(v):  # Jaxpr
        yield v
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from iter_subjaxprs(item)


def _closed_subjaxprs(v):
    """Like `iter_subjaxprs` but keeps the consts: yields (Jaxpr, consts|None)."""
    if _is_closed(v):
        yield v.jaxpr, list(v.consts)
    elif _is_open(v):
        yield v, None
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from _closed_subjaxprs(item)


@dataclasses.dataclass
class EqnContext:
    """Where an eqn lives inside the traced program.

    ``path``          primitive names of the enclosing eqns, outermost first
                      (e.g. ``("pjit", "shard_map", "pallas_call")``);
    ``in_scan_body``  True inside (any nesting of) a `scan` body jaxpr —
                      the scope the SPMD index-width detector cares about;
    ``pallas_grid``   the grid of the enclosing `pallas_call` (None outside
                      any kernel) — the overflow certifier multiplies dot
                      contractions by the innermost grid axis, since the
                      repo's mod-GEMM kernels all accumulate across it;
    ``consts``        var -> value bindings for the constvars of the eqn's
                      own jaxpr (where the enclosing ClosedJaxpr exposed
                      them), letting passes prove bounds "from consts".
    """

    path: tuple = ()
    in_scan_body: bool = False
    pallas_grid: tuple | None = None
    consts: dict = dataclasses.field(default_factory=dict)


def pallas_grid(params) -> tuple | None:
    """Best-effort grid of a `pallas_call` eqn's params (duck-typed across
    jax versions: grid_mapping.grid, else a plain 'grid' param)."""
    gm = params.get("grid_mapping")
    grid = getattr(gm, "grid", None)
    if grid is None:
        grid = params.get("grid")
    if grid is None:
        return None
    try:
        return tuple(int(g) for g in grid)
    except (TypeError, ValueError):
        return None


def child_context(ctx: EqnContext, eqn) -> EqnContext:
    """The context of jaxprs nested in `eqn`'s params, given `eqn`'s own."""
    name = eqn.primitive.name
    return EqnContext(
        path=ctx.path + (name,),
        in_scan_body=ctx.in_scan_body or name == "scan",
        pallas_grid=(
            pallas_grid(eqn.params) if name == "pallas_call" else ctx.pallas_grid
        ),
    )


def iter_eqns(jaxpr, ctx: EqnContext | None = None):
    """Depth-first (eqn, EqnContext) over `jaxpr` and every nested sub-jaxpr.

    `jaxpr` may be a ClosedJaxpr (consts resolved into the context) or an
    open Jaxpr.
    """
    open_jaxpr, consts = unwrap(jaxpr)
    if ctx is None:
        ctx = EqnContext()
    if consts is not None:
        ctx = dataclasses.replace(
            ctx, consts=dict(zip(open_jaxpr.constvars, consts))
        )
    for eqn in open_jaxpr.eqns:
        yield eqn, ctx
        sub_ctx = child_context(ctx, eqn)
        for v in eqn.params.values():
            for sub, sub_consts in _closed_subjaxprs(v):
                src = sub if sub_consts is None else _Closed(sub, sub_consts)
                yield from iter_eqns(src, sub_ctx)


@dataclasses.dataclass
class _Closed:
    """Minimal ClosedJaxpr stand-in (duck-typed by `unwrap`)."""

    jaxpr: object
    consts: list


def count_primitive(jaxpr, name: str) -> int:
    """Number of `name` equations in `jaxpr`, including nested sub-jaxprs."""
    return sum(1 for eqn, _ in iter_eqns(jaxpr) if eqn.primitive.name == name)


def count_pallas_calls(fn, *args, **kwargs) -> int:
    """Number of `pallas_call` equations in the jaxpr of fn(*args, **kwargs).

    This is the kernel-launch count of one execution (the grid of a single
    call is not a launch multiplier), used by the launch-count certifier,
    the regression tests and the CI smoke benchmark.
    """
    jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    return count_primitive(jaxpr, "pallas_call")


# historical name (pre-analysis-package); kept as a compat alias because the
# kernels package and the fusion benchmark re-export it
count_pallas_launches = count_pallas_calls
