"""Full decoder model: embedding -> scanned layer groups -> norm -> head.

Supports all 10 assigned architecture families (dense / SSM / hybrid / MoE /
VLM+audio backbones with stub frontends) through `ModelConfig`.

Parameters of each homogeneous (block_kind, mlp_kind) group are stacked along
a leading 'layers' axis and driven by `lax.scan` (MaxText-style) so the HLO
stays compact for 64-layer models; `cfg.remat` wraps the scan body in
jax.checkpoint for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .blocks import BLOCKS, moe_abstract, moe_apply
from .config import ModelConfig
from .layers import (
    apply_mlp,
    apply_norm,
    mlp_abstract,
    norm_abstract,
    sinusoidal_embedding,
)
from .params import ParamMeta, abstract_arrays, materialize, stack_metas


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------ params

    def _layer_abstract(self, block_kind: str, mlp_kind: str) -> dict:
        cfg = self.cfg
        out = {
            "norm1": norm_abstract(cfg.norm, cfg.d_model, cfg.dtype),
            "block": BLOCKS[block_kind]["abstract"](cfg),
        }
        if mlp_kind != "none":
            out["norm2"] = norm_abstract(cfg.norm, cfg.d_model, cfg.dtype)
            if mlp_kind == "moe":
                out["mlp"] = moe_abstract(cfg)
            elif mlp_kind == "dense_first":
                out["mlp"] = mlp_abstract(
                    cfg.mlp if cfg.mlp != "moe" else "swiglu",
                    cfg.d_model,
                    cfg.first_dense_ff,
                    cfg.dtype,
                )
            else:
                out["mlp"] = mlp_abstract(mlp_kind, cfg.d_model, cfg.d_ff, cfg.dtype)
        return out

    def abstract_params(self) -> dict:
        cfg = self.cfg
        out = {
            "embed": ParamMeta(
                (cfg.vocab, cfg.d_model),
                ("vocab", "embed"),
                cfg.dtype,
                scale=cfg.d_model**-0.5,  # sane tied-head logits at init
            ),
            "groups": [
                stack_metas(self._layer_abstract(bk, mk), cnt)
                for bk, mk, cnt in cfg.layer_groups
            ],
            "final_norm": norm_abstract(cfg.norm, cfg.d_model, cfg.dtype),
        }
        if not cfg.tie_embeddings:
            out["head"] = ParamMeta(
                (cfg.d_model, cfg.vocab), ("embed", "vocab"), cfg.dtype
            )
        return out

    def init(self, key: jax.Array) -> dict:
        return materialize(self.abstract_params(), key)

    def param_shapes(self) -> dict:
        return abstract_arrays(self.abstract_params())

    # ------------------------------------------------------------ embedding

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        h = jnp.take(params["embed"], tokens, axis=0)
        spec = cfg.embed_pspec or (
            (cfg.act_pspec[0], None, None) if cfg.act_pspec else None
        )
        if spec is not None:
            from jax.sharding import PartitionSpec as P

            h = jax.lax.with_sharding_constraint(h, P(*spec))
        if cfg.frontend is not None and "prefix_embeds" in batch:
            h = jnp.concatenate([batch["prefix_embeds"].astype(h.dtype), h], axis=1)
        s = h.shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(h.shape[0], 0)
        if cfg.pos == "sinusoidal":
            h = h + sinusoidal_embedding(positions, cfg.d_model).astype(h.dtype)
        return h, positions

    def _head(self, params, h):
        cfg = self.cfg
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        return (h.astype(jnp.float32) @ w.astype(jnp.float32)).astype(jnp.float32)

    # ------------------------------------------------------------ forward

    def _constrain(self, x):
        # Megatron-style sequence parallelism on the inter-layer activations:
        # the saved scan carry is sharded (batch x seq), cutting per-device
        # activation memory n_model-fold (see EXPERIMENTS.md SPerf).
        if self.cfg.act_pspec is not None:
            from jax.sharding import PartitionSpec as P

            x = jax.lax.with_sharding_constraint(x, P(*self.cfg.act_pspec))
        return x

    def _run_group(self, gp, bk, mk, x, positions):
        cfg = self.cfg
        n_layers = jax.tree.leaves(gp)[0].shape[0]

        # The stacked layer params are indexed in the body with an explicit
        # int32 carry index instead of riding scan's xs: under
        # jax_enable_x64 the scan machinery's internal loop counter is
        # int64 (lax._const of a Python int), and the XLA SPMD partitioner
        # rejects s64 dynamic_update_slice indices on sharded operands
        # ("compare s64[] vs s32[]") when it transposes the remat scan.
        # With the carry index pinned to int32, every gather the forward
        # emits on the sharded layer stack — and every scatter-add its
        # transpose emits for the layer-stacked cotangents — is s32; scan's
        # own s64 counter only ever touches the replicated aux stack, which
        # the partitioner leaves alone.
        def body(carry, _):
            i, x = carry
            lp = jax.tree.map(
                lambda p: jax.lax.dynamic_index_in_dim(p, i, keepdims=False),
                gp,
            )
            x = self._constrain(x)
            hn = apply_norm(cfg.norm, lp["norm1"], x)
            x = x + BLOCKS[bk]["apply"](cfg, lp["block"], hn, positions)
            aux = jnp.zeros((), jnp.float32)
            if mk != "none":
                hn2 = apply_norm(cfg.norm, lp["norm2"], x)
                if mk == "moe":
                    y, aux = moe_apply(cfg, lp["mlp"], hn2)
                elif mk == "dense_first":
                    y = apply_mlp(
                        cfg.mlp if cfg.mlp != "moe" else "swiglu",
                        lp["mlp"],
                        hn2,
                        cfg.gemm_policy,
                    )
                else:
                    y = apply_mlp(mk, lp["mlp"], hn2, cfg.gemm_policy)
                x = x + y
            return (i + jnp.int32(1), self._constrain(x)), aux

        fn = jax.checkpoint(body) if cfg.remat else body
        (_, x), auxs = jax.lax.scan(
            fn, (jnp.int32(0), x), None, length=n_layers,
            unroll=True if cfg.scan_unroll else 1,
        )
        return x, jnp.sum(auxs)

    def backbone(self, params, batch):
        """Pre-head hidden states. Returns (h, positions, aux_loss)."""
        cfg = self.cfg
        h, positions = self._embed_inputs(params, batch)
        aux_total = jnp.zeros((), jnp.float32)
        for gp, (bk, mk, _) in zip(params["groups"], cfg.layer_groups):
            h, aux = self._run_group(gp, bk, mk, h, positions)
            aux_total = aux_total + aux
        h = apply_norm(cfg.norm, params["final_norm"], h)
        return h, positions, aux_total

    def forward(self, params, batch):
        """Full-sequence logits (training). Returns (logits_f32, aux_loss)."""
        h, _, aux_total = self.backbone(params, batch)
        return self._head(params, h), aux_total

    def _chunked_ce(self, params, h, targets, mask):
        """Cross entropy over vocab slabs — never materializes the
        (B, S, vocab) f32 logits (SPerf: memory-term optimization)."""
        cfg = self.cfg
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        v = w.shape[-1]
        chunk = min(cfg.loss_vocab_chunk, v)
        n_chunks = -(-v // chunk)
        pad = n_chunks * chunk - v
        if pad:
            w = jnp.pad(w, ((0, 0), (0, pad)))
        wc = w.reshape(w.shape[0], n_chunks, chunk).transpose(1, 0, 2)

        # the slab index rides in the carry as int32 and the slab is gathered
        # inside the body: scanning over wc as xs would make jax.lax.scan
        # index it with an s64 counter under jax_enable_x64, and the SPMD
        # partitioner rejects s64 dynamic-slice indices (same fix as the
        # layer-scan in _run_group)
        def body(carry, _):
            i, m, l, gold = carry
            wi = jax.lax.dynamic_index_in_dim(wc, i, keepdims=False)
            logits = (h.astype(jnp.float32) @ wi.astype(jnp.float32))
            base = i * chunk
            idx = jnp.arange(chunk, dtype=jnp.int32)[None, None, :] + base
            logits = jnp.where(idx < v, logits, -1e30)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            l = l * jnp.exp(m - m_new) + jnp.sum(
                jnp.exp(logits - m_new[..., None]), axis=-1
            )
            in_chunk = (targets >= base) & (targets < base + chunk)
            g = jnp.take_along_axis(
                logits, jnp.clip(targets - base, 0, chunk - 1)[..., None], axis=-1
            )[..., 0]
            gold = jnp.where(in_chunk, g, gold)
            return (i + jnp.int32(1), m_new, l, gold), None

        b, s = targets.shape
        init = (
            jnp.int32(0),
            jnp.full((b, s), -1e30, jnp.float32),
            jnp.zeros((b, s), jnp.float32),
            jnp.full((b, s), -1e30, jnp.float32),
        )
        body = jax.checkpoint(body)
        (_, m, l, gold), _ = jax.lax.scan(
            body, init, None, length=n_chunks,
            unroll=True if cfg.scan_unroll else 1,
        )
        logz = m + jnp.log(jnp.maximum(l, 1e-30))
        ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce

    def loss(self, params, batch):
        """Next-token CE over the token region (prefix embeds excluded).

        Targets keep the full sequence length (the final position is masked
        instead of sliced away): odd-sized S-1 slices force uneven tiled
        shardings under SP and crash the XLA scatter partitioner."""
        cfg = self.cfg
        n_prefix = (
            batch["prefix_embeds"].shape[1]
            if (cfg.frontend is not None and "prefix_embeds" in batch)
            else 0
        )
        tokens = batch["tokens"]
        targets = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1
        )
        mask = batch.get(
            "loss_mask", jnp.ones_like(tokens, jnp.float32)
        ).astype(jnp.float32)
        mask = mask * jnp.concatenate(
            [jnp.ones_like(tokens[:, 1:], jnp.float32),
             jnp.zeros_like(tokens[:, :1], jnp.float32)], axis=1
        )
        if cfg.loss_vocab_chunk:
            h, _, aux = self.backbone(params, batch)
            h = h[:, n_prefix:, :]
            ce = self._chunked_ce(params, h, targets, mask)
        else:
            logits, aux = self.forward(params, batch)
            pred = logits[:, n_prefix:, :]
            logz = jax.nn.logsumexp(pred, axis=-1)
            gold = jnp.take_along_axis(pred, targets[..., None], axis=-1)[..., 0]
            ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + 0.01 * aux, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------ serving

    def cache_abstract(self, batch_size: int, cache_len: int) -> list:
        cfg = self.cfg
        return [
            stack_metas(
                BLOCKS[bk]["cache"](cfg, batch_size, cache_len), cnt
            )
            for bk, mk, cnt in cfg.layer_groups
        ]

    def init_cache(self, batch_size: int, cache_len: int) -> list:
        return materialize(
            self.cache_abstract(batch_size, cache_len), jax.random.PRNGKey(0)
        )

    def prefill(self, params, batch, cache):
        """Run the prompt, fill the cache; returns (last-position logits, cache)."""
        cfg = self.cfg
        h, positions = self._embed_inputs(params, batch)
        new_caches = []
        for gp, gc, (bk, mk, _) in zip(
            params["groups"], cache, cfg.layer_groups
        ):
            def body(carry, xs, bk=bk, mk=mk):
                x = carry
                lp, lc = xs
                hn = apply_norm(cfg.norm, lp["norm1"], x)
                y, nc = BLOCKS[bk]["prefill"](cfg, lp["block"], hn, positions, lc)
                x = x + y
                x = self._apply_mlp_serve(lp, mk, x)
                return x, nc

            h, nc = jax.lax.scan(
                body, h, (gp, gc), unroll=True if cfg.scan_unroll else 1
            )
            new_caches.append(nc)
        h = apply_norm(cfg.norm, params["final_norm"], h)
        return self._head(params, h[:, -1:, :]), new_caches

    def decode_step(self, params, token, cache, pos):
        """One decode step. token: (B, 1) int32; pos: scalar int32 position."""
        cfg = self.cfg
        h = jnp.take(params["embed"], token, axis=0)
        if cfg.pos == "sinusoidal":
            p1 = jnp.full((1, 1), pos, jnp.int32)
            h = h + sinusoidal_embedding(p1, cfg.d_model).astype(h.dtype)
        new_caches = []
        for gp, gc, (bk, mk, _) in zip(params["groups"], cache, cfg.layer_groups):
            def body(carry, xs, bk=bk, mk=mk):
                x = carry
                lp, lc = xs
                hn = apply_norm(cfg.norm, lp["norm1"], x)
                y, nc = BLOCKS[bk]["decode"](cfg, lp["block"], hn, lc, pos)
                x = x + y
                x = self._apply_mlp_serve(lp, mk, x)
                return x, nc

            h, nc = jax.lax.scan(
                body, h, (gp, gc), unroll=True if cfg.scan_unroll else 1
            )
            new_caches.append(nc)
        h = apply_norm(cfg.norm, params["final_norm"], h)
        return self._head(params, h), new_caches

    def _apply_mlp_serve(self, lp, mk, x):
        cfg = self.cfg
        if mk == "none":
            return x
        hn2 = apply_norm(cfg.norm, lp["norm2"], x)
        if mk == "moe":
            y, _ = moe_apply(cfg, lp["mlp"], hn2)
        elif mk == "dense_first":
            y = apply_mlp(
                cfg.mlp if cfg.mlp != "moe" else "swiglu", lp["mlp"], hn2,
                cfg.gemm_policy,
            )
        else:
            y = apply_mlp(mk, lp["mlp"], hn2, cfg.gemm_policy)
        return x + y
