"""Per-layer blocks: GQA attention, Mamba2 SSD, RG-LRU, MoE MLP.

Uniform interface per block kind:
  abstract(cfg)                      -> ParamMeta tree
  apply(cfg, p, x, positions)        -> y                     (full sequence)
  cache_abstract(cfg, b, cache_len)  -> ParamMeta tree        (decode cache)
  prefill(cfg, p, x, positions, cache) -> (y, cache)
  decode(cfg, p, x, cache, pos)      -> (y, cache)            (x: (B, 1, d))
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    AttnSpec,
    apply_linear,
    apply_mlp,
    apply_rope,
    attention,
    linear_abstract,
    mlp_abstract,
)
from .params import ParamMeta

_NEG_POS = jnp.int32(2**30)  # sentinel "future" position for empty cache slots


# =================================================================== attention


def attn_abstract(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.dtype
    return {
        "q": linear_abstract(d, h * hd, ("embed", "qkv"), dt, cfg.qkv_bias),
        "k": linear_abstract(d, kv * hd, ("embed", "kv_qkv"), dt, cfg.qkv_bias),
        "v": linear_abstract(d, kv * hd, ("embed", "kv_qkv"), dt, cfg.qkv_bias),
        "o": linear_abstract(h * hd, d, ("qkv", "embed"), dt),
    }


def _qkv(cfg: ModelConfig, p, x, positions):
    b, s, _ = x.shape
    q = apply_linear(p["q"], x, cfg.gemm_policy).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = apply_linear(p["k"], x, cfg.gemm_policy).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = apply_linear(p["v"], x, cfg.gemm_policy).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_pct, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_pct, cfg.rope_theta)
    return q, k, v


def _spec(cfg: ModelConfig, kv_chunk=None) -> AttnSpec:
    return AttnSpec(
        causal=True,
        window=cfg.window,
        softcap=cfg.attn_logit_softcap,
        kv_chunk=kv_chunk if kv_chunk is not None else cfg.kv_chunk,
    )


def attn_apply(cfg: ModelConfig, p, x, positions):
    q, k, v = _qkv(cfg, p, x, positions)
    pos1 = positions[0] if positions.ndim > 1 else positions
    out = attention(q, k, v, _spec(cfg), pos1, pos1)
    b, s, _, _ = q.shape
    return apply_linear(p["o"], out.reshape(b, s, -1), cfg.gemm_policy)


def attn_cache_len(cfg: ModelConfig, max_len: int) -> int:
    # windowed layers only ever need `window` slots (ring buffer) — this is
    # what makes long_500k decoding feasible for recurrentgemma.
    return min(max_len, cfg.window) if cfg.window else max_len


def attn_cache_abstract(cfg: ModelConfig, b: int, cache_len: int) -> dict:
    c = attn_cache_len(cfg, cache_len)
    kvshape = (b, c, cfg.n_kv_heads, cfg.head_dim)
    axes = ("batch", "kv_seq", "kv_heads", None)
    return {
        "k": ParamMeta(kvshape, axes, cfg.dtype, "zeros"),
        "v": ParamMeta(kvshape, axes, cfg.dtype, "zeros"),
        "pos": ParamMeta((c,), (None,), jnp.int32, "future_pos"),
    }


def attn_prefill(cfg: ModelConfig, p, x, positions, cache):
    q, k, v = _qkv(cfg, p, x, positions)
    pos1 = positions[0] if positions.ndim > 1 else positions
    out = attention(q, k, v, _spec(cfg), pos1, pos1)
    b, s, _, _ = q.shape
    c = cache["k"].shape[1]
    if s >= c:  # keep the last c tokens, slot = pos % c (ring layout)
        ktail, vtail, ptail = k[:, -c:], v[:, -c:], pos1[-c:]
        slot = ptail % c
        new_k = jnp.zeros_like(cache["k"]).at[:, slot].set(ktail)
        new_v = jnp.zeros_like(cache["v"]).at[:, slot].set(vtail)
        new_pos = (jnp.zeros_like(cache["pos"]) + _NEG_POS).at[slot].set(ptail)
    else:
        slot = pos1 % c
        new_k = cache["k"].at[:, slot].set(k)
        new_v = cache["v"].at[:, slot].set(v)
        new_pos = cache["pos"].at[slot].set(pos1)
    cache = {"k": new_k, "v": new_v, "pos": new_pos}
    y = apply_linear(p["o"], out.reshape(b, s, -1), cfg.gemm_policy)
    return y, cache


def attn_decode(cfg: ModelConfig, p, x, cache, pos):
    b = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _qkv(cfg, p, x, positions)
    c = cache["k"].shape[1]
    slot = (pos % c).astype(jnp.int32)
    zero = jnp.int32(0)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (zero, slot, zero, zero))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (zero, slot, zero, zero))
    cpos = jax.lax.dynamic_update_slice(cache["pos"], positions, (slot,))
    out = attention(
        q, ck, cv, _spec(cfg, kv_chunk=c), positions, cpos, kv_valid=cpos <= pos
    )
    y = apply_linear(p["o"], out.reshape(b, 1, -1), cfg.gemm_policy)
    return y, {"k": ck, "v": cv, "pos": cpos}


# =================================================================== mamba2 SSD


def ssd_abstract(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    h = cfg.ssm_heads
    conv_ch = di + 2 * gn
    dt = cfg.dtype
    return {
        "in_proj": linear_abstract(d, 2 * di + 2 * gn + h, ("embed", "ssm_inner"), dt),
        "conv_w": ParamMeta((cfg.conv_width, conv_ch), (None, "ssm_inner"), dt),
        "conv_b": ParamMeta((conv_ch,), ("ssm_inner",), dt, "zeros"),
        "dt_bias": ParamMeta((h,), (None,), jnp.float32, "zeros"),
        "a_log": ParamMeta((h,), (None,), jnp.float32, "zeros"),
        "d_skip": ParamMeta((h,), (None,), jnp.float32, "ones"),
        "norm": ParamMeta((di,), ("ssm_inner",), dt, "ones"),
        "out_proj": linear_abstract(di, d, ("ssm_inner", "embed"), dt),
    }


def _segsum(x):
    """(..., Q) -> (..., Q, Q): sum_{k=j+1..i} x_k for i >= j else -inf."""
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    q = x.shape[-1]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan(xbar, a_dt, bmat, cmat, init_state=None, chunk=128):
    """Chunked state-space-duality scan (Mamba-2, alg. 'SSD').

    xbar: (B,S,H,P) dt-weighted inputs; a_dt: (B,S,H) log-decays;
    bmat/cmat: (B,S,N) (single group).  Returns y (B,S,H,P), final_state
    (B,H,P,N).  All f32.
    """
    b, s, h, p = xbar.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    xc = xbar.reshape(b, nc, chunk, h, p)
    ac = a_dt.reshape(b, nc, chunk, h)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)

    acs = jnp.cumsum(ac, axis=2)  # (B,Nc,Q,H) inclusive
    # intra-chunk (diagonal blocks)
    l_mat = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))  # (B,Nc,H,Q,Q)
    g_mat = jnp.einsum("bcin,bcjn->bcij", cc, bc)
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp", g_mat, l_mat, xc)
    # per-chunk end states.  NB: slice-then-squeeze, not `acs[:, :, -1, :]`
    # — a negative *integer* index lowers to a dynamic_slice whose
    # normalized index scalars are s64 under x64, inside the remat layer
    # scan (the SPMD partitioner bug class ScanIndexWidthPass flags).
    a_last = acs[:, :, -1:, :]  # (B,Nc,1,H) static slice
    decay_states = jnp.exp(a_last - acs)  # (B,Nc,Q,H)
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_states, bc, xc)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.squeeze(a_last, 2))  # (B,Nc,H)

    def body(carry, xs):
        st, gamma = xs
        new = carry * gamma[:, :, None, None] + st
        return new, carry  # emit state *before* this chunk

    init = (
        jnp.zeros((b, h, p, n), xbar.dtype) if init_state is None else init_state
    )
    final_state, prev_states = jax.lax.scan(
        body, init, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    prev_states = prev_states.swapaxes(0, 1)  # (B,Nc,H,P,N)
    y_off = jnp.einsum("bcin,bchpn,bcih->bcihp", cc, prev_states, jnp.exp(acs))
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def _causal_conv(x, w, b, carry=None):
    """Depthwise causal conv along seq. x: (B,S,C); w: (W,C). carry: (B,W-1,C)."""
    width = w.shape[0]
    pad = (
        jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
        if carry is None
        else carry
    )
    xp = jnp.concatenate([pad, x], axis=1).astype(jnp.float32)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(jnp.float32) for i in range(width)
    )
    new_carry = xp[:, -(width - 1) :].astype(x.dtype) if width > 1 else pad
    return (out + b.astype(jnp.float32)).astype(x.dtype), new_carry


def _ssd_inner(cfg: ModelConfig, p, x, conv_carry, state, chunk=128):
    b, s, _ = x.shape
    di, gn, h = cfg.d_inner, cfg.ssm_ngroups * cfg.ssm_state, cfg.ssm_heads
    zxbcdt = apply_linear(p["in_proj"], x, cfg.gemm_policy)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * gn], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_carry)
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xin, bmat, cmat = jnp.split(xbc, [di, di + gn], axis=-1)
    n = cfg.ssm_state
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])  # (H,)
    xh = xin.reshape(b, s, h, cfg.ssm_headdim)
    y, final_state = ssd_scan(
        xh * dt[..., None], dt * a, bmat[..., :n], cmat[..., :n], state, chunk
    )
    y = y + p["d_skip"][:, None] * xh
    y = y.reshape(b, s, di)
    # gated RMSNorm (mamba2): norm(y * silu(z)) * scale
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = (y * p["norm"].astype(jnp.float32)).astype(x.dtype)
    return apply_linear(p["out_proj"], y, cfg.gemm_policy), new_conv, final_state


def ssd_apply(cfg: ModelConfig, p, x, positions):
    y, _, _ = _ssd_inner(cfg, p, x, None, None)
    return y


def ssd_cache_abstract(cfg: ModelConfig, b: int, cache_len: int) -> dict:
    di, gn = cfg.d_inner, cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": ParamMeta(
            (b, cfg.conv_width - 1, di + 2 * gn), ("batch", None, "ssm_inner"),
            cfg.dtype, "zeros",
        ),
        "state": ParamMeta(
            (b, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
            ("batch", None, None, None), jnp.float32, "zeros",
        ),
    }


def ssd_prefill(cfg: ModelConfig, p, x, positions, cache):
    y, conv, state = _ssd_inner(cfg, p, x, cache["conv"] * 0, cache["state"] * 0)
    return y, {"conv": conv, "state": state}


def ssd_decode(cfg: ModelConfig, p, x, cache, pos):
    b = x.shape[0]
    di, gn, h, n = (
        cfg.d_inner,
        cfg.ssm_ngroups * cfg.ssm_state,
        cfg.ssm_heads,
        cfg.ssm_state,
    )
    zxbcdt = apply_linear(p["in_proj"], x, cfg.gemm_policy)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * gn], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], cache["conv"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32))[:, 0]  # (B, C)
    xin, bmat, cmat = jnp.split(xbc, [di, di + gn], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)[:, 0] + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)  # (B,H)
    xh = xin.reshape(b, h, cfg.ssm_headdim)
    state = cache["state"] * da[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, bmat[..., :n]
    )
    y = jnp.einsum("bhpn,bn->bhp", state, cmat[..., :n])
    y = y + p["d_skip"][:, None] * xh
    y = y.reshape(b, 1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    y = (y * p["norm"].astype(jnp.float32)).astype(x.dtype)
    return apply_linear(p["out_proj"], y, cfg.gemm_policy), {
        "conv": new_conv,
        "state": state,
    }


# =================================================================== rg-lru


def rglru_abstract(cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    dt = cfg.dtype
    return {
        "in_x": linear_abstract(d, w, ("embed", "ssm_inner"), dt),
        "in_gate": linear_abstract(d, w, ("embed", "ssm_inner"), dt),
        "conv_w": ParamMeta((cfg.conv_width, w), (None, "ssm_inner"), dt),
        "conv_b": ParamMeta((w,), ("ssm_inner",), dt, "zeros"),
        "w_a": linear_abstract(w, w, ("ssm_inner", None), dt),
        "w_x": linear_abstract(w, w, ("ssm_inner", None), dt),
        "lam": ParamMeta((w,), (None,), jnp.float32, "ones"),
        "out": linear_abstract(w, d, ("ssm_inner", "embed"), dt),
    }


_LRU_C = 8.0


def _rglru_gates(cfg, p, xc):
    r = jax.nn.sigmoid(apply_linear(p["w_a"], xc, cfg.gemm_policy).astype(jnp.float32))
    i = jax.nn.sigmoid(apply_linear(p["w_x"], xc, cfg.gemm_policy).astype(jnp.float32))
    # log a_t = -c * r_t * softplus(lam)  (a = sigmoid(lam)^(c r) in griffin)
    log_a = -_LRU_C * r * jax.nn.softplus(p["lam"])
    a = jnp.exp(log_a)
    gated_x = i * xc.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    return a, b


def _rglru_apply_seq(cfg, p, xc, h0=None):
    """Linear recurrence h_t = a_t h_{t-1} + b_t via associative scan."""
    a, b = _rglru_gates(cfg, p, xc)  # (B,S,W) each
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h  # (B,S,W) f32


def rglru_apply(cfg: ModelConfig, p, x, positions):
    gate = jax.nn.gelu(
        apply_linear(p["in_gate"], x, cfg.gemm_policy).astype(jnp.float32)
    )
    xb = apply_linear(p["in_x"], x, cfg.gemm_policy)
    xc, _ = _causal_conv(xb, p["conv_w"], p["conv_b"])
    h = _rglru_apply_seq(cfg, p, xc)
    y = (h * gate).astype(x.dtype)
    return apply_linear(p["out"], y, cfg.gemm_policy)


def rglru_cache_abstract(cfg: ModelConfig, b: int, cache_len: int) -> dict:
    w = cfg.lru_width
    return {
        "conv": ParamMeta(
            (b, cfg.conv_width - 1, w), ("batch", None, "ssm_inner"), cfg.dtype, "zeros"
        ),
        "h": ParamMeta((b, w), ("batch", "ssm_inner"), jnp.float32, "zeros"),
    }


def rglru_prefill(cfg: ModelConfig, p, x, positions, cache):
    gate = jax.nn.gelu(
        apply_linear(p["in_gate"], x, cfg.gemm_policy).astype(jnp.float32)
    )
    xb = apply_linear(p["in_x"], x, cfg.gemm_policy)
    xc, conv = _causal_conv(xb, p["conv_w"], p["conv_b"], cache["conv"] * 0)
    h = _rglru_apply_seq(cfg, p, xc)
    y = (h * gate).astype(x.dtype)
    out = apply_linear(p["out"], y, cfg.gemm_policy)
    # slice-then-squeeze: `h[:, -1]` would emit an s64 dynamic_slice inside
    # the prefill layer scan (ScanIndexWidthPass bug class)
    return out, {"conv": conv, "h": jnp.squeeze(h[:, -1:], 1)}


def rglru_decode(cfg: ModelConfig, p, x, cache, pos):
    gate = jax.nn.gelu(
        apply_linear(p["in_gate"], x, cfg.gemm_policy).astype(jnp.float32)
    )
    xb = apply_linear(p["in_x"], x, cfg.gemm_policy)
    xc, conv = _causal_conv(xb, p["conv_w"], p["conv_b"], cache["conv"])
    a, b = _rglru_gates(cfg, p, xc[:, 0])
    h = a * cache["h"] + b
    y = (h[:, None] * gate).astype(x.dtype)
    out = apply_linear(p["out"], y, cfg.gemm_policy)
    return out, {"conv": conv, "h": h}


# =================================================================== moe


def moe_abstract(cfg: ModelConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    dt = cfg.dtype
    out = {
        "router": ParamMeta((d, e), ("embed", "experts"), jnp.float32),
        "gate": ParamMeta((e, d, ff), ("experts", "embed", "ff"), dt),
        "up": ParamMeta((e, d, ff), ("experts", "embed", "ff"), dt),
        "down": ParamMeta((e, ff, d), ("experts", "ff", "embed"), dt),
    }
    if cfg.moe_shared:
        out["shared"] = mlp_abstract("swiglu", d, ff * cfg.moe_shared, dt)
    return out


def _moe_group(cfg: ModelConfig, p, xg):
    """GShard-style top-k dispatch for one token group. xg: (T, d)."""
    t, d = xg.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    cap = max(k, int(math.ceil(cfg.moe_capacity_factor * t * k / e)))
    logits = (xg.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    topv, topi = jax.lax.top_k(probs, k)  # (T, K)
    topv = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)  # (T, K, E)
    # slot position of each (token, k) inside its expert queue
    pos_in_e = jnp.cumsum(onehot.reshape(t * k, e), axis=0).reshape(t, k, e) - 1.0
    slot_idx = jnp.sum(pos_in_e * onehot, axis=-1)  # (T, K)
    # one_hot of indices >= cap is all-zero => capacity overflow tokens drop
    oh_slot = jax.nn.one_hot(slot_idx.astype(jnp.int32), cap, dtype=jnp.float32)
    # batched per-token (K,E)^T @ (K,C): no (T,K,E,C) intermediate
    combine = jnp.einsum("tke,tkc->tec", onehot * topv[..., None], oh_slot)
    dispatch = (combine > 0).astype(cfg.dtype)  # (T, E, C)
    xe = jnp.einsum("td,tec->ecd", xg.astype(cfg.dtype), dispatch)  # (E, C, d)
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["gate"]).astype(jnp.float32))
    up = jnp.einsum("ecd,edf->ecf", xe, p["up"]).astype(jnp.float32)
    ye = jnp.einsum("ecf,efd->ecd", (gate * up).astype(cfg.dtype), p["down"])
    out = jnp.einsum("ecd,tec->td", ye.astype(jnp.float32), combine)
    # load-balance aux loss (Switch): E * mean(frac_tokens * mean_prob)
    frac = jnp.mean(onehot[:, 0, :], axis=0)
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))
    return out.astype(xg.dtype), aux


def moe_apply(cfg: ModelConfig, p, x, group_size: int | None = None):
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    group_size = group_size or cfg.moe_group_size
    g = max(1, t // min(group_size, t))
    if t % g:
        g = 1
    grouped = tokens.reshape(g, t // g, d)

    if cfg.moe_dispatch_pspec is not None:
        # EP layout (SPerf): groups batched + sharded over the data axes, so
        # top-k dispatch is data-local; only the expert combine crosses the
        # 'model' (expert) axis.  The sequential scan below would otherwise
        # process one (single-shard) group at a time.
        from jax.sharding import PartitionSpec as P

        gspec = P(cfg.moe_dispatch_pspec[0], None, None)
        grouped = jax.lax.with_sharding_constraint(grouped, gspec)
        ys, auxs = jax.vmap(lambda xg: _moe_group(cfg, p, xg))(grouped)
        ys = jax.lax.with_sharding_constraint(ys, gspec)
        y = ys.reshape(b, s, d)
    else:
        def body(_, xg):
            yg, aux = _moe_group(cfg, p, xg)
            return None, (yg, aux)

        _, (ys, auxs) = jax.lax.scan(body, None, grouped)
        y = ys.reshape(b, s, d)
    if cfg.moe_shared:
        y = y + apply_mlp("swiglu", p["shared"], x, cfg.gemm_policy)
    return y, jnp.mean(auxs)


BLOCKS = {
    "attn": {
        "abstract": attn_abstract,
        "apply": attn_apply,
        "cache": attn_cache_abstract,
        "prefill": attn_prefill,
        "decode": attn_decode,
    },
    "ssd": {
        "abstract": ssd_abstract,
        "apply": ssd_apply,
        "cache": ssd_cache_abstract,
        "prefill": ssd_prefill,
        "decode": ssd_decode,
    },
    "rglru": {
        "abstract": rglru_abstract,
        "apply": rglru_apply,
        "cache": rglru_cache_abstract,
        "prefill": rglru_prefill,
        "decode": rglru_decode,
    },
}
