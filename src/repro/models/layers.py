"""Shared neural-net building blocks (pure JAX, pytree params).

All matmuls route through the one drop-in entry point `repro.linalg.matmul`
under the config's `GemmPolicy`, so any layer can run on the Ozaki-II
emulated GEMM backends — reference or Pallas-kernel execution — exactly as
user code does (the paper's technique as a first-class framework feature).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .. import linalg
from ..core.policy import GemmPolicy
from .params import ParamMeta

# ---------------------------------------------------------------- norms


def norm_abstract(kind: str, d: int, dtype) -> dict:
    out = {"scale": ParamMeta((d,), ("embed",), dtype, "ones")}
    if kind == "layernorm":
        out["bias"] = ParamMeta((d,), ("embed",), dtype, "zeros")
    return out


def apply_norm(kind: str, p: dict, x: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mean) ** 2, axis=-1, keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    out = xf * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- linear


def linear_abstract(d_in, d_out, axes, dtype, bias=False, scale=None) -> dict:
    out = {"w": ParamMeta((d_in, d_out), axes, dtype, "normal", scale)}
    if bias:
        out["b"] = ParamMeta((d_out,), (axes[1],), dtype, "zeros")
    return out


def apply_linear(p: dict, x: jnp.ndarray, policy: GemmPolicy) -> jnp.ndarray:
    """p["w"] may be a raw (k, n) array or a right-side `PreparedOperand`
    (weights residue-cast once by `core.policy.prepare_weights` — the
    weight-stationary serving fast path); `linalg.matmul` handles both."""
    y = linalg.matmul(x, p["w"], policy=policy)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------- rope


def rope_frequencies(head_dim: int, pct: float, theta: float) -> jnp.ndarray:
    rot = int(head_dim * pct) // 2 * 2
    return 1.0 / theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, pct: float, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    rot = int(d * pct) // 2 * 2
    freqs = rope_frequencies(d, pct, theta)  # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., : rot // 2].astype(jnp.float32)
    x2 = x[..., rot // 2 : rot].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)
    if rot < d:
        out = jnp.concatenate([out, x[..., rot:]], axis=-1)
    return out


def sinusoidal_embedding(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------- attention


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    causal: bool = True
    window: int | None = None
    softcap: float | None = None
    kv_chunk: int = 1024


def _apply_logit_mods(logits, spec: AttnSpec, q_pos, kv_pos, kv_valid=None):
    if spec.softcap:
        logits = spec.softcap * jnp.tanh(logits / spec.softcap)
    mask = jnp.ones(logits.shape[-2:], dtype=bool)
    if spec.causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if spec.window is not None:
        mask &= q_pos[:, None] - kv_pos[None, :] < spec.window
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    return jnp.where(mask, logits, -1e30)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    spec: AttnSpec,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    kv_valid: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Blockwise (flash-semantics) GQA attention in pure JAX.

    q: (B, Sq, H, D);  k, v: (B, Skv, KV, D);  H = KV * G.
    Online-softmax scan over KV chunks keeps activations O(Sq * kv_chunk),
    which is what makes the 32k-prefill shapes compile at scale.
    """
    b, sq, h, d = q.shape
    _, skv, kv, _ = k.shape
    g = h // kv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, sq, kv, g, d).astype(jnp.float32) * scale

    chunk = min(spec.kv_chunk, skv)
    if skv % chunk:
        chunk = skv  # fall back to one block for ragged sizes
    nblk = skv // chunk
    kc = k.reshape(b, nblk, chunk, kv, d)
    vc = v.reshape(b, nblk, chunk, kv, d)
    pc = kv_pos.reshape(nblk, chunk)
    valc = None if kv_valid is None else kv_valid.reshape(nblk, chunk)

    def body(carry, xs):
        m, l, acc = carry
        if valc is None:
            kb, vb, pb = xs
            vab = None
        else:
            kb, vb, pb, vab = xs
        logits = jnp.einsum("bskgd,btkd->bkgst", qg, kb.astype(jnp.float32))
        logits = _apply_logit_mods(logits, spec, q_pos, pb, vab)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vb.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, kv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, d), jnp.float32)
    xs = (
        kc.swapaxes(0, 1),
        vc.swapaxes(0, 1),
        pc,
    ) + (() if valc is None else (valc,))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------- mlps


def mlp_abstract(cfg_mlp: str, d: int, ff: int, dtype) -> dict:
    if cfg_mlp in ("swiglu", "geglu"):
        return {
            "gate": linear_abstract(d, ff, ("embed", "ff"), dtype),
            "up": linear_abstract(d, ff, ("embed", "ff"), dtype),
            "down": linear_abstract(ff, d, ("ff", "embed"), dtype),
        }
    return {
        "up": linear_abstract(d, ff, ("embed", "ff"), dtype),
        "down": linear_abstract(ff, d, ("ff", "embed"), dtype),
    }


def apply_mlp(cfg_mlp: str, p: dict, x: jnp.ndarray, policy: GemmPolicy):
    if cfg_mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg_mlp == "swiglu" else jax.nn.gelu
        g = act(apply_linear(p["gate"], x, policy))
        u = apply_linear(p["up"], x, policy)
        return apply_linear(p["down"], g * u, policy)
    h = apply_linear(p["up"], x, policy)
    if cfg_mlp == "gelu":
        h = jax.nn.gelu(h)
    elif cfg_mlp == "sq_relu":  # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(f"unknown mlp {cfg_mlp!r}")
    return apply_linear(p["down"], h, policy)
