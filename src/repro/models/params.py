"""Abstract parameter metadata -> init + sharding specs.

Every layer describes its parameters once as a pytree of `ParamMeta`
(shape, dtype, logical axis names).  From that single description we derive:
  * materialized random inits (deterministic per tree path),
  * `PartitionSpec`s via the logical-axis rules in `repro.distributed.sharding`,
  * `ShapeDtypeStruct`s for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]        # logical axis names, len == ndim
    dtype: Any = jnp.bfloat16
    init: str = "normal"                # 'normal' | 'zeros' | 'ones' | custom
    scale: float | None = None          # stddev; default fan-in

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} do not match shape {self.shape}")


def _fan_in_scale(shape: tuple[int, ...]) -> float:
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    return float(1.0 / np.sqrt(max(fan_in, 1)))


def _const(shape, dtype, value) -> jnp.ndarray:
    """Constant leaf with a guaranteed-fresh device buffer.

    Eager jnp constants (zeros/ones of equal shape+dtype) share one
    executable-owned buffer, which breaks train-step donation ("donate the
    same buffer twice").  device_put of a distinct host array always
    allocates."""
    return jnp.asarray(np.full(shape, value, dtype=np.dtype(jnp.dtype(dtype))))


def _init_one(meta: ParamMeta, key: jax.Array) -> jnp.ndarray:
    if meta.init == "zeros":
        return _const(meta.shape, meta.dtype, 0)
    if meta.init == "ones":
        return _const(meta.shape, meta.dtype, 1)
    if meta.init == "future_pos":  # KV-cache position sentinel (masked slot)
        return _const(meta.shape, meta.dtype, 2**30)
    scale = meta.scale if meta.scale is not None else _fan_in_scale(meta.shape)
    return (jax.random.normal(key, meta.shape, jnp.float32) * scale).astype(meta.dtype)


def _iter_leaves(tree, path=()):
    if isinstance(tree, ParamMeta):
        yield path, tree
    elif isinstance(tree, dict):
        for k in sorted(tree):
            yield from _iter_leaves(tree[k], path + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _iter_leaves(v, path + (str(i),))
    else:
        raise TypeError(f"unexpected node {type(tree)} at {path}")


def _map_like(tree, fn, path=()):
    if isinstance(tree, ParamMeta):
        return fn(path, tree)
    if isinstance(tree, dict):
        return {k: _map_like(v, fn, path + (k,)) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(
            _map_like(v, fn, path + (str(i),)) for i, v in enumerate(tree)
        )
    raise TypeError(f"unexpected node {type(tree)} at {path}")


def materialize(abstract: Any, key: jax.Array) -> Any:
    """Deterministic init: each leaf gets fold_in(key, hash(path))."""

    def init(path, meta):
        k = key
        for part in path:
            k = jax.random.fold_in(k, abs(hash(part)) % (2**31))
        return _init_one(meta, k)

    return _map_like(abstract, init)


def abstract_arrays(abstract: Any) -> Any:
    """ShapeDtypeStructs for .lower() (dry-run: no allocation)."""
    return _map_like(
        abstract, lambda _, m: jax.ShapeDtypeStruct(m.shape, jnp.dtype(m.dtype))
    )


def logical_axes(abstract: Any) -> Any:
    return _map_like(abstract, lambda _, m: m.axes)


def stack_metas(meta_tree: Any, n: int) -> Any:
    """Add a leading 'layers' axis to every leaf (scan-over-layers stacking)."""
    return _map_like(
        meta_tree,
        lambda _, m: ParamMeta(
            (n,) + m.shape, ("layers",) + m.axes, m.dtype, m.init, m.scale
        ),
    )


def param_bytes(abstract: Any) -> int:
    return sum(
        int(np.prod(m.shape)) * jnp.dtype(m.dtype).itemsize
        for _, m in _iter_leaves(abstract)
    )
