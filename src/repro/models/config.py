"""Model configuration — one dataclass covers all 10 assigned architectures.

Per-layer block types are selected by `block_pattern` (cycled over layers):
  'attn'  — GQA attention block (optionally windowed)
  'ssd'   — Mamba2 state-space-duality block
  'rglru' — RecurrentGemma RG-LRU recurrent block
MLP variants: 'swiglu' | 'gelu' | 'sq_relu' | 'geglu' | 'moe'.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from ..core.policy import GemmPolicy


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    window: int | None = None          # local attention window (tokens)
    attn_logit_softcap: float | None = None
    # position encoding: 'rope' | 'sinusoidal' | 'none'
    pos: str = "rope"
    rope_pct: float = 1.0
    rope_theta: float = 10000.0
    # mlp
    d_ff: int = 0
    mlp: str = "swiglu"
    norm: str = "rmsnorm"              # 'rmsnorm' | 'layernorm'
    block_pattern: Tuple[str, ...] = ("attn",)
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    conv_width: int = 4
    # rg-lru (recurrentgemma)
    lru_width: int = 0
    # moe
    moe_experts: int = 0
    moe_topk: int = 0
    moe_shared: int = 0                # always-on shared experts (deepseek)
    moe_capacity_factor: float = 1.25
    first_dense_ff: int = 0            # dense FFN in layer 0 (deepseek)
    # modality frontend stubs (DESIGN.md S5)
    frontend: str | None = None        # 'vision' | 'audio' | None
    n_prefix_embeds: int = 0           # precomputed patch/conditioning embeds
    # misc
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Matmul policy for every linear in the model.  None (the default)
    # resolves to the ambient `repro.use_policy` scope *at config
    # construction* — so `with use_policy(p): cfg = get_config(...)` pins p
    # into the (hashable, jit-static) config and the whole model runs on
    # p's backend/execution; with no active scope it resolves to the native
    # policy.  An explicit GemmPolicy always wins over the ambient scope.
    gemm_policy: GemmPolicy | None = None
    # remat policy for scan-over-layers training
    remat: bool = True
    # sequence parallelism: PartitionSpec (as a static tuple) constraining the
    # inter-layer activations (B, S, d), e.g. (("pod","data"), "model", None).
    # None disables SP (baseline).  Set by the launcher per mesh.
    act_pspec: tuple | None = None
    # pin the embedding-lookup output sharding (B, S, d).  Keeps the
    # embedding-gradient scatter in a partitioner-friendly layout when SP or
    # emulated-GEMM backends reshuffle propagation (XLA SPMD HandleScatter
    # CHECK-crashes otherwise; see EXPERIMENTS.md SPerf).
    embed_pspec: tuple | None = None
    # attention KV-chunk (online-softmax block) and MoE dispatch group sizes
    kv_chunk: int = 1024
    moe_group_size: int = 2048
    # EP dispatch layout: None = sequential scan over token groups (memory-
    # lean single-host baseline).  A tuple (e.g. (("pod","data"),)) switches
    # to batched groups sharded over those axes: dispatch becomes data-local
    # and only the combine all-reduce crosses the model axis (SPerf).
    moe_dispatch_pspec: tuple | None = None
    # cost-mode: fully unroll the layer scans so XLA cost_analysis counts
    # every layer (while bodies are otherwise counted once). Used only by the
    # dry-run's flop-accounting lowering — never for real execution.
    scan_unroll: bool = False
    # chunked-vocab cross entropy: compute logits/logsumexp over vocab slabs
    # of this size to avoid materializing (B, S, vocab) f32 (SPerf).
    loss_vocab_chunk: int | None = None

    def __post_init__(self):
        if self.gemm_policy is None:
            from ..linalg import current_policy

            # frozen dataclass: resolve the ambient policy in place (runs
            # again on dataclasses.replace, so replace(cfg, gemm_policy=None)
            # re-reads the scope while plain replace keeps the pinned value)
            object.__setattr__(self, "gemm_policy", current_policy())

    @property
    def d_inner(self) -> int:          # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    def mlp_kind(self, layer: int) -> str:
        if self.mlp == "moe":
            return "dense_first" if (layer == 0 and self.first_dense_ff) else "moe"
        if self.d_ff == 0:
            return "none"
        return self.mlp

    @property
    def layer_groups(self) -> Tuple[Tuple[str, str, int], ...]:
        """Consecutive (block_kind, mlp_kind, count) runs, scanned
        homogeneously (stacked params + lax.scan per group)."""
        kinds = [
            (self.block_kind(i), self.mlp_kind(i)) for i in range(self.n_layers)
        ]
        groups: list[list] = []
        for bk, mk in kinds:
            if groups and groups[-1][0] == bk and groups[-1][1] == mk:
                groups[-1][2] += 1
            else:
                groups.append([bk, mk, 1])
        return tuple(tuple(g) for g in groups)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d = self.d_model
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind == "attn":
                total += d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
                total += self.n_heads * self.head_dim * d
            elif kind == "ssd":
                di, ng, ns = self.d_inner, self.ssm_ngroups, self.ssm_state
                total += d * (2 * di + 2 * ng * ns + self.ssm_heads) + di * d
            elif kind == "rglru":
                w = self.lru_width
                total += 2 * d * w + w * d + 3 * w * w // w  # proj + gates
            total += self._mlp_params(i)
        return total

    def _mlp_params(self, layer: int) -> int:
        d = self.d_model
        if self.mlp == "moe" and not (layer == 0 and self.first_dense_ff):
            e = self.moe_experts
            per = 3 * d * self.d_ff
            shared = 3 * d * self.d_ff * self.moe_shared
            return e * per + shared + d * e  # + router
        ff = self.first_dense_ff if (layer == 0 and self.first_dense_ff) else self.d_ff
        mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        return mult * d * ff

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if self.mlp != "moe":
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        inactive = (self.moe_experts - self.moe_topk) * 3 * d * self.d_ff
        n_moe_layers = self.n_layers - (1 if self.first_dense_ff else 0)
        return total - inactive * n_moe_layers
