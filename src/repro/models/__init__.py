"""Model substrate: configs, blocks, and the assembled decoder Model."""
from .config import ModelConfig
from .transformer import Model

__all__ = ["Model", "ModelConfig"]
