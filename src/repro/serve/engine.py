"""Batched serving engine: jitted prefill + single-token decode steps.

The decode step is the unit the `decode_*`/`long_*` dry-run shapes lower:
one new token against a KV/state cache of the configured length.

With an emulated (Ozaki-II) GEMM policy, `prepare=True` residue-casts
every linear weight once at engine construction (`core.policy.prepare_weights`,
which casts with the policy's *selected execution backend*, so prepared
serving stays bit-identical on the Pallas kernel path too): step 1 of the
scheme for the weight side — scaling, truncation and the N int8 residue
planes — is amortized across all subsequent requests, and each call pays
only the activation-side cast.  Bit-identical to the unprepared fast-mode
path.

`prepared_dir` persists that one-time work across restarts: the first
construction saves the prepared residue planes through the checkpointer and
later constructions restore them (bitwise — the planes are int8/int32
exact) instead of re-preparing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.policy import prepare_weights
from ..models.transformer import Model


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params,
        cache_len: int,
        batch_size: int,
        prepare: bool = False,
        prepared_dir: str | None = None,
    ):
        self.model = model
        policy = model.cfg.gemm_policy
        if prepare and policy.backend != "native":
            params = self._prepared_params(params, policy, prepared_dir)
        self.params = params
        self.cache_len = cache_len
        self.batch_size = batch_size
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))

    @classmethod
    def _collect_prepared(cls, like, tree, out=None, prefix=""):
        """Flat {path: aligned node} at every PreparedOperand site of `like`.

        `like` is the `jax.eval_shape` skeleton of `prepare_weights(params)`,
        so its PreparedOperand sites mark exactly the weights preparation
        consumes; walking an aligned tree next to it picks out those raw
        weights (tree=params) or the prepared planes (tree=prepped) without
        re-stating prepare_weights' selection rule.
        """
        from ..core.executor import PreparedOperand

        if out is None:
            out = {}
        if isinstance(like, PreparedOperand):
            out[prefix[:-1]] = tree
        elif isinstance(like, dict):
            for k in sorted(like):
                cls._collect_prepared(like[k], tree[k], out, f"{prefix}{k}/")
        elif isinstance(like, (list, tuple)):
            for i, (lk, tr) in enumerate(zip(like, tree)):
                cls._collect_prepared(lk, tr, out, f"{prefix}{i}/")
        return out

    @classmethod
    def _graft_prepared(cls, like, params, restored, prefix=""):
        """`params` with each to-prepare weight swapped for restored[path]."""
        from ..core.executor import PreparedOperand

        if isinstance(like, PreparedOperand):
            return restored[prefix[:-1]]
        if isinstance(like, dict):
            return {
                k: cls._graft_prepared(like[k], params[k], restored, f"{prefix}{k}/")
                for k in like
            }
        if isinstance(like, (list, tuple)):
            return type(like)(
                cls._graft_prepared(lk, pr, restored, f"{prefix}{i}/")
                for i, (lk, pr) in enumerate(zip(like, params))
            )
        return params

    @staticmethod
    def _weights_fingerprint(raw_weights: dict) -> str:
        """Content hash of the to-prepare weights (path-keyed, order-stable).

        Guards the prepared-plane cache: restored residues are only valid for
        the exact weights and policy they were cast from.  Only the weights
        preparation consumes participate, so editing e.g. a bias or norm does
        not discard valid planes.
        """
        import hashlib

        h = hashlib.sha256()
        for path in sorted(raw_weights):
            a = np.asarray(raw_weights[path])
            h.update(path.encode())
            h.update(f"{a.shape}{a.dtype}".encode())
            h.update(np.ascontiguousarray(a).tobytes())
        return h.hexdigest()

    @classmethod
    def _prepared_params(cls, params, policy, prepared_dir):
        """Prepared weights, restored from `prepared_dir` when a persisted
        copy matches this (policy, weights) — else prepared now and
        persisted for the next restart.  Only the prepared residue planes
        are stored (the rest of the tree lives in the regular checkpoint),
        and a stale save (different policy, e.g. a reference-cast cache
        reused on the kernel path, or updated weights) would silently break
        the bit-identity guarantee, so it is detected via the saved metadata
        and re-prepared instead.
        """
        if prepared_dir is None:
            return prepare_weights(params, policy)
        import warnings

        from ..checkpoint import Checkpointer, latest_step

        ck = Checkpointer(prepared_dir, keep=1)
        step = latest_step(prepared_dir)
        # eval_shape walks prepare_weights abstractly: the `like` tree has
        # the right PreparedOperand structure/metadata but no residue cast
        # ever runs — it locates the weight sites (and types the restore).
        like = jax.eval_shape(lambda p: prepare_weights(p, policy), params)
        raw = cls._collect_prepared(like, params)
        meta = {
            "gemm_policy": repr(policy),
            "weights_fingerprint": cls._weights_fingerprint(raw),
        }
        if step is not None:
            if all(ck.meta(step).get(k) == v for k, v in meta.items()):
                restored = ck.restore(step, cls._collect_prepared(like, like))
                return cls._graft_prepared(like, params, restored)
            warnings.warn(
                f"prepared-weight cache in {prepared_dir!r} was saved for a "
                "different policy or weights; re-preparing (the stale planes "
                "would not be bit-identical to this configuration)",
                stacklevel=2,
            )
            step += 1  # keep=1 GC drops the stale save after the rewrite
        prepped = prepare_weights(params, policy)
        ck.save(step or 0, cls._collect_prepared(like, prepped), extra_meta=meta)
        return prepped

    def generate(
        self,
        batch: dict,
        max_new_tokens: int,
        temperature: float = 0.0,
        key=None,
    ):
        """Greedy/temperature sampling; returns (B, max_new_tokens) tokens."""
        cfg = self.model.cfg
        cache = self.model.init_cache(self.batch_size, self.cache_len)
        logits, cache = self._prefill(self.params, batch, cache)
        npre = cfg.n_prefix_embeds if cfg.frontend else 0
        pos = batch["tokens"].shape[1] + npre
        out = []
        tok = self._sample(logits[:, -1, :], temperature, key, 0)
        for i in range(max_new_tokens):
            out.append(tok)
            logits, cache = self._decode(
                self.params, tok, cache, jnp.int32(pos + i)
            )
            tok = self._sample(logits[:, -1, :], temperature, key, i + 1)
        return jnp.concatenate(out, axis=1)

    @functools.partial(jax.jit, static_argnums=(0, 2))
    def _sample(self, logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(k, logits / temperature, axis=-1)[
            :, None
        ].astype(jnp.int32)
