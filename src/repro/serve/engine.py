"""Batched serving engine: jitted prefill + single-token decode steps.

The decode step is the unit the `decode_*`/`long_*` dry-run shapes lower:
one new token against a KV/state cache of the configured length.

With an emulated (Ozaki-II) GEMM policy, `prepare=True` residue-casts
every linear weight once at engine construction (`core.policy.prepare_weights`):
step 1 of the scheme for the weight side — scaling, truncation and the N int8
residue planes — is amortized across all subsequent requests, and each call
pays only the activation-side cast.  Bit-identical to the unprepared fast-mode
path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.policy import prepare_weights
from ..models.transformer import Model


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params,
        cache_len: int,
        batch_size: int,
        prepare: bool = False,
    ):
        self.model = model
        policy = model.cfg.gemm_policy
        if prepare and policy.backend != "native":
            params = prepare_weights(params, policy)
        self.params = params
        self.cache_len = cache_len
        self.batch_size = batch_size
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))

    def generate(
        self,
        batch: dict,
        max_new_tokens: int,
        temperature: float = 0.0,
        key=None,
    ):
        """Greedy/temperature sampling; returns (B, max_new_tokens) tokens."""
        cfg = self.model.cfg
        cache = self.model.init_cache(self.batch_size, self.cache_len)
        logits, cache = self._prefill(self.params, batch, cache)
        npre = cfg.n_prefix_embeds if cfg.frontend else 0
        pos = batch["tokens"].shape[1] + npre
        out = []
        tok = self._sample(logits[:, -1, :], temperature, key, 0)
        for i in range(max_new_tokens):
            out.append(tok)
            logits, cache = self._decode(
                self.params, tok, cache, jnp.int32(pos + i)
            )
            tok = self._sample(logits[:, -1, :], temperature, key, i + 1)
        return jnp.concatenate(out, axis=1)

    @functools.partial(jax.jit, static_argnums=(0, 2))
    def _sample(self, logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(k, logits / temperature, axis=-1)[
            :, None
        ].astype(jnp.int32)
