"""Ozaki-I scheme (the paper's comparison baseline, SIV: 'OS I-S').

Error-free slicing emulation on int8 engines (Ootomo-Ozaki-Yokota [27] /
cuBLAS 'Fixed Mantissa Control' family): row/col-normalize to [0.5, 1),
peel S signed 7-bit mantissa slices per operand, and accumulate the
S(S+1)/2 cross products with |i+j| < S on the int8 engine:

    C ~= sum_{i+j < S} 2^{-7(i+j+2)} A_i B_j .

Versus Ozaki-II with N moduli (N int8 GEMMs), Ozaki-I needs S(S+1)/2 —
the quadratic-vs-linear gap behind the paper's SIV-B throughput results.
Complex variant uses the same Karatsuba trick (3 real emulations).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .intmul import int8_matmul
from .scaling import exp2_vector, ilogb

SLICE_BITS = 7
_F64 = jnp.float64


def _slices(x: jnp.ndarray, n_slices: int) -> jnp.ndarray:
    """Peel signed 7-bit slices of |x| < 1: x ~= sum_t q_t 2^{-7(t+1)}."""
    out = []
    r = x
    for t in range(n_slices):
        scale = 2.0 ** (SLICE_BITS * (t + 1))
        q = jnp.trunc(r * scale)  # |q| <= 127 by normalization
        r = r - q / scale
        out.append(q.astype(jnp.int8))
    return jnp.stack(out, axis=0)


@functools.partial(jnp.vectorize, excluded=(2, 3), signature="(m,k),(k,n)->(m,n)")
def _gemm_2d(a, b, n_slices, out_dtype):
    a64 = a.astype(_F64)
    b64 = b.astype(_F64)
    amax = jnp.max(jnp.abs(a64), axis=1)
    bmax = jnp.max(jnp.abs(b64), axis=0)
    e_mu = -(ilogb(jnp.where(amax > 0, amax, 1.0)) + 1)
    e_nu = -(ilogb(jnp.where(bmax > 0, bmax, 1.0)) + 1)
    an = a64 * exp2_vector(e_mu)[:, None]   # rows in [0.5, 1)
    bn = b64 * exp2_vector(e_nu)[None, :]
    asl = _slices(an, n_slices)
    bsl = _slices(bn, n_slices)
    acc = jnp.zeros(a.shape[:-1] + (b.shape[-1],), _F64)
    # low-order first so the final additions are the significant ones
    for s in range(n_slices - 1, -1, -1):  # s = i + j
        part = jnp.zeros_like(acc)
        for i in range(s + 1):
            j = s - i
            part = part + int8_matmul(asl[i], bsl[j]).astype(_F64)
        acc = acc + part * 2.0 ** (-SLICE_BITS * (s + 2))
    inv = exp2_vector(-e_mu)[:, None] * exp2_vector(-e_nu)[None, :]
    return (acc * inv).astype(out_dtype)


def ozaki1_gemm(
    a: jnp.ndarray, b: jnp.ndarray, n_slices: int = 8, out_dtype=None
) -> jnp.ndarray:
    """Emulated real GEMM, Ozaki-I with S slices: S(S+1)/2 int8 GEMMs."""
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    return _gemm_2d(a, b, int(n_slices), out_dtype)


def ozaki1_cgemm(
    a: jnp.ndarray, b: jnp.ndarray, n_slices: int = 8, out_dtype=None
) -> jnp.ndarray:
    """Complex Ozaki-I via Karatsuba: 3 real emulations (paper SIV-B)."""
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    real_dtype = {"complex64": jnp.float32, "complex128": jnp.float64}[
        jnp.dtype(out_dtype).name
    ]
    ar, ai = jnp.real(a).astype(_F64), jnp.imag(a).astype(_F64)
    br, bi = jnp.real(b).astype(_F64), jnp.imag(b).astype(_F64)
    d = ozaki1_gemm(ar, br, n_slices, _F64)
    e = ozaki1_gemm(ai, bi, n_slices, _F64)
    f = ozaki1_gemm(ar + ai, br + bi, n_slices, _F64)
    cr = (d - e).astype(real_dtype)
    ci = (f - d - e).astype(real_dtype)
    return jax.lax.complex(cr, ci)


def int8_gemm_count(n_slices: int) -> int:
    return n_slices * (n_slices + 1) // 2
