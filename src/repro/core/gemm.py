"""Real-valued Ozaki-II GEMM emulation (paper SII; SGEMM/DGEMM).

Pipeline (Alg. 1):  scale -> trunc -> residues -> N int8 GEMMs -> per-modulus
reduction -> CRT reconstruction -> exact inverse scaling.

Everything is jit-compatible with static (n_moduli, mode, method, n_block).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax.numpy as jnp

from . import crt, scaling
from .intmul import int8_matmul
from .moduli import CRTContext, K_CHUNK_LIMIT, make_crt_context
from .residues import (
    num_limbs_for_bits,
    quantize,
    residues_from_quantized,
    sym_mod_int32,
)

# Defaults matching the paper's accuracy bands (SIV-A / [30]):
#   CGEMM-level: fast 6-9, accu 6-8;  ZGEMM/DGEMM-level: fast 13/14-18, accu 13/14-17.
DEFAULT_MODULI = {
    ("float32", "fast"): 8,
    ("float32", "accu"): 7,
    ("float64", "fast"): 16,
    ("float64", "accu"): 15,
    ("complex64", "fast"): 7,
    ("complex64", "accu"): 7,
    ("complex128", "fast"): 14,
    ("complex128", "accu"): 14,
}


def default_n_moduli(dtype, mode: str) -> int:
    key = (jnp.dtype(dtype).name, mode)
    if key not in DEFAULT_MODULI:
        raise ValueError(f"no default moduli count for {key}")
    return DEFAULT_MODULI[key]


def _n_limbs(ctx: CRTContext) -> int:
    # |a'| <= 2^(P'_accu + 6) <= 2^(log2(P)/2 + 6); +2 safety margin.
    return num_limbs_for_bits(ctx.log2_P / 2.0 + 8.0)


def _residue_matmul(ares: jnp.ndarray, bres: jnp.ndarray, ctx: CRTContext):
    """(N,m,k) x (N,k,n) -> (N,m,n) int8 residues of A'B' (steps V-iii/iv).

    K is chunked so every int8 GEMM accumulates exactly in int32; chunks are
    reduced mod p between accumulations (residue arithmetic is closed).
    """
    k = ares.shape[-1]
    if k <= K_CHUNK_LIMIT:
        d = int8_matmul(ares, bres)
        return _sym_mod_stack(d, ctx)
    acc = None
    for k0 in range(0, k, K_CHUNK_LIMIT):
        d = int8_matmul(ares[..., k0 : k0 + K_CHUNK_LIMIT], bres[:, k0 : k0 + K_CHUNK_LIMIT, :])
        e = _sym_mod_stack(d, ctx).astype(jnp.int32)
        acc = e if acc is None else acc + e
    return _sym_mod_stack(acc, ctx)  # |acc| <= n_chunks*127 << 2^31


def _sym_mod_stack(d: jnp.ndarray, ctx: CRTContext) -> jnp.ndarray:
    outs = [sym_mod_int32(d[l], int(ctx.moduli_arr[l])) for l in range(ctx.n)]
    return jnp.stack(outs, axis=0).astype(jnp.int8)


@functools.partial(
    jnp.vectorize, excluded=(2, 3, 4, 5, 6), signature="(m,k),(k,n)->(m,n)"
)
def _gemm_2d(a, b, n_moduli, mode, method, out_dtype, n_block):
    ctx = make_crt_context(n_moduli)
    if mode == "fast":
        e_mu, e_nu = scaling.scale_fast_real(a, b, ctx)
    elif mode == "accu":
        e_mu, e_nu = scaling.scale_accurate_real(a, b, ctx)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    nl = _n_limbs(ctx)
    a64 = a.astype(jnp.float64)
    b64 = b.astype(jnp.float64)
    aq = quantize(a64, scaling.exp2_vector(e_mu), axis=0)
    ares = residues_from_quantized(aq, ctx, nl)
    n = b.shape[1]
    blocks = []
    n_block_eff = n_block or n
    for j0 in range(0, n, n_block_eff):
        bq = quantize(b64[:, j0 : j0 + n_block_eff], scaling.exp2_vector(e_nu[j0 : j0 + n_block_eff]), axis=1)
        bres = residues_from_quantized(bq, ctx, nl)
        e_r = _residue_matmul(ares, bres, ctx)
        hi, lo = crt.reconstruct(e_r, ctx, method)
        blocks.append(crt.inverse_scale(hi, lo, e_mu, e_nu[j0 : j0 + n_block_eff], out_dtype))
    return blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=1)


class PreparedOperand:
    """Beyond-paper optimization: one-time residue-cast of a reused operand.

    In iterative solvers / repeated applications (C_i = A @ B_i with a fixed
    A), step 1 of the scheme (scaling + truncation + N residue planes of A)
    can be computed once and amortized: the paper's step-1 memory term
    ((3N + 32 + c) k (m+n) / b) loses its A-side contribution entirely on
    every call after the first.  Scaling uses the fast (Cauchy-Schwarz)
    per-row bound, which is independent of the other operand.
    """

    def __init__(self, a: jnp.ndarray, n_moduli: int, side: str = "left"):
        from . import scaling as _sc

        if side not in ("left", "right"):
            raise ValueError(side)
        self.side = side
        self.n_moduli = n_moduli
        self.ctx = make_crt_context(n_moduli)
        a64 = a.astype(jnp.float64)
        amax = jnp.max(jnp.abs(a64), axis=1 if side == "left" else 0)
        norm_scale = _sc.exp2_vector(
            -_sc.ilogb(jnp.where(amax > 0, amax, 1.0))
        )
        if side == "left":
            an = a64 * norm_scale[:, None]
            nrm = jnp.sum(an * an, axis=1)
        else:
            an = a64 * norm_scale[None, :]
            nrm = jnp.sum(an * an, axis=0)
        self.e_scale = _sc._fast_exponent(amax, nrm, self.ctx)
        nl = _n_limbs(self.ctx)
        axis = 0 if side == "left" else 1
        aq = quantize(a64, _sc.exp2_vector(self.e_scale), axis)
        self.residues = residues_from_quantized(aq, self.ctx, nl)
        self.n_limbs = nl


def gemm_prepared(
    prep: PreparedOperand,
    b: jnp.ndarray,
    method: str = "paper",
    out_dtype=None,
) -> jnp.ndarray:
    """C ~= A @ B with A pre-residue-cast (fast mode). B is cast per call."""
    if prep.side != "left":
        raise NotImplementedError("right-prepared operands: transpose instead")
    from . import scaling as _sc

    ctx = prep.ctx
    out_dtype = jnp.dtype(out_dtype or b.dtype)
    b64 = b.astype(jnp.float64)
    e_mu = prep.e_scale
    _, e_nu = _sc.scale_fast_real(jnp.zeros((1, b.shape[0])), b64, ctx)
    bq = quantize(b64, _sc.exp2_vector(e_nu), axis=1)
    bres = residues_from_quantized(bq, ctx, prep.n_limbs)
    e_r = _residue_matmul(prep.residues, bres, ctx)
    hi, lo = crt.reconstruct(e_r, ctx, method)
    return crt.inverse_scale(hi, lo, e_mu, e_nu, out_dtype)


def ozaki2_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    method: str = "paper",
    out_dtype=None,
    n_block: int | None = None,
) -> jnp.ndarray:
    """Emulated high-precision real GEMM: C ~= A @ B.

    a: (..., m, k), b: (..., k, n) float32/float64 (batched over leading dims).
    n_moduli: number of CRT moduli N (defaults per dtype/mode to the paper's
      accuracy-matching setting).  mode: 'fast' | 'accu'.
    method: CRT reconstruction — 'paper' (eq. 5) | 'dd' | 'garner'.
    n_block: output-column blocking (paper SIII-A blocking variant).
    """
    if a.dtype != b.dtype:
        raise ValueError(f"dtype mismatch {a.dtype} vs {b.dtype}")
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    if n_moduli is None:
        n_moduli = default_n_moduli(a.dtype, mode)
    return _gemm_2d(a, b, int(n_moduli), mode, method, out_dtype, n_block)
