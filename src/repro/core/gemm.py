"""Real-valued Ozaki-II GEMM emulation (paper SII; SGEMM/DGEMM).

Pipeline (Alg. 1):  scale -> trunc -> residues -> N int8 GEMMs -> per-modulus
reduction -> CRT reconstruction -> exact inverse scaling.

This module is a thin wrapper: the pipeline itself lives once in
`core/executor.py`, driven by an `EmulationPlan` (`core/plan.py`).  The same
executor also serves the complex path (`core/cgemm.py`) and the Pallas
kernel path (`kernels/ops.py`).

Everything is jit-compatible with static (n_moduli, mode, method, n_block).
"""
from __future__ import annotations

import jax.numpy as jnp

from .executor import PreparedOperand, gemm_prepared, run_plan
from .plan import DEFAULT_MODULI, default_n_moduli, make_plan, n_limbs_for_ctx

__all__ = [
    "DEFAULT_MODULI",
    "PreparedOperand",
    "default_n_moduli",
    "gemm_prepared",
    "ozaki2_gemm",
]


# limb count for residue decomposition — kept under the historical name for
# external callers; the formula lives in the plan layer
_n_limbs = n_limbs_for_ctx


def ozaki2_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    method: str = "paper",
    out_dtype=None,
    n_block: int | None = None,
) -> jnp.ndarray:
    """Emulated high-precision real GEMM: C ~= A @ B.

    a: (..., m, k), b: (..., k, n) float32/float64 (batched over leading dims).
    n_moduli: number of CRT moduli N (defaults per dtype/mode to the paper's
      accuracy-matching setting).  mode: 'fast' | 'accu'.
    method: CRT reconstruction — 'paper' (eq. 5) | 'dd' | 'garner'.
    n_block: output-column blocking (paper SIII-A blocking variant).

    Complex operands are routed to the complex plan (Karatsuba formulation);
    use `ozaki2_cgemm` to control the formulation.
    """
    if a.dtype != b.dtype:
        raise ValueError(f"dtype mismatch {a.dtype} vs {b.dtype}")
    plan = make_plan(
        a.dtype,
        n_moduli=n_moduli,
        mode=mode,
        method=method,
        out_dtype=out_dtype,
        n_block=n_block,
        shape=(a.shape[-2], a.shape[-1], b.shape[-1]),
    )
    return run_plan(plan, a, b)
