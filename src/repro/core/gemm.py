"""DEPRECATED real-GEMM entry point — use `repro.linalg` + `GemmPolicy`.

`ozaki2_gemm` predates the policy redesign that made *execution* a
first-class axis.  It survives as a thin shim over the one real pipeline:

    repro.linalg.matmul(a, b, policy=GemmPolicy(backend=..., ...))

or, context-scoped (the drop-in deployment style):

    with repro.use_policy(GemmPolicy(backend="ozaki2_f64")):
        c = repro.linalg.matmul(a, b)

The shim builds exactly the `EmulationPlan` the old wrapper built, so its
results remain bitwise-identical; it emits a `DeprecationWarning` on every
call and will be removed once external callers migrate.
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from .executor import PreparedOperand, gemm_prepared
from .plan import DEFAULT_MODULI, default_n_moduli, make_plan, n_limbs_for_ctx

__all__ = [
    "DEFAULT_MODULI",
    "PreparedOperand",
    "default_n_moduli",
    "gemm_prepared",
    "ozaki2_gemm",
]


# limb count for residue decomposition — kept under the historical name for
# external callers; the formula lives in the plan layer
_n_limbs = n_limbs_for_ctx


def _deprecated(name: str, policy, stacklevel: int = 3) -> None:
    """Shared deprecation warning for every legacy ozaki2_* entry point
    (core and kernels shims) — one message template, one category."""
    warnings.warn(
        f"{name} is deprecated; call repro.linalg.matmul under "
        f"repro.use_policy({policy!r}) (or pass policy= explicitly)",
        DeprecationWarning,
        stacklevel=stacklevel,
    )


def _shim_policy(dtype, **kw):
    from .policy import BACKEND_FOR_DTYPE, GemmPolicy

    name = jnp.dtype(dtype).name
    if name not in BACKEND_FOR_DTYPE:
        raise ValueError(f"no emulation backend for operand dtype {name}")
    return GemmPolicy(backend=BACKEND_FOR_DTYPE[name], **kw)


def ozaki2_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    method: str = "paper",
    out_dtype=None,
    n_block: int | None = None,
) -> jnp.ndarray:
    """Emulated high-precision real GEMM: C ~= A @ B.

    .. deprecated:: use ``repro.linalg.matmul`` with a
       ``GemmPolicy(backend="ozaki2_f32"/"ozaki2_f64", ...)`` instead.

    a: (..., m, k), b: (..., k, n) float32/float64 (batched over leading dims).
    n_moduli: number of CRT moduli N (defaults per dtype/mode to the paper's
      accuracy-matching setting).  mode: 'fast' | 'accu'.
    method: CRT reconstruction — 'paper' (eq. 5) | 'dd' | 'garner'.
    n_block: output-column blocking (paper SIII-A blocking variant).

    Complex operands are routed to the complex plan (Karatsuba formulation);
    use the policy's `formulation` field to control the strategy.
    """
    if a.dtype != b.dtype:
        raise ValueError(f"dtype mismatch {a.dtype} vs {b.dtype}")
    policy = _shim_policy(
        a.dtype,
        n_moduli=n_moduli,
        mode=mode,
        method=method,
        out_dtype=None if out_dtype is None else jnp.dtype(out_dtype).name,
        n_block=n_block,
    )
    _deprecated("ozaki2_gemm", policy)
    from .. import linalg

    if a.ndim == 2 and b.ndim == 2:
        return linalg.matmul(a, b, policy=policy)
    # batched operands keep the historical per-slice semantics (the accu
    # bound and the auto selections see each (m,k,n) slice, not a flattened
    # product) — emulated_matmul vectorizes exactly like run_plan did
    from .policy import emulated_matmul

    return emulated_matmul(a, b, policy)
