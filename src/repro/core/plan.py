"""Static emulation plans: every Ozaki-II GEMM is described by one object.

An :class:`EmulationPlan` captures the *static* decisions of the scheme —
dtype class (real/complex), number of CRT moduli, scaling mode, CRT
reconstruction method, complex formulation (paper Fig. 1), output blocking
and the K-chunk limit — and nothing data-dependent.  It is frozen/hashable so
it can sit inside jit static arguments, `jnp.vectorize(excluded=...)` slots
and `GemmPolicy` configs.

`make_plan` is the single front door used by every entry point — the policy
stack behind `repro.linalg.matmul` (`GemmPolicy.plan_for`) and the legacy
`ozaki2_*` shims: it applies the paper's per-dtype moduli defaults and —
when the caller passes ``formulation="auto"`` / ``n_block="auto"`` with
shape hints — consults the SIII-C performance model (`core/perfmodel.py`)
to pick the complex formulation and output-column blocking (charging launch
terms per the executing backend's `fused_karatsuba`/`modulus_batched`
capabilities, which `plan_for` derives from the policy's execution axis).

The data path that *executes* a plan lives in `core/executor.py`; the plan
itself never touches arrays.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from .moduli import CRTContext, make_crt_context
from .residues import num_limbs_for_bits

# Defaults matching the paper's accuracy bands (SIV-A / [30]):
#   CGEMM-level: fast 6-9, accu 6-8;  ZGEMM/DGEMM-level: fast 13/14-18, accu 13/14-17.
DEFAULT_MODULI = {
    ("float32", "fast"): 8,
    ("float32", "accu"): 7,
    ("float64", "fast"): 16,
    ("float64", "accu"): 15,
    ("complex64", "fast"): 7,
    ("complex64", "accu"): 7,
    ("complex128", "fast"): 14,
    ("complex128", "accu"): 14,
}

# Paper SIII-A: output-column blocks of 8192 keep the Karatsuba working set
# resident; used by the auto n_block selection.
DEFAULT_N_BLOCK = 8192

REAL_FORMULATION = "real"
COMPLEX_FORMULATIONS = ("karatsuba", "block_a", "block_b")

_REAL_OF_COMPLEX = {"complex64": "float32", "complex128": "float64"}


def default_n_moduli(dtype, mode: str) -> int:
    key = (jnp.dtype(dtype).name, mode)
    if key not in DEFAULT_MODULI:
        raise ValueError(f"no default moduli count for {key}")
    return DEFAULT_MODULI[key]


def n_limbs_for_ctx(ctx: CRTContext) -> int:
    """Limb count for the residue decomposition of one CRT context:
    |a'| <= 2^(P'_accu + 6) <= 2^(log2(P)/2 + 6); +2 safety margin."""
    return num_limbs_for_bits(ctx.log2_P / 2.0 + 8.0)


@dataclasses.dataclass(frozen=True)
class EmulationPlan:
    """Static description of one emulated GEMM (real or complex).

    Fields are plain str/int so the plan is hashable and can be threaded as a
    jit-static argument.  Derived objects (CRT context, limb count) are
    recomputed on demand — `make_crt_context` is lru-cached, so this is free.
    """

    dtype: str                 # compute dtype name (float32/.../complex128)
    n_moduli: int
    mode: str                  # 'fast' | 'accu'
    method: str                # CRT reconstruction: 'paper' | 'dd' | 'garner'
    formulation: str           # 'real' | 'karatsuba' | 'block_a' | 'block_b'
    n_block: int | None        # output-column blocking (paper SIII-A)
    out_dtype: str             # result dtype name
    rtol: float | None = None  # declared accuracy contract (metadata only:
    # the componentwise tolerance this plan was resolved for, certified
    # statically by `analysis.AccuracyPass`; never read by the executor)

    # ------------------------------------------------------------ derived

    @property
    def is_complex(self) -> bool:
        return self.formulation != REAL_FORMULATION

    @property
    def ctx(self) -> CRTContext:
        return make_crt_context(self.n_moduli)

    @property
    def n_limbs(self) -> int:
        return n_limbs_for_ctx(self.ctx)

    @property
    def real_out_dtype(self):
        """dtype of each real component of the output."""
        name = self.out_dtype
        return jnp.dtype(_REAL_OF_COMPLEX.get(name, name))

    def n_block_slices(self, n: int):
        """Output-column block slices (one full slice when unblocked)."""
        nb = self.n_block or n
        return [slice(j0, j0 + nb) for j0 in range(0, n, nb)]


def make_plan(
    dtype,
    n_moduli: int | None = None,
    mode: str = "fast",
    method: str = "paper",
    formulation: str | None = None,
    out_dtype=None,
    n_block=None,
    shape: tuple[int, int, int] | None = None,
    hw=None,
    fused_karatsuba: bool = False,
    modulus_batched: bool = False,
    megakernel: bool = False,
    comm_s: float = 0.0,
    engine: str = "int8",
    rtol: float | None = None,
) -> EmulationPlan:
    """Build an :class:`EmulationPlan` from user-facing knobs.

    dtype: compute dtype of the operands; complex dtypes yield complex plans.
    formulation: for complex plans one of 'karatsuba' | 'block_a' | 'block_b'
      | 'auto' (perfmodel-driven, needs `shape`); ignored/`'real'` for real.
    n_block: int, None, or 'auto' (paper's 8192 blocking when n is larger).
    shape: optional (m, k, n) hint for the auto selections.
    hw: `perfmodel.HW` target for 'auto' (default: `perfmodel.default_hw()`
      — the active calibration's measured HW, else the TPU v5e preset).
    fused_karatsuba: the executing backend fuses the Karatsuba triple into
      one launch per modulus (the Pallas kernel path) — changes the launch
      term the 'auto' selection charges Karatsuba.
    modulus_batched: the executing backend folds all N residue planes into
      one kernel grid (`kernels` batched path) — the 'auto' selection then
      charges each product strategy a single launch instead of N.
    megakernel: the executing backend fuses cast + products + reconstruction
      into a single launch per GEMM (`execution='fused'`) — the 'auto'
      selection then charges every formulation exactly one launch, so the
      choice degenerates to the compute/memory terms.
    comm_s: collective cost of a sharded execution (perfmodel
      `sharded_comm_time_s`, priced by `GemmPolicy.plan_for` on per-shard
      shapes) — folded into the 'auto' formulation totals.
    engine: the multiply engine the executing backend runs residue products
      on ('int8' | 'fp8') — the 'auto' selections price ops at that engine's
      rate and MAC-volume factor (`perfmodel.ENGINE_OP_FACTOR`), so an fp8
      policy's launch-vs-compute crossover reflects e4m3 throughput.
    rtol: optional declared componentwise tolerance (metadata): recorded on
      the plan so `analysis.AccuracyPass` can certify the static
      `core.accuracy` bound against it.  Adaptive policies
      (`GemmPolicy(rtol=...)` / ``mode="auto"``) resolve to a concrete
      (mode, n_moduli) *before* calling `make_plan` and stamp their rtol
      here; the executor never reads it.
    """
    dt = jnp.dtype(dtype)
    if mode not in ("fast", "accu"):
        raise ValueError(f"unknown mode {mode!r}")
    is_complex = jnp.issubdtype(dt, jnp.complexfloating)
    if n_moduli is None:
        n_moduli = default_n_moduli(dt, mode)
    out_dt = jnp.dtype(out_dtype or dt)
    if jnp.issubdtype(out_dt, jnp.complexfloating) != is_complex:
        raise ValueError(
            f"out_dtype {out_dt.name} does not match the "
            f"{'complex' if is_complex else 'real'} compute dtype {dt.name}"
        )

    if not is_complex:
        formulation = REAL_FORMULATION
    else:
        formulation = formulation or "karatsuba"
        if formulation == "auto":
            formulation = _auto_formulation(
                shape, int(n_moduli), mode, dt, hw, fused_karatsuba,
                modulus_batched, megakernel, comm_s, engine,
            )
        if formulation not in COMPLEX_FORMULATIONS:
            raise ValueError(f"unknown complex formulation {formulation!r}")

    if n_block == "auto":
        n_block = _auto_n_block(shape)
    if n_block is not None:
        n_block = int(n_block)
        if n_block <= 0:
            raise ValueError(f"n_block must be positive, got {n_block}")

    return EmulationPlan(
        dtype=dt.name,
        n_moduli=int(n_moduli),
        mode=mode,
        method=method,
        formulation=formulation,
        n_block=n_block,
        out_dtype=out_dt.name,
        rtol=rtol,
    )


def _auto_formulation(
    shape, n_moduli, mode, dt, hw, fused_karatsuba=False,
    modulus_batched=False, megakernel=False, comm_s=0.0, engine="int8",
):
    from . import perfmodel

    if shape is None:
        raise ValueError(
            "formulation='auto' needs the (m, k, n) shape hint to consult "
            "the performance model; pass shape= or pick a formulation"
        )
    m, k, n = shape
    prec = "c" if dt.name == "complex64" else "z"
    return perfmodel.select_formulation(
        m, n, k, n_moduli,
        hw=hw or perfmodel.default_hw(),
        mode=mode,
        prec=prec,
        karatsuba_launches=1 if fused_karatsuba else 3,
        modulus_batched=modulus_batched,
        megakernel=megakernel,
        comm_s=comm_s,
        engine=engine,
    )


def _auto_n_block(shape) -> int | None:
    if shape is None:
        raise ValueError(
            "n_block='auto' needs the (m, k, n) shape hint; pass shape= "
            "or an explicit block size"
        )
    n = shape[2]
    if n <= DEFAULT_N_BLOCK:
        return None
    # round the block count up so blocks stay balanced (paper uses flat 8192;
    # equalizing avoids a ragged tail block)
    blocks = math.ceil(n / DEFAULT_N_BLOCK)
    return math.ceil(n / blocks)
