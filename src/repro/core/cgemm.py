"""Complex Ozaki-II GEMM emulation (the paper's core contribution, SIII).

Three INT8 complex-multiplication formulations (paper SIII-A, Fig. 1):

* 'karatsuba' (default, the paper's choice): per modulus,
      D = AR.BR, E = AI.BI, F = mod(AR+AI).mod(BR+BI)
      CR = D - E,  CI = F - D - E          -> 3N int8 GEMMs of (m,k,n)
  with optional n-blocking (paper: blocks of 8192 keep working sets resident).
  Karatsuba is exact in the residue ring — no floating-point cancellation —
  which is why ZGEMM-grade needs only 13 moduli vs 14 for real DGEMM.
* 'block_a' (eq. 7): one (2m, 2k) x (2k, n) real GEMM per modulus.
* 'block_b' (eq. 8): one (m, 2k) x (2k, 2n) real GEMM per modulus.
  (both shrink the exact-k limit from 2^17 to 2^16 — handled by K chunking.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import crt, scaling
from .gemm import _n_limbs, _residue_matmul, default_n_moduli
from .moduli import CRTContext, make_crt_context
from .residues import quantize, residues_from_quantized, sym_mod_int32

DEFAULT_N_BLOCK = 8192


def _sym_mod_i32_stack(v: jnp.ndarray, ctx: CRTContext) -> jnp.ndarray:
    outs = [sym_mod_int32(v[l], int(ctx.moduli_arr[l])) for l in range(ctx.n)]
    return jnp.stack(outs, axis=0)


def _karatsuba_block(arr, ari, brr, bri, ctx):
    """Residues of (CR', CI') for one n-block via 3 int8 GEMMs per modulus."""
    asum = _sym_mod_i32_stack(arr.astype(jnp.int32) + ari.astype(jnp.int32), ctx).astype(jnp.int8)
    bsum = _sym_mod_i32_stack(brr.astype(jnp.int32) + bri.astype(jnp.int32), ctx).astype(jnp.int8)
    d = _residue_matmul(arr, brr, ctx).astype(jnp.int32)  # already mod p
    e = _residue_matmul(ari, bri, ctx).astype(jnp.int32)
    f = _residue_matmul(asum, bsum, ctx).astype(jnp.int32)
    er = _sym_mod_i32_stack(d - e, ctx).astype(jnp.int8)
    ei = _sym_mod_i32_stack(f - d - e, ctx).astype(jnp.int8)
    return er, ei


def _block_a(arr, ari, brr, bri, ctx):
    """eq. (7): [[AR,-AI],[AI,AR]] @ [BR;BI] = [CR;CI] — one GEMM of (2m,2k,n)."""
    top = jnp.concatenate([arr, -ari], axis=-1)
    bot = jnp.concatenate([ari, arr], axis=-1)
    ahat = jnp.concatenate([top, bot], axis=-2)  # (N, 2m, 2k)
    bhat = jnp.concatenate([brr, bri], axis=-2)  # (N, 2k, n)
    chat = _residue_matmul(ahat, bhat, ctx)  # (N, 2m, n) int8 residues
    m = arr.shape[-2]
    return chat[:, :m, :], chat[:, m:, :]


def _block_b(arr, ari, brr, bri, ctx):
    """eq. (8): [AI,AR] @ [[BR,-BI],[BI,BR]] = [CI,CR] — one GEMM of (m,2k,2n)."""
    ahat = jnp.concatenate([ari, arr], axis=-1)  # (N, m, 2k)
    left = jnp.concatenate([brr, bri], axis=-2)  # (N, 2k, n)
    right = jnp.concatenate([-bri, brr], axis=-2)
    bhat = jnp.concatenate([left, right], axis=-1)  # (N, 2k, 2n)
    chat = _residue_matmul(ahat, bhat, ctx)
    n = brr.shape[-1]
    return chat[:, :, n:], chat[:, :, :n]


_FORMULATIONS = {"karatsuba": _karatsuba_block, "block_a": _block_a, "block_b": _block_b}


@functools.partial(
    jnp.vectorize, excluded=(2, 3, 4, 5, 6, 7), signature="(m,k),(k,n)->(m,n)"
)
def _cgemm_2d(a, b, n_moduli, mode, method, formulation, out_dtype, n_block):
    ctx = make_crt_context(n_moduli)
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    if mode == "fast":
        e_mu, e_nu = scaling.scale_fast_complex(ar, ai, br, bi, ctx)
    elif mode == "accu":
        e_mu, e_nu = scaling.scale_accurate_complex(ar, ai, br, bi, ctx)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    nl = _n_limbs(ctx)
    mu = scaling.exp2_vector(e_mu)
    f64 = jnp.float64
    arr = residues_from_quantized(quantize(ar.astype(f64), mu, 0), ctx, nl)
    ari = residues_from_quantized(quantize(ai.astype(f64), mu, 0), ctx, nl)
    real_dtype = {"complex64": jnp.float32, "complex128": jnp.float64}[
        jnp.dtype(out_dtype).name
    ]
    kernel = _FORMULATIONS[formulation]
    n = b.shape[1]
    n_block_eff = n_block or n
    blocks = []
    for j0 in range(0, n, n_block_eff):
        sl = slice(j0, j0 + n_block_eff)
        nu = scaling.exp2_vector(e_nu[sl])
        brr = residues_from_quantized(quantize(br[:, sl].astype(f64), nu, 1), ctx, nl)
        bri = residues_from_quantized(quantize(bi[:, sl].astype(f64), nu, 1), ctx, nl)
        er, ei = kernel(arr, ari, brr, bri, ctx)
        rh, rl = crt.reconstruct(er, ctx, method)
        ih, il = crt.reconstruct(ei, ctx, method)
        cr = crt.inverse_scale(rh, rl, e_mu, e_nu[sl], real_dtype)
        ci = crt.inverse_scale(ih, il, e_mu, e_nu[sl], real_dtype)
        blocks.append(jax.lax.complex(cr, ci))
    return blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=1)


def ozaki2_cgemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    method: str = "paper",
    formulation: str = "karatsuba",
    out_dtype=None,
    n_block: int | None = None,
) -> jnp.ndarray:
    """Emulated complex GEMM: C ~= A @ B for complex64 (CGEMM) / complex128
    (ZGEMM) operands, per the paper's Ozaki-II complex extension."""
    if a.dtype != b.dtype:
        raise ValueError(f"dtype mismatch {a.dtype} vs {b.dtype}")
    if not jnp.issubdtype(a.dtype, jnp.complexfloating):
        raise ValueError("ozaki2_cgemm expects complex operands")
    out_dtype = jnp.dtype(out_dtype or a.dtype)
    if n_moduli is None:
        n_moduli = default_n_moduli(a.dtype, mode)
    return _cgemm_2d(
        a, b, int(n_moduli), mode, method, formulation, out_dtype, n_block
    )
