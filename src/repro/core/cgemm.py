"""DEPRECATED complex-GEMM entry point — use `repro.linalg` + `GemmPolicy`.

`ozaki2_cgemm` predates the policy redesign; the three INT8 complex
formulations (paper SIII-A, Fig. 1 — 'karatsuba' | 'block_a' | 'block_b' |
'auto') are selected by `GemmPolicy.formulation` now:

    repro.linalg.cgemm(a, b)                      # ambient policy knobs
    repro.linalg.matmul(a, b, policy=GemmPolicy(
        backend="ozaki2_c128", formulation="block_a"))

The shim builds exactly the `EmulationPlan` the old wrapper built, so its
results remain bitwise-identical; it emits a `DeprecationWarning` on every
call and will be removed once external callers migrate.
"""
from __future__ import annotations

import jax.numpy as jnp

from .gemm import _deprecated, _shim_policy
from .plan import DEFAULT_N_BLOCK

__all__ = ["DEFAULT_N_BLOCK", "ozaki2_cgemm"]


def ozaki2_cgemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    method: str = "paper",
    formulation: str = "karatsuba",
    out_dtype=None,
    n_block: int | None = None,
) -> jnp.ndarray:
    """Emulated complex GEMM: C ~= A @ B for complex64 (CGEMM) / complex128
    (ZGEMM) operands, per the paper's Ozaki-II complex extension.

    .. deprecated:: use ``repro.linalg.cgemm``/``zgemm`` (or
       ``repro.linalg.matmul`` with a ``GemmPolicy(backend="ozaki2_c64" /
       "ozaki2_c128", formulation=...)``) instead.
    """
    if a.dtype != b.dtype:
        raise ValueError(f"dtype mismatch {a.dtype} vs {b.dtype}")
    if not jnp.issubdtype(a.dtype, jnp.complexfloating):
        raise ValueError("ozaki2_cgemm expects complex operands")
    policy = _shim_policy(
        a.dtype,
        n_moduli=n_moduli,
        mode=mode,
        method=method,
        formulation=formulation,
        out_dtype=None if out_dtype is None else jnp.dtype(out_dtype).name,
        n_block=n_block,
    )
    _deprecated("ozaki2_cgemm", policy)
    from .. import linalg

    if a.ndim == 2 and b.ndim == 2:
        return linalg.matmul(a, b, policy=policy)
    from .policy import emulated_matmul

    return emulated_matmul(a, b, policy)
