"""Complex Ozaki-II GEMM emulation (the paper's core contribution, SIII).

Three INT8 complex-multiplication formulations (paper SIII-A, Fig. 1):

* 'karatsuba' (default, the paper's choice): per modulus,
      D = AR.BR, E = AI.BI, F = mod(AR+AI).mod(BR+BI)
      CR = D - E,  CI = F - D - E          -> 3N int8 GEMMs of (m,k,n)
  with optional n-blocking (paper: blocks of 8192 keep working sets resident).
  Karatsuba is exact in the residue ring — no floating-point cancellation —
  which is why ZGEMM-grade needs only 13 moduli vs 14 for real DGEMM.
* 'block_a' (eq. 7): one (2m, 2k) x (2k, n) real GEMM per modulus.
* 'block_b' (eq. 8): one (m, 2k) x (2k, 2n) real GEMM per modulus.
  (both shrink the exact-k limit from 2^17 to 2^16 — handled by K chunking.)
* 'auto': pick by the SIII-C performance model (`core/perfmodel.py`).

The pipeline itself lives once in `core/executor.py`; this module only
builds the `EmulationPlan` and validates operands.
"""
from __future__ import annotations

import jax.numpy as jnp

from .executor import run_plan
from .plan import DEFAULT_N_BLOCK, make_plan

__all__ = ["DEFAULT_N_BLOCK", "ozaki2_cgemm"]


def ozaki2_cgemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    method: str = "paper",
    formulation: str = "karatsuba",
    out_dtype=None,
    n_block: int | None = None,
) -> jnp.ndarray:
    """Emulated complex GEMM: C ~= A @ B for complex64 (CGEMM) / complex128
    (ZGEMM) operands, per the paper's Ozaki-II complex extension.

    formulation: 'karatsuba' | 'block_a' | 'block_b' | 'auto' (SIII-C model).
    n_block: int | None | 'auto' (paper's 8192-column blocking when n is big).
    """
    if a.dtype != b.dtype:
        raise ValueError(f"dtype mismatch {a.dtype} vs {b.dtype}")
    if not jnp.issubdtype(a.dtype, jnp.complexfloating):
        raise ValueError("ozaki2_cgemm expects complex operands")
    plan = make_plan(
        a.dtype,
        n_moduli=n_moduli,
        mode=mode,
        method=method,
        formulation=formulation,
        out_dtype=out_dtype,
        n_block=n_block,
        shape=(a.shape[-2], a.shape[-1], b.shape[-1]),
    )
    return run_plan(plan, a, b)
