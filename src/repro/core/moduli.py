"""Moduli selection and CRT constants for the Ozaki-II scheme.

The paper (Alg. 1, steps I-II) uses N pairwise-coprime moduli p_l <= 256 and
precomputes P = prod(p_l) and the modular inverses q_l of P/p_l (mod p_l).

TPU adaptation (DESIGN.md S2): we restrict to *odd* moduli <= 255 so that the
symmetric residue satisfies |r| <= (p-1)/2 <= 127 (fits int8 with margin) and
the floating-point modular reduction is provably exact (no round-to-nearest
ties at +/- p/2).

All big-integer constants (P, P/p_l, q_l, Garner tables, eq.(5) splits) are
computed host-side with exact Python integers at trace time.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import numpy as np

MAX_MODULI = 24
# int8 residue products |r_a * r_b| <= 127^2; int32 accumulates exactly for
# k <= 2^31 / 127^2 ~= 133152.  We chunk K above this (core/gemm.py).
K_CHUNK_LIMIT = 1 << 17


def _pairwise_coprime_moduli(count: int) -> list[int]:
    """Greedy descending odd pairwise-coprime moduli <= 255."""
    chosen: list[int] = []
    cand = 255
    while len(chosen) < count and cand >= 3:
        if all(math.gcd(cand, c) == 1 for c in chosen):
            chosen.append(cand)
        cand -= 2
    if len(chosen) < count:
        raise ValueError(f"cannot find {count} pairwise-coprime odd moduli <= 255")
    return chosen


@functools.lru_cache(maxsize=None)
def default_moduli(n: int) -> tuple[int, ...]:
    if not 1 <= n <= MAX_MODULI:
        raise ValueError(f"N must be in [1, {MAX_MODULI}], got {n}")
    return tuple(_pairwise_coprime_moduli(n))


def _split_fp64_at(x: int, cutpos: int) -> tuple[float, float]:
    """Split an exact integer x into (hi, lo) doubles at absolute bit
    position `cutpos` (paper eq. (5): s_l1 / s_l2).

    Splitting every w_l at the SAME absolute position (rather than a
    per-value relative one) makes all S1 products multiples of 2^cutpos, so
    the N-term accumulation spans exactly (53-7-ceil(log2 N)) + 7 +
    ceil(log2 N) = 53 bits and is error-free — the guarantee the paper's bit
    allocation is designed for.
    """
    if x == 0:
        return 0.0, 0.0
    shift = max(0, cutpos)
    hi_int = (x >> shift) << shift
    hi = float(hi_int)  # exact: <= 53-7-ceil(log2 N) significant bits
    lo = float(x - hi_int)  # rounded to nearest double (|err| <= 2^(cut-53))
    return hi, lo


def _dd_from_int(x: int) -> tuple[float, float]:
    """Round an exact integer to a double-double (hi, lo) pair."""
    hi = float(x)
    lo = float(x - int(hi))
    return hi, lo


@dataclasses.dataclass(frozen=True)
class CRTContext:
    """Precomputed constants for an N-moduli Ozaki-II instance.

    Everything here is a small numpy array or Python scalar captured as a
    compile-time constant; nothing depends on runtime data.
    """

    n: int
    moduli: tuple[int, ...]          # p_l
    P: int                           # prod p_l (exact Python int)
    log2_P: float                    # log2(P), exact enough for scaling
    # --- paper eq. (5) reconstruction: w_l = (P/p_l)*q_l split hi/lo ---
    w_hi: np.ndarray                 # (N,) f64, exact top bits of w_l
    w_lo: np.ndarray                 # (N,) f64
    # extended split for the double-double reconstruction path
    w_dd_hi: np.ndarray              # (N,) f64: w_l rounded to dd
    w_dd_lo: np.ndarray              # (N,) f64
    # P as a 3-term f64 expansion (exact for log2(P) <= 159)
    P_exp: np.ndarray                # (3,) f64, P = sum(P_exp) exactly
    # --- Garner mixed-radix reconstruction (TPU path) ---
    garner_inv: np.ndarray           # (N, N) int32, inv(prod_{s<t} p_s, p_t) staged:
    #   garner_inv[s, t] = inverse of p_s modulo p_t (s < t), else 0
    weights_dd: np.ndarray           # (N, 2) f64: W_t = prod_{s<t} p_s as dd
    moduli_arr: np.ndarray           # (N,) int32
    half_arr: np.ndarray             # (N,) int32, (p_l - 1) // 2

    @property
    def p_half(self) -> float:
        return float(self.P) / 2.0


@functools.lru_cache(maxsize=None)
def make_crt_context(n: int, moduli: Sequence[int] | None = None) -> CRTContext:
    p = tuple(moduli) if moduli is not None else default_moduli(n)
    if len(p) != n:
        raise ValueError("len(moduli) != n")
    for i in range(n):
        for j in range(i + 1, n):
            if math.gcd(p[i], p[j]) != 1:
                raise ValueError(f"moduli {p[i]}, {p[j]} not coprime")
        if p[i] % 2 == 0 or p[i] > 255:
            raise ValueError("moduli must be odd and <= 255 (see DESIGN.md)")

    P = 1
    for pl in p:
        P *= pl

    # w_l = (P / p_l) * q_l with q_l = (P/p_l)^{-1} mod p_l  (Alg. 1 step II)
    w_hi = np.zeros(n, dtype=np.float64)
    w_lo = np.zeros(n, dtype=np.float64)
    w_dd_hi = np.zeros(n, dtype=np.float64)
    w_dd_lo = np.zeros(n, dtype=np.float64)
    # symmetric-mod residues are 7-bit => hi part may keep 53-7-ceil(log2 N)
    hi_bits = 53 - 7 - max(1, math.ceil(math.log2(max(n, 2))))
    ws = []
    for pl in p:
        M = P // pl
        q = pow(M % pl, -1, pl)
        ws.append(M * q)
    cutpos = max(w.bit_length() for w in ws) - hi_bits
    for l, w in enumerate(ws):
        w_hi[l], w_lo[l] = _split_fp64_at(w, cutpos)
        w_dd_hi[l], w_dd_lo[l] = _dd_from_int(w)

    # P as an exact 3-term expansion (greedy round-and-subtract)
    P_exp = np.zeros(3, dtype=np.float64)
    rem = P
    for t in range(3):
        v = float(rem)
        # round-to-nearest may exceed rem; greedy exact peel of top 53 bits:
        top = rem.bit_length()
        shift = max(0, top - 53)
        vi = (rem >> shift) << shift
        P_exp[t] = float(vi)
        rem -= vi
        if rem == 0:
            break
    if rem != 0:
        raise ValueError("P needs more than 159 bits; reduce N")

    garner_inv = np.zeros((n, n), dtype=np.int32)
    for t in range(n):
        for s in range(t):
            garner_inv[s, t] = pow(p[s], -1, p[t])

    weights_dd = np.zeros((n, 2), dtype=np.float64)
    W = 1
    for t in range(n):
        weights_dd[t, 0], weights_dd[t, 1] = _dd_from_int(W)
        W *= p[t]

    return CRTContext(
        n=n,
        moduli=p,
        P=P,
        log2_P=_log2_bigint(P),
        w_hi=w_hi,
        w_lo=w_lo,
        w_dd_hi=w_dd_hi,
        w_dd_lo=w_dd_lo,
        P_exp=P_exp,
        garner_inv=garner_inv,
        weights_dd=weights_dd,
        moduli_arr=np.asarray(p, dtype=np.int32),
        half_arr=np.asarray([(pl - 1) // 2 for pl in p], dtype=np.int32),
    )


def _log2_bigint(x: int) -> float:
    top = x.bit_length()
    if top <= 53:
        return math.log2(x)
    shift = top - 53
    return math.log2(x >> shift) + shift


def min_moduli_for_bits(bits: float) -> int:
    """Smallest N whose product exceeds 2^bits."""
    for n in range(1, MAX_MODULI + 1):
        if make_crt_context(n).log2_P > bits:
            return n
    raise ValueError(f"cannot reach {bits} bits with {MAX_MODULI} moduli")
