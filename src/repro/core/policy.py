"""GEMM backend policy — the framework-facing integration of the technique.

A :class:`GemmPolicy` is one hashable object answering every static question
about a matmul: *what* to emulate (``backend`` — the compute dtype class),
*how precisely* (``n_moduli``/``mode``/``method``/``out_dtype``), *which
complex strategy* (``formulation``/``n_block``), and — since this layer
became the seam for every execution target — *where* to run it:

    execution="reference"           jnp reference data path (exact f64 host)
    execution="kernel"              modulus-batched Pallas kernels (the TPU
                                    path; 4 launches per GEMM at any N)
    execution="per_modulus_kernel"  pre-batching Pallas path (one launch per
                                    modulus; bitwise parity reference)
    execution="sharded"             the kernel pipeline under `shard_map`
                                    over a mesh: residue planes shard N over
                                    the 'residue' axis (falling back to
                                    'model'), m/n shard like a normal GEMM,
                                    and one psum of the reconstructed output
                                    is the only communication
                                    (`distributed/sharded_gemm.py`)
    execution="fp8"                 the FP8 (e4m3) engine: residue products
                                    as exact base-16 digit GEMMs with
                                    per-plane rescale, bitwise identical to
                                    "kernel" but priced at the e4m3 rate
                                    (`kernels/fp8_mod_gemm.py`,
                                    arXiv:2603.10634)
    execution="fused"               the one-launch megakernel: residue casts
                                    as the kernel prologue, Garner
                                    reconstruction as its epilogue, K-chunk
                                    carries in-kernel — a fast-mode GEMM is
                                    exactly one `pallas_call`, bitwise
                                    identical to "kernel".  With an ambient
                                    `use_mesh` (or pinned ``mesh=``) the
                                    fused worker runs under the sharded
                                    pipeline (m/n sharding; residue-sharded
                                    meshes fall back to the composed worker)

The sharded execution needs a mesh: pin it on the policy (``mesh=``) or
scope a thread-local default with :func:`use_mesh` (also reachable as
``repro.use_mesh`` and via ``repro.use_policy(policy, mesh=...)``).
``shard_axes`` optionally overrides the (residue, m, n) mesh-axis names.

Execution targets plug in as new ``execution`` values resolved by
:meth:`GemmPolicy.execution_backend`; the plan/executor layer
(`core/plan.py` + `core/executor.py`) is backend-agnostic — the fp8 engine
and the fused megakernel are the existence proofs that the protocol
generalizes beyond per-stage int8 kernels.

User code normally does not call this module directly: `repro.linalg.matmul`
is the drop-in entry point, scoped by `repro.use_policy(policy)` — the
analog of the paper's LD_PRELOAD interposition of cuBLAS calls, but
composable, context-scoped and differentiable.  Any dense layer in
`repro.models` routes its matmuls through the same function, so the paper's
emulation is a first-class, config-selectable feature (`gemm_policy` in the
arch configs).

Backends cover both halves of the paper: `ozaki2_f32`/`ozaki2_f64` run the
real SGEMM/DGEMM emulation, `ozaki2_c64`/`ozaki2_c128` the complex
CGEMM/ZGEMM emulation (SIII) with a selectable Fig. 1 `formulation` and
output-column `n_block`.  All four build an `EmulationPlan` and run the
shared executor with the policy's resolved execution backend.

The emulated forward is wrapped in a custom VJP: trunc() has zero gradient,
but the emulation approximates an exact GEMM to (beyond-)float precision, so
the correct cotangents are those of the exact GEMM — themselves computed with
the same emulated backend (keeping the whole training step int8-dominated).
For complex operands the cotangents use the plain (non-conjugating)
transpose, matching JAX's `dot_general` transpose rule, so `jax.grad` of a
real-valued loss through complex emulated matmuls agrees with the native
path.

Weight-stationary callers (serving) may pass a `PreparedOperand` as the
weight: its scaling + residue planes were cast once up front — by the
*selected* execution backend, so prepared serving stays bit-identical to the
unprepared run on the kernel path too — and the per-call work drops to the
activation side only (see `prepare_weights`).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .executor import PreparedOperand, REFERENCE, gemm_prepared, run_plan
from .plan import default_n_moduli, make_plan

Backend = Literal[
    "native", "ozaki2_f32", "ozaki2_f64", "ozaki2_c64", "ozaki2_c128"
]

Execution = Literal[
    "reference", "kernel", "per_modulus_kernel", "sharded", "fp8", "fused"
]

EXECUTIONS = (
    "reference", "kernel", "per_modulus_kernel", "sharded", "fp8", "fused"
)


# ------------------------------------------------- thread-local default mesh

_MESH_STATE = threading.local()


def current_mesh():
    """The innermost `use_mesh` mesh (None outside any scope) — the default
    a ``GemmPolicy(execution="sharded", mesh=None)`` resolves at trace time."""
    stack = getattr(_MESH_STATE, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_mesh(mesh):
    """Scope the thread-local default mesh for sharded-execution policies.

    Nestable; the innermost scope wins.  `repro.use_policy(policy, mesh=...)`
    enters this scope alongside the policy scope, so one context manager
    distributes every matmul in a model.

    Example — a mesh-less sharded policy resolves the ambient mesh::

        >>> import jax, repro
        >>> from repro.core import GemmPolicy
        >>> mesh = jax.make_mesh((1,), ("residue",))
        >>> pol = GemmPolicy(backend="ozaki2_f32", execution="sharded")
        >>> with repro.use_mesh(mesh):
        ...     resolved = pol.resolved_mesh()
        >>> resolved is mesh
        True
    """
    from jax.sharding import Mesh

    if not isinstance(mesh, Mesh):
        raise TypeError(f"use_mesh expects a jax.sharding.Mesh; got {type(mesh).__name__}")
    stack = getattr(_MESH_STATE, "stack", None)
    if stack is None:
        stack = _MESH_STATE.stack = []
    stack.append(mesh)
    try:
        yield mesh
    finally:
        stack.pop()

_COMPUTE_DTYPES = {
    "native": None,
    "ozaki2_f32": jnp.float32,
    "ozaki2_f64": jnp.float64,
    "ozaki2_c64": jnp.complex64,
    "ozaki2_c128": jnp.complex128,
}

# the ozaki2_* backend matching each compute dtype (used by the linalg
# BLAS-shaped wrappers and the legacy entry-point shims)
BACKEND_FOR_DTYPE = {
    "float32": "ozaki2_f32",
    "float64": "ozaki2_f64",
    "complex64": "ozaki2_c64",
    "complex128": "ozaki2_c128",
}


@dataclasses.dataclass(frozen=True)
class GemmPolicy:
    """Static (hashable) matmul policy threaded through the model configs.

    One policy object answers every static question about a matmul.  The
    fields, axis by axis:

    ``backend``
        *What to emulate* — the compute dtype class: ``"native"`` (plain
        ``jnp.matmul``, no emulation) or ``"ozaki2_f32"`` / ``"ozaki2_f64"``
        / ``"ozaki2_c64"`` / ``"ozaki2_c128"`` (SGEMM/DGEMM/CGEMM/ZGEMM
        emulation; operands are coerced to that dtype).
    ``n_moduli``
        Number of CRT moduli N (None: the paper's per-(dtype, mode) default,
        `plan.DEFAULT_MODULI`).  More moduli = more accuracy, more int8/fp8
        work.
    ``mode``
        Scaling mode: ``"fast"`` (Cauchy-Schwarz bound, eqs. 11-12),
        ``"accu"`` (auxiliary 7-bit product bound, eqs. 13-14 — tighter, one
        extra product), or ``"auto"`` (requires ``rtol``): resolve the
        cheapest (mode, n_moduli) pair that provably meets the tolerance,
        priced by the calibrated perfmodel (`perfmodel.select_mode`).
    ``rtol``
        Accuracy-adaptive target (arXiv:2602.02549): the componentwise
        tolerance ``max_ij |C - C_emul|_ij / (k amax_i bmax_j)`` the
        emulation must provably meet.  With ``n_moduli=None`` the moduli
        count is resolved per call via `core.accuracy.min_moduli_for`
        (a cheap dynamic-range probe of concrete operands tightens the
        bound; under jit the static worst case applies — both provably meet
        the tolerance).  With an explicit ``n_moduli`` the pin is kept and
        validated against the bound instead.  None (default): nothing
        adaptive — behavior is bitwise identical to a policy without this
        field.  The native backend ignores ``rtol`` (no emulation step to
        adapt).
    ``method``
        CRT reconstruction: ``"paper"`` (eq. (5) split), ``"dd"``
        (double-double), ``"garner"`` (mixed-radix, the TPU-native kernel),
        or ``"auto"`` — paper on the reference execution, garner on every
        kernel execution (the only reconstruction the kernels implement; no
        f64 on the VPU).
    ``formulation``
        Complex-product strategy (paper Fig. 1): ``"karatsuba"`` (eq. 10),
        ``"block_a"`` / ``"block_b"`` (the eqs. 7/8 embeddings), or
        ``"auto"`` (SIII-C perfmodel per shape, priced at the executing
        backend's launch capabilities and engine).  Ignored for real
        backends.
    ``n_block``
        Output-column blocking (paper SIII-A): an int, None (unblocked), or
        ``"auto"`` (the paper's 8192 columns, balanced).
    ``execution``
        *Where to run it* — the residue backend: ``"reference"`` |
        ``"kernel"`` | ``"per_modulus_kernel"`` | ``"sharded"`` | ``"fp8"``
        | ``"fused"`` (see module docstring; resolved by
        :meth:`execution_backend`).
    ``interpret``
        Forces/forbids Pallas interpret mode for the kernel executions
        (None = auto: interpret off-TPU).
    ``out_dtype``
        Result dtype name (None: the compute dtype) — e.g. f64-shaped
        output from f32 operands.
    ``mesh`` / ``shard_axes``
        Sharded execution only: the mesh to distribute over (None: the
        thread-local `use_mesh` default, resolved at trace time) and an
        optional override of the resolved (residue, m, n) mesh-axis names.
        Both hashable, so sharded policies remain valid jit statics.
    ``calibration``
        Optional path of a `repro.tune` calibration cache to pin: every
        'auto' decision of this policy then prices against that file's
        *measured* `HW`, and its kernel launches use that file's autotuned
        block shapes — regardless of the ambient `use_calibration` scope.
        None (default): the ambient scope decides (presets + static default
        blocks when no scope is active).  A missing/stale/corrupt pinned
        file warns once and degrades to the presets; pinning never changes
        numerics, only the plan pricing and tile shapes.

    Example::

        >>> from repro.core import GemmPolicy
        >>> pol = GemmPolicy(backend="ozaki2_c128", mode="accu",
        ...                  execution="fp8", n_block=8192)
        >>> (pol.compute_dtype.__name__, pol.is_complex, pol.resolved_method)
        ('complex128', True, 'garner')
        >>> pol.plan_for(256, 256, 256).n_moduli     # paper default for accu
        14
    """

    backend: Backend = "native"
    n_moduli: int | None = None
    mode: str = "fast"            # 'fast' | 'accu' | 'auto' (needs rtol)
    method: str = "auto"          # CRT reconstruction path (or 'auto')
    formulation: str = "karatsuba"  # complex Fig. 1 strategy (or 'auto')
    n_block: int | str | None = None  # output-column blocking (or 'auto')
    execution: Execution = "reference"
    interpret: bool | None = None  # Pallas interpret override (kernel paths)
    out_dtype: str | None = None  # result dtype name (None: compute dtype)
    mesh: object | None = None    # sharded execution: jax.sharding.Mesh
    shard_axes: tuple | None = None  # sharded: (residue, m, n) name override
    calibration: str | None = None  # repro.tune cache path to pin (or None)
    rtol: float | None = None     # componentwise accuracy target (adaptive)

    def __post_init__(self):
        if self.backend not in _COMPUTE_DTYPES:
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.mode not in ("fast", "accu", "auto"):
            raise ValueError(
                f"unknown mode {self.mode!r}; expected 'fast', 'accu' or 'auto'"
            )
        if self.rtol is not None and not float(self.rtol) > 0.0:
            raise ValueError(f"rtol must be > 0, got {self.rtol!r}")
        if self.mode == "auto" and self.rtol is None:
            raise ValueError(
                "mode='auto' picks the cheapest (mode, n_moduli) pair meeting "
                "an accuracy target — pass GemmPolicy(rtol=...) to declare it"
            )
        if self.execution not in EXECUTIONS:
            raise ValueError(
                f"unknown execution {self.execution!r}; expected one of "
                f"{EXECUTIONS}"
            )
        if self.execution != "reference" and self.method not in ("auto", "garner"):
            raise ValueError(
                f"execution={self.execution!r} reconstructs via the Garner "
                f"kernel only; method={self.method!r} is reference-path only"
            )
        if self.out_dtype is not None:
            # normalize to the dtype's canonical name so the policy hash is
            # stable across jnp.float32 / 'float32' / np.dtype spellings
            object.__setattr__(self, "out_dtype", jnp.dtype(self.out_dtype).name)

    @property
    def compute_dtype(self):
        return _COMPUTE_DTYPES[self.backend]

    @property
    def is_complex(self) -> bool:
        return self.backend in ("ozaki2_c64", "ozaki2_c128")

    @property
    def resolved_method(self) -> str:
        """The CRT reconstruction this policy actually runs."""
        if self.method != "auto":
            return self.method
        return "paper" if self.execution == "reference" else "garner"

    def resolved_mesh(self):
        """The mesh a sharded execution runs on: the pinned field, else the
        thread-local `use_mesh` default (resolved at trace time)."""
        mesh = self.mesh if self.mesh is not None else current_mesh()
        if mesh is None:
            raise ValueError(
                "execution='sharded' needs a mesh: pass GemmPolicy(mesh=...) "
                "or enter repro.use_mesh(mesh) / repro.use_policy(policy, "
                "mesh=mesh) around tracing"
            )
        return mesh

    def resolved_calibration(self):
        """The `repro.tune.Calibration` this policy's decisions read: the
        pinned ``calibration`` file (memoized; warns once and yields None
        when unfit), else the ambient `use_calibration`/`set_calibration`
        one, else None (presets + static default blocks)."""
        from ..tune.cache import current_calibration, load_calibration_cached

        if self.calibration is not None:
            return load_calibration_cached(self.calibration)
        return current_calibration()

    def _calibration_scope(self):
        """Context manager activating the pinned calibration file (a no-op
        without one — the ambient scope then applies as-is).  Entered around
        plan selection AND kernel tracing, so the perfmodel's `default_hw`
        and the kernels' `resolve_blocks` both see the pinned cache."""
        if self.calibration is None:
            return contextlib.nullcontext()
        from ..tune.cache import load_calibration_cached, use_calibration

        cal = load_calibration_cached(self.calibration)
        if cal is None:
            return contextlib.nullcontext()
        return use_calibration(cal)

    def execution_backend(self):
        """Resolve the residue-backend instance for this policy's execution.

        The returned object is hashable (frozen dataclass) so it can ride in
        jit-static slots; `interpret` is resolved here — *outside* any jitted
        function — so an unset value never causes an avoidable retrace.
        """
        if self.execution == "reference":
            return REFERENCE
        # lazy import: core stays importable without pulling the Pallas stack
        from ..kernels.common import interpret_default
        from ..kernels.ops import KernelBackend, PerModulusKernelBackend

        interp = (
            self.interpret if self.interpret is not None else interpret_default()
        )
        if self.execution == "sharded":
            from ..distributed.sharded_gemm import ShardedBackend

            return ShardedBackend(
                KernelBackend(bool(interp)), self.resolved_mesh(),
                self.shard_axes,
            )
        if self.execution == "fp8":
            from .executor import Fp8Backend

            return Fp8Backend(bool(interp))
        if self.execution == "fused":
            from ..kernels.ops import FusedBackend

            be = FusedBackend(bool(interp))
            # optional-mesh: inside a use_mesh scope (or with mesh= pinned)
            # the fused worker runs under the sharded pipeline; without one
            # it is the plain single-device megakernel
            mesh = self.mesh if self.mesh is not None else current_mesh()
            if mesh is not None:
                from ..distributed.sharded_gemm import ShardedBackend

                return ShardedBackend(be, mesh, self.shard_axes)
            return be
        cls = (
            KernelBackend
            if self.execution == "kernel"
            else PerModulusKernelBackend
        )
        return cls(bool(interp))

    @property
    def is_adaptive(self) -> bool:
        """True when (mode, n_moduli) are deferred to per-call resolution —
        ``mode='auto'``, or ``rtol`` with no pinned ``n_moduli`` (see
        :meth:`resolve_adaptive`).  A pinned ``n_moduli`` alongside ``rtol``
        is *not* adaptive: the pin runs as-is and the declared tolerance is
        certified statically by `analysis.AccuracyPass` instead — which also
        means a policy resolve_adaptive returns (concrete mode, concrete
        n_moduli, rtol kept) runs one fixed plan everywhere, including the
        cotangent products whose contraction length differs."""
        return self.backend != "native" and (
            self.mode == "auto" or (self.rtol is not None and self.n_moduli is None)
        )

    def resolve_adaptive(self, m: int, k: int, n: int, *, stats=None):
        """Resolve ``rtol`` / ``mode='auto'`` to a concrete policy.

        Returns ``self`` unchanged when nothing is adaptive (the bitwise
        no-change guarantee for non-adaptive policies).  Otherwise: the
        admissible (mode, n_moduli) pairs come from the arXiv:2602.02549
        bound calculator (`core.accuracy`) — ``n_moduli=None`` resolves via
        `min_moduli_for`, a pinned ``n_moduli`` is validated against
        `rel_bound` — and `perfmodel.select_mode` picks the cheapest pair on
        this machine (the live `repro.tune` calibration when one is active).
        ``stats`` is an optional `core.accuracy.GemmStats` probe of the
        concrete operands that tightens the bound; ``None`` (e.g. under jit,
        or on the prepared/serving path, which must resolve identically at
        prepare and serve time) certifies the static worst case instead.
        The returned policy keeps ``rtol`` so the resolved plan carries its
        accuracy contract for `analysis.AccuracyPass`.
        """
        if not self.is_adaptive:
            return self
        from . import accuracy, perfmodel

        dtype = jnp.dtype(self.compute_dtype).name
        form = self.formulation if self.is_complex else None
        modes = ("fast", "accu") if self.mode == "auto" else (self.mode,)
        cands, reasons = [], []
        for mode in modes:
            if self.n_moduli is not None:
                bound = accuracy.rel_bound(
                    dtype, mode, self.n_moduli, k, formulation=form,
                    stats=stats, out_dtype=self.out_dtype,
                )
                if self.rtol is not None and bound > self.rtol:
                    reasons.append(
                        f"{mode}: bound {bound:g} at the pinned "
                        f"n_moduli={self.n_moduli} exceeds rtol"
                    )
                    continue
                cands.append((mode, self.n_moduli))
            else:
                try:
                    cands.append((mode, accuracy.min_moduli_for(
                        self.rtol, dtype, k=k, mode=mode, formulation=form,
                        stats=stats, out_dtype=self.out_dtype,
                    )))
                except ValueError as e:
                    reasons.append(f"{mode}: {e}")
        if not cands:
            raise ValueError(
                f"no (mode, n_moduli) meets rtol={self.rtol:g} for "
                f"backend={self.backend!r} at k={k}: " + "; ".join(reasons)
            )
        prec = {"float32": "s", "float64": "d",
                "complex64": "c", "complex128": "z"}[dtype]
        with self._calibration_scope():
            mode, n_moduli = perfmodel.select_mode(
                m, n, k, cands, prec=prec,
                engine="fp8" if self.execution == "fp8" else "int8",
            )
        if (mode, n_moduli) == (self.mode, self.n_moduli):
            return self  # already concrete (and re-validated): fixed point
        return dataclasses.replace(self, mode=mode, n_moduli=n_moduli)

    def plan_for(self, m: int, k: int, n: int):
        """The `EmulationPlan` this policy runs for an (m,k)x(k,n) product.

        Selected inside the policy's calibration scope: with a pinned (or
        ambient) `repro.tune` calibration, every `hw=None` perfmodel term
        below — the sharded comm pricing and the formulation/n_block/engine
        'auto' selections in `make_plan` — resolves `perfmodel.default_hw()`
        to the *measured* hardware instead of the TPU v5e preset.  An
        adaptive policy (``rtol`` / ``mode='auto'``) resolves its concrete
        (mode, n_moduli) first — statically here; callers holding concrete
        operands probe them and resolve before reaching this point.
        """
        if self.backend == "native":
            raise ValueError("native policy has no emulation plan")
        if self.is_adaptive:
            resolved = self.resolve_adaptive(m, k, n)
            if resolved is not self:
                return resolved.plan_for(m, k, n)
        # the perfmodel terms behind the 'auto' selections depend on how the
        # executing backend launches — read its declared capabilities so
        # plan_for and gemm_prepared can never disagree
        with self._calibration_scope():
            be = self.execution_backend()
            shape = (m, k, n)
            comm_s = 0.0
            factors = getattr(be, "shard_factors", None)
            if factors is not None:
                # sharded: price the per-shard problem plus the psum term, so
                # the 'auto' selections reflect what each shard actually runs
                from . import perfmodel

                md, nd, r = factors(m, n)
                shape = (m // md, k, n // nd)
                comm_s = perfmodel.sharded_comm_time_s(
                    shape[0], shape[2],
                    self.n_moduli
                    or default_n_moduli(self.compute_dtype, self.mode),
                    r, complex_=self.is_complex,
                )
            return make_plan(
                self.compute_dtype,
                n_moduli=self.n_moduli,
                mode=self.mode,
                method=self.resolved_method,
                formulation=self.formulation if self.is_complex else None,
                out_dtype=self.out_dtype,
                n_block=self.n_block,
                shape=shape,
                fused_karatsuba=getattr(be, "fused_karatsuba", False),
                modulus_batched=getattr(be, "modulus_batched", False),
                megakernel=getattr(be, "megakernel", False),
                comm_s=comm_s,
                engine=getattr(be, "engine", "int8"),
                rtol=self.rtol,
            )


NATIVE = GemmPolicy()


def _real_cast(y: jnp.ndarray, dtype) -> jnp.ndarray:
    """astype that is explicit about dropping an imaginary part."""
    if jnp.issubdtype(y.dtype, jnp.complexfloating) and not jnp.issubdtype(
        jnp.dtype(dtype), jnp.complexfloating
    ):
        y = jnp.real(y)
    return y.astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def emulated_matmul(x: jnp.ndarray, w: jnp.ndarray, policy: GemmPolicy):
    return _emulated_fwd_raw(x, w, policy)


def _emulated_fwd_raw(x, w, policy):
    ct = policy.compute_dtype
    plan = policy.plan_for(x.shape[-2], x.shape[-1], w.shape[-1])
    # trace under the pinned calibration (a no-op without one) so the
    # kernels' `resolve_blocks` launches the policy's tuned tile shapes
    with policy._calibration_scope():
        y = run_plan(
            plan, x.astype(ct), w.astype(ct),
            backend=policy.execution_backend(),
        )
    return _real_cast(y, policy.out_dtype or x.dtype)


def _emulated_fwd(x, w, policy):
    return _emulated_fwd_raw(x, w, policy), (x, w)


def _emulated_bwd(policy, res, g):
    x, w = res
    # dX = G @ W^T, dW = X^T @ G — also emulated (int8-engine dominated).
    # Plain transposes (no conjugation) match JAX's dot_general transpose
    # rule, so complex operands differentiate identically to jnp.matmul.
    dx = _emulated_fwd_raw(g, w.swapaxes(-1, -2), policy)
    dw = _emulated_fwd_raw(x.swapaxes(-1, -2), g, policy)
    return _real_cast(dx, x.dtype), _real_cast(dw, w.dtype)


emulated_matmul.defvjp(_emulated_fwd, _emulated_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _prepared_matmul(x: jnp.ndarray, w: PreparedOperand, policy: GemmPolicy):
    """x @ w with the weight prepared up front (inference only)."""
    ct = policy.compute_dtype
    with policy._calibration_scope():
        y = gemm_prepared(
            w,
            x.astype(ct),
            method=policy.resolved_method,
            formulation=policy.formulation,
            out_dtype=policy.out_dtype,
            n_block=policy.n_block,
            backend=policy.execution_backend(),
            mode=policy.mode,
        )
    return _real_cast(y, policy.out_dtype or x.dtype)


def _prepared_fwd(x, w, policy):
    return _prepared_matmul(x, w, policy), None


def _prepared_bwd(policy, res, g):
    # The prepared residues carry only the weight-side scaling, which is the
    # wrong axis for the cotangent products — grads would silently vanish
    # through trunc().  Training must use raw weights.
    raise ValueError(
        "prepared-weight matmuls are inference-only; differentiate through "
        "raw weights (emulated_matmul) instead"
    )


_prepared_matmul.defvjp(_prepared_fwd, _prepared_bwd)


def policy_matmul(x: jnp.ndarray, w, policy: GemmPolicy) -> jnp.ndarray:
    """x: (..., k) @ w: (k, n) under the policy's backend and execution.

    `w` may be a raw array or a right-side `PreparedOperand` (weights cast
    once, amortized across calls — the serving fast path).  This is the
    layer-shaped entry point; the general drop-in (batched `w`, ambient
    policy) is `repro.linalg.matmul`, which routes here.
    """
    if isinstance(w, PreparedOperand):
        if policy.backend == "native":
            raise ValueError(
                "prepared weights require an emulated (ozaki2_*) policy "
                "backend; the native policy runs jnp.matmul on raw weights"
            )
        if w.side != "right":
            raise ValueError("policy_matmul expects a side='right' prepared weight")
        if policy.execution == "sharded" or (
            policy.execution == "fused"
            and (policy.mesh is not None or current_mesh() is not None)
        ):
            raise NotImplementedError(
                "prepared weights are not supported under a sharded "
                "execution yet (the prepared residue planes live unsharded "
                "on one device); serve prepared weights with GemmPolicy("
                "execution='kernel') or execution='fused' outside any mesh "
                "scope, or pass raw weights to shard this matmul"
            )
        k, n = w.operand_shape
        # adaptive policies resolve *statically* on the prepared path — no
        # operand probe, and a canonical pricing shape (m := n) independent
        # of the batch — so prepare_weights and this call agree whenever the
        # policy and weight shape are unchanged; any drift (rtol edited
        # between prepare and serve, a different adaptive pick) is caught by
        # the recorded-plan checks below instead of returning wrong answers
        policy = policy.resolve_adaptive(n, k, n)
        if policy.mode == "accu" and w.raw is None:
            raise ValueError(
                "accu-mode prepared matmuls re-cast from the raw operand "
                "(the accurate exponents couple both operands); re-prepare "
                "with prepare_weights(accu policy) / keep_raw=True"
            )
        if w.mode != policy.mode:
            raise ValueError(
                f"prepared weight was prepared for mode={w.mode!r} but the "
                f"policy resolves to mode={policy.mode!r}"
                + (" (adaptive resolution)" if policy.rtol is not None else "")
                + "; re-prepare with prepare_weights(policy)"
            )
        expect = policy.n_moduli or default_n_moduli(
            policy.compute_dtype, policy.mode
        )
        if w.n_moduli != expect:
            raise ValueError(
                f"prepared weight has n_moduli={w.n_moduli} but the policy "
                f"resolves to {expect}"
                + (" (adaptive resolution)" if policy.rtol is not None else "")
                + "; re-prepare with prepare_weights(policy)"
            )
        if jnp.dtype(w.dtype) != jnp.dtype(policy.compute_dtype):
            raise ValueError(
                f"prepared weight was cast for {w.dtype} but the policy "
                f"computes in {jnp.dtype(policy.compute_dtype).name}; "
                "re-prepare with prepare_weights(policy)"
            )
        n = w.operand_shape[1]
        lead = x.shape[:-1]
        y = _prepared_matmul(x.reshape((-1, x.shape[-1])), w, policy)
        return y.reshape(lead + (n,))
    if policy.backend == "native":
        y = jnp.matmul(x, w)
        return y if policy.out_dtype is None else y.astype(policy.out_dtype)
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    if policy.is_adaptive:
        # adaptive resolution happens *before* the custom-VJP boundary so the
        # forward and both cotangent products run one concrete plan.  With
        # concrete operands a cheap dynamic-range probe tightens the bound
        # (possibly fewer moduli); under jit the probe returns None and the
        # static worst case resolves — either way provably within rtol.
        from .accuracy import probe_operands

        policy = policy.resolve_adaptive(
            x2.shape[0], x2.shape[1], w.shape[-1],
            stats=probe_operands(x2, w),
        )
    y = emulated_matmul(x2, w, policy)
    return y.reshape(lead + (w.shape[-1],))


def prepare_weights(params, policy: GemmPolicy):
    """Pre-residue-cast every linear weight in a param tree (serving).

    Walks the tree and replaces the ``"w"`` leaf of each linear bundle
    (the dicts produced by `models.layers.linear_abstract`, possibly stacked
    with a leading layers axis for scanned groups, and possibly a list/tuple
    of such stacks) by a right-side `PreparedOperand` cast with the policy's
    *selected execution backend* — so prepared serving stays bit-identical
    to the unprepared run on the kernel path as well as the reference path.
    Step 1 of the scheme then runs once per weight instead of once per
    request.  Fast mode amortizes the whole weight-side cast; accu mode
    amortizes the per-column 7-bit bound matrix and retains the raw weight
    (`keep_raw`) because the accurate exponents couple both operands — the
    weight-side residues are re-cast per call at the coupled truncation
    position (see `PreparedOperand`).  A native policy returns the tree
    unchanged (there is nothing to prepare).
    """
    if policy.backend == "native":
        return params
    if policy.execution == "sharded" or (
        policy.execution == "fused"
        and (policy.mesh is not None or current_mesh() is not None)
    ):
        raise NotImplementedError(
            "prepare_weights under a sharded execution is not supported yet "
            "(prepared planes live unsharded); prepare with "
            "execution='kernel' — or 'fused' outside any mesh scope — "
            "and serve on that policy, or serve unprepared"
        )
    cast_backend = policy.execution_backend()

    def _is_weight_leaf(val):
        return (
            isinstance(val, (jnp.ndarray, np.ndarray))
            and val.ndim >= 2
            and jnp.issubdtype(val.dtype, jnp.inexact)
        )

    def prep(val):
        """Rewrite one "w" value: an array, or a list/tuple of stacked
        weight arrays (scanned groups bundle their per-group stacks this
        way) — the "w" context propagates through the sequence nesting."""
        if _is_weight_leaf(val):
            # adaptive policies resolve statically per weight, with the same
            # canonical pricing shape (m := n) the prepared matmul path uses,
            # so the planes prepared here are exactly what serving resolves
            k, n = int(val.shape[-2]), int(val.shape[-1])
            pol = policy.resolve_adaptive(n, k, n)
            # jnp.asarray: checkpoint restores may hand numpy leaves
            return PreparedOperand(
                jnp.asarray(val).astype(policy.compute_dtype),
                pol.n_moduli
                or default_n_moduli(policy.compute_dtype, pol.mode),
                side="right",
                backend=cast_backend,
                keep_raw=pol.mode == "accu",
            )
        if isinstance(val, (list, tuple)):
            return type(val)(prep(v) for v in val)
        return walk(val)

    def walk(node):
        if isinstance(node, dict):
            return {
                key: (prep(val) if key == "w" else walk(val))
                for key, val in node.items()
            }
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)
