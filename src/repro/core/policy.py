"""GEMM backend policy — the framework-facing integration of the technique.

Any dense layer in `repro.models` routes its matmuls through `policy_matmul`,
so the paper's emulation is a first-class, config-selectable feature
(`gemm_backend` in the arch configs), analogous to the paper's LD_PRELOAD
interposition of cuBLAS calls — but composable and differentiable.

Backends cover both halves of the paper: `ozaki2_f32`/`ozaki2_f64` run the
real SGEMM/DGEMM emulation, `ozaki2_c64`/`ozaki2_c128` the complex
CGEMM/ZGEMM emulation (SIII) with a selectable Fig. 1 `formulation` and
output-column `n_block`.  All four build an `EmulationPlan` and run the
shared executor (`core/executor.py`).

The emulated forward is wrapped in a custom VJP: trunc() has zero gradient,
but the emulation approximates an exact GEMM to (beyond-)float precision, so
the correct cotangents are those of the exact GEMM — themselves computed with
the same emulated backend (keeping the whole training step int8-dominated).
For complex operands the cotangents use the plain (non-conjugating)
transpose, matching JAX's `dot_general` transpose rule, so `jax.grad` of a
real-valued loss through complex emulated matmuls agrees with the native
path.

Weight-stationary callers (serving) may pass a `PreparedOperand` as the
weight: its scaling + residue planes were cast once up front and the
per-call work drops to the activation side only (see `prepare_weights`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .executor import PreparedOperand, gemm_prepared, run_plan
from .plan import default_n_moduli, make_plan

Backend = Literal[
    "native", "ozaki2_f32", "ozaki2_f64", "ozaki2_c64", "ozaki2_c128"
]

_COMPUTE_DTYPES = {
    "native": None,
    "ozaki2_f32": jnp.float32,
    "ozaki2_f64": jnp.float64,
    "ozaki2_c64": jnp.complex64,
    "ozaki2_c128": jnp.complex128,
}


@dataclasses.dataclass(frozen=True)
class GemmPolicy:
    """Static (hashable) matmul policy threaded through the model configs."""

    backend: Backend = "native"
    n_moduli: int | None = None
    mode: str = "fast"            # 'fast' | 'accu'
    method: str = "paper"         # CRT reconstruction path
    formulation: str = "karatsuba"  # complex Fig. 1 strategy (or 'auto')
    n_block: int | None = None    # output-column blocking (or 'auto')

    @property
    def compute_dtype(self):
        return _COMPUTE_DTYPES[self.backend]

    @property
    def is_complex(self) -> bool:
        return self.backend in ("ozaki2_c64", "ozaki2_c128")

    def plan_for(self, m: int, k: int, n: int):
        """The `EmulationPlan` this policy runs for an (m,k)x(k,n) product."""
        if self.backend == "native":
            raise ValueError("native policy has no emulation plan")
        return make_plan(
            self.compute_dtype,
            n_moduli=self.n_moduli,
            mode=self.mode,
            method=self.method,
            formulation=self.formulation if self.is_complex else None,
            n_block=self.n_block,
            shape=(m, k, n),
        )


NATIVE = GemmPolicy()


def _real_cast(y: jnp.ndarray, dtype) -> jnp.ndarray:
    """astype that is explicit about dropping an imaginary part."""
    if jnp.issubdtype(y.dtype, jnp.complexfloating) and not jnp.issubdtype(
        jnp.dtype(dtype), jnp.complexfloating
    ):
        y = jnp.real(y)
    return y.astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def emulated_matmul(x: jnp.ndarray, w: jnp.ndarray, policy: GemmPolicy):
    return _emulated_fwd_raw(x, w, policy)


def _emulated_fwd_raw(x, w, policy):
    ct = policy.compute_dtype
    plan = policy.plan_for(x.shape[-2], x.shape[-1], w.shape[-1])
    y = run_plan(plan, x.astype(ct), w.astype(ct))
    return _real_cast(y, x.dtype)


def _emulated_fwd(x, w, policy):
    return _emulated_fwd_raw(x, w, policy), (x, w)


def _emulated_bwd(policy, res, g):
    x, w = res
    # dX = G @ W^T, dW = X^T @ G — also emulated (int8-engine dominated).
    # Plain transposes (no conjugation) match JAX's dot_general transpose
    # rule, so complex operands differentiate identically to jnp.matmul.
    dx = _emulated_fwd_raw(g, w.swapaxes(-1, -2), policy)
    dw = _emulated_fwd_raw(x.swapaxes(-1, -2), g, policy)
    return _real_cast(dx, x.dtype), _real_cast(dw, w.dtype)


emulated_matmul.defvjp(_emulated_fwd, _emulated_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _prepared_matmul(x: jnp.ndarray, w: PreparedOperand, policy: GemmPolicy):
    """x @ w with the weight pre-residue-cast (fast mode, inference only)."""
    ct = policy.compute_dtype
    y = gemm_prepared(
        w,
        x.astype(ct),
        method=policy.method,
        formulation=policy.formulation,
        n_block=policy.n_block,
    )
    return _real_cast(y, x.dtype)


def _prepared_fwd(x, w, policy):
    return _prepared_matmul(x, w, policy), None


def _prepared_bwd(policy, res, g):
    # The prepared residues carry only the weight-side scaling, which is the
    # wrong axis for the cotangent products — grads would silently vanish
    # through trunc().  Training must use raw weights.
    raise ValueError(
        "prepared-weight matmuls are inference-only; differentiate through "
        "raw weights (emulated_matmul) instead"
    )


_prepared_matmul.defvjp(_prepared_fwd, _prepared_bwd)


def policy_matmul(x: jnp.ndarray, w, policy: GemmPolicy) -> jnp.ndarray:
    """x: (..., k) @ w: (k, n) under the policy's backend.

    `w` may be a raw array or a right-side `PreparedOperand` (weights cast
    once, amortized across calls — the serving fast path).
    """
    if isinstance(w, PreparedOperand):
        if policy.backend == "native":
            raise ValueError(
                "prepared weights require an emulated (ozaki2_*) policy "
                "backend; the native policy runs jnp.matmul on raw weights"
            )
        if w.side != "right":
            raise ValueError("policy_matmul expects a side='right' prepared weight")
        if policy.mode != "fast":
            raise ValueError(
                "prepared weights are fast-mode only (the accurate-mode "
                f"bound couples both operands); policy.mode={policy.mode!r}"
            )
        expect = policy.n_moduli or default_n_moduli(
            policy.compute_dtype, policy.mode
        )
        if w.n_moduli != expect:
            raise ValueError(
                f"prepared weight has n_moduli={w.n_moduli} but the policy "
                f"resolves to {expect}; re-prepare with prepare_weights(policy)"
            )
        if jnp.dtype(w.dtype) != jnp.dtype(policy.compute_dtype):
            raise ValueError(
                f"prepared weight was cast for {w.dtype} but the policy "
                f"computes in {jnp.dtype(policy.compute_dtype).name}; "
                "re-prepare with prepare_weights(policy)"
            )
        n = w.operand_shape[1]
        lead = x.shape[:-1]
        y = _prepared_matmul(x.reshape((-1, x.shape[-1])), w, policy)
        return y.reshape(lead + (n,))
    if policy.backend == "native":
        return jnp.matmul(x, w)
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y = emulated_matmul(x2, w, policy)
    return y.reshape(lead + (w.shape[-1],))


def prepare_weights(params, policy: GemmPolicy):
    """Pre-residue-cast every linear weight in a param tree (serving).

    Walks the tree and replaces the ``"w"`` leaf of each linear bundle
    (the dicts produced by `models.layers.linear_abstract`, possibly stacked
    with a leading layers axis for scanned groups) by a right-side
    `PreparedOperand`, so step 1 of the scheme runs once per weight instead
    of once per request.  Only valid for fast-mode emulated policies: the
    accurate-mode bound couples both operands, so asking to prepare an
    'accu' policy is a misconfiguration and raises (a silent no-op would
    quietly forfeit the requested amortization).  A native policy returns
    the tree unchanged (there is nothing to prepare).
    """
    if policy.backend == "native":
        return params
    if policy.mode != "fast":
        raise ValueError(
            "prepare_weights requires a fast-mode policy (the accurate-mode "
            f"scaling bound couples both operands); got mode={policy.mode!r}"
        )
    n_moduli = policy.n_moduli or default_n_moduli(policy.compute_dtype, policy.mode)

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for key, val in node.items():
                if (
                    key == "w"
                    and isinstance(val, (jnp.ndarray, np.ndarray))
                    and val.ndim >= 2
                    and jnp.issubdtype(val.dtype, jnp.inexact)
                ):
                    # jnp.asarray: checkpoint restores may hand numpy leaves
                    out[key] = PreparedOperand(
                        jnp.asarray(val).astype(policy.compute_dtype),
                        n_moduli,
                        side="right",
                    )
                else:
                    out[key] = walk(val)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)
