"""GEMM backend policy — the framework-facing integration of the technique.

Any dense layer in `repro.models` routes its matmuls through `policy_matmul`,
so the paper's emulation is a first-class, config-selectable feature
(`gemm_backend` in the arch configs), analogous to the paper's LD_PRELOAD
interposition of cuBLAS calls — but composable and differentiable.

The emulated forward is wrapped in a custom VJP: trunc() has zero gradient,
but the emulation approximates an exact GEMM to (beyond-)float precision, so
the correct cotangents are those of the exact GEMM — themselves computed with
the same emulated backend (keeping the whole training step int8-dominated).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp

from .gemm import ozaki2_gemm

Backend = Literal["native", "ozaki2_f32", "ozaki2_f64"]


@dataclasses.dataclass(frozen=True)
class GemmPolicy:
    """Static (hashable) matmul policy threaded through the model configs."""

    backend: Backend = "native"
    n_moduli: int | None = None
    mode: str = "fast"            # 'fast' | 'accu'
    method: str = "paper"         # CRT reconstruction path

    @property
    def compute_dtype(self):
        return {"native": None, "ozaki2_f32": jnp.float32, "ozaki2_f64": jnp.float64}[
            self.backend
        ]


NATIVE = GemmPolicy()


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def emulated_matmul(x: jnp.ndarray, w: jnp.ndarray, policy: GemmPolicy):
    return _emulated_fwd_raw(x, w, policy)


def _emulated_fwd_raw(x, w, policy):
    ct = policy.compute_dtype
    y = ozaki2_gemm(
        x.astype(ct),
        w.astype(ct),
        n_moduli=policy.n_moduli,
        mode=policy.mode,
        method=policy.method,
    )
    return y.astype(x.dtype)


def _emulated_fwd(x, w, policy):
    return _emulated_fwd_raw(x, w, policy), (x, w)


def _emulated_bwd(policy, res, g):
    x, w = res
    # dX = G @ W^T, dW = X^T @ G — also emulated (int8-engine dominated).
    dx = _emulated_fwd_raw(g, w.swapaxes(-1, -2), policy)
    dw = _emulated_fwd_raw(x.swapaxes(-1, -2), g, policy)
    return dx.astype(x.dtype), dw.astype(w.dtype)


emulated_matmul.defvjp(_emulated_fwd, _emulated_bwd)


def policy_matmul(x: jnp.ndarray, w: jnp.ndarray, policy: GemmPolicy) -> jnp.ndarray:
    """x: (..., k) @ w: (k, n) under the policy's backend."""
    if policy.backend == "native":
        return jnp.matmul(x, w)
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y = emulated_matmul(x2, w, policy)
    return y.reshape(lead + (w.shape[-1],))
