"""CRT reconstruction (Alg. 1 steps V-v/vi) — three interchangeable paths.

paper   : the paper's eq. (5) unevaluated split S = S1 + S2 where S1 sums the
          exact high parts of w_l = (P/p_l) q_l (53-7-ceil(log2 N) bits thanks
          to the symmetric int8 residues) and S2 the rounded low parts; then
          mod(S, P) in double-double with P as an exact 3-term expansion.
dd      : full double-double accumulation of w_l * E_l (strictly more precise
          than the paper's split; used for cross-checks).
garner  : mixed-radix (Garner) reconstruction in pure small-integer
          arithmetic — the TPU-native path (no f64 on the VPU; DESIGN.md S2).
          With symmetric digits d_t in [-(p_t-1)/2,(p_t-1)/2] the representable
          range telescopes to exactly [-(P-1)/2,(P-1)/2], so uniqueness under
          condition (4) gives an *exact* integer reconstruction.

All paths take E: (N, ...) int8/int32 symmetric residues of C' and return the
value of C' as a double-double pair (hi, lo) in f64.  Inverse scaling by the
power-of-two mu, nu is exact and done by the caller.
"""
from __future__ import annotations

import jax.numpy as jnp

from .expansion import dd_add, dd_mul_fp, two_prod, quick_two_sum
from .moduli import CRTContext
from .residues import sym_mod_small

_F64 = jnp.float64


def reconstruct_paper(e_res: jnp.ndarray, ctx: CRTContext):
    """Paper eq. (5): S1 (exact) + S2 (low parts), then mod(S, P) in dd."""
    ef = e_res.astype(_F64)
    s1 = jnp.zeros(e_res.shape[1:], dtype=_F64)
    s2 = jnp.zeros(e_res.shape[1:], dtype=_F64)
    for l in range(ctx.n):  # fixed-order accumulation => bitwise reproducible
        s1 = s1 + float(ctx.w_hi[l]) * ef[l]
        s2 = s2 + float(ctx.w_lo[l]) * ef[l]
    return _mod_P_dd(s1, s2, ctx)


def reconstruct_dd(e_res: jnp.ndarray, ctx: CRTContext):
    """Full double-double accumulation (beyond-paper precision)."""
    ef = e_res.astype(_F64)
    hi = jnp.zeros(e_res.shape[1:], dtype=_F64)
    lo = jnp.zeros(e_res.shape[1:], dtype=_F64)
    for l in range(ctx.n):
        ph, pl = two_prod(jnp.asarray(float(ctx.w_dd_hi[l]), _F64), ef[l])
        pl = pl + float(ctx.w_dd_lo[l]) * ef[l]
        hi, lo = dd_add(hi, lo, ph, pl)
    return _mod_P_dd(hi, lo, ctx)


def _mod_P_dd(s_hi, s_lo, ctx: CRTContext):
    """mod(S, P) = S - P*round(S/P), P held as an exact 3-term expansion.

    |S/P| <= N * max|w_l| * 127 / P < 2^15, so z = round(S/P) is a small exact
    integer; each P_t * z is formed with two_prod (error-free) and subtracted
    in double-double.  This is the paper's 'simplified double-double modulo'.
    """
    z = jnp.round(s_hi / float(ctx.P))
    hi, lo = s_hi, s_lo
    for t in range(3):
        pt = float(ctx.P_exp[t])
        if pt == 0.0:
            continue
        ph, pl = two_prod(jnp.asarray(pt, _F64), z)
        hi, lo = dd_add(hi, lo, -ph, -pl)
    # one correction step in case round(S/P) was off by one.  The compare
    # runs in double-double: results within one f64 ulp of +/- P/2 would
    # otherwise compare equal to `half` and miss the correction.
    hh = float(ctx.P_exp[0]) / 2.0  # exact (power-of-two division)
    hl = (float(ctx.P_exp[1]) + float(ctx.P_exp[2])) / 2.0
    dpos_hi, dpos_lo = dd_add(hi, lo, -hh, -hl)  # result - P/2
    dneg_hi, dneg_lo = dd_add(hi, lo, hh, hl)    # result + P/2
    pos = (dpos_hi > 0) | ((dpos_hi == 0) & (dpos_lo > 0))
    neg = (dneg_hi < 0) | ((dneg_hi == 0) & (dneg_lo < 0))
    adj = jnp.where(pos, -1.0, jnp.where(neg, 1.0, 0.0))
    for t in range(3):
        pt = float(ctx.P_exp[t])
        if pt == 0.0:
            continue
        ph, pl = two_prod(jnp.asarray(pt, _F64), adj)
        hi, lo = dd_add(hi, lo, ph, pl)
    return hi, lo


def garner_digits(e_res: jnp.ndarray, ctx: CRTContext) -> jnp.ndarray:
    """Symmetric mixed-radix digits d_t, C' = sum_t d_t * prod_{s<t} p_s.

    Pure small-integer arithmetic: |(r - d_s) * inv| <= 254*254 < 2^16.
    Runs identically in int32 on TPU and on host.
    """
    e32 = e_res.astype(jnp.int32)
    digits = []
    for t in range(ctx.n):
        p_t = int(ctx.moduli_arr[t])
        half_t = int(ctx.half_arr[t])
        r = e32[t]
        for s in range(t):
            r = (r - digits[s]) * int(ctx.garner_inv[s, t])
            r = sym_mod_small(r, p_t, half_t).astype(jnp.int32)
        digits.append(r)
    return jnp.stack(digits, axis=0)


def reconstruct_garner(e_res: jnp.ndarray, ctx: CRTContext):
    """Garner digits -> double-double value (exact digits; dd conversion)."""
    digits = garner_digits(e_res, ctx)
    hi = jnp.zeros(e_res.shape[1:], dtype=_F64)
    lo = jnp.zeros(e_res.shape[1:], dtype=_F64)
    for t in range(ctx.n - 1, -1, -1):  # most-significant first
        d = digits[t].astype(_F64)
        wh, wl = float(ctx.weights_dd[t, 0]), float(ctx.weights_dd[t, 1])
        ph, pl = two_prod(jnp.asarray(wh, _F64), d)
        pl = pl + wl * d
        hi, lo = dd_add(hi, lo, ph, pl)
    return hi, lo


RECONSTRUCTORS = {
    "paper": reconstruct_paper,
    "dd": reconstruct_dd,
    "garner": reconstruct_garner,
}


def reconstruct(e_res: jnp.ndarray, ctx: CRTContext, method: str = "paper"):
    try:
        fn = RECONSTRUCTORS[method]
    except KeyError:
        raise ValueError(f"unknown reconstruction {method!r}") from None
    return fn(e_res, ctx)


def inverse_scale(hi, lo, e_mu, e_nu, out_dtype):
    """C = diag(mu)^-1 C' diag(nu)^-1 — exact (powers of two)."""
    inv = jnp.ldexp(jnp.asarray(1.0, _F64), -(e_mu[:, None] + e_nu[None, :]))
    return ((hi * inv) + (lo * inv)).astype(out_dtype)
