"""CRT reconstruction (Alg. 1 steps V-v/vi) — three interchangeable paths.

paper   : the paper's eq. (5) unevaluated split S = S1 + S2 where S1 sums the
          exact high parts of w_l = (P/p_l) q_l (53-7-ceil(log2 N) bits thanks
          to the symmetric int8 residues) and S2 the rounded low parts; then
          mod(S, P) in double-double with P as an exact 3-term expansion.
dd      : full double-double accumulation of w_l * E_l (strictly more precise
          than the paper's split; used for cross-checks).
garner  : mixed-radix (Garner) reconstruction in pure small-integer
          arithmetic — the TPU-native path (no f64 on the VPU; DESIGN.md S2).
          With symmetric digits d_t in [-(p_t-1)/2,(p_t-1)/2] the representable
          range telescopes to exactly [-(P-1)/2,(P-1)/2], so uniqueness under
          condition (4) gives an *exact* integer reconstruction.

All paths take E: (N, ...) int8/int32 symmetric residues of C' and return the
value of C' as a double-double pair (hi, lo) in f64.  Inverse scaling by the
power-of-two mu, nu is exact and done by the caller.
"""
from __future__ import annotations

import functools
import math

import jax.numpy as jnp
import numpy as np

from .expansion import dd_add, dd_mul_fp, two_prod, quick_two_sum
from .moduli import CRTContext
from .residues import num_limbs_for_bits, residues_from_quantized, sym_mod_int32, sym_mod_small

_F64 = jnp.float64


def reconstruct_paper(e_res: jnp.ndarray, ctx: CRTContext):
    """Paper eq. (5): S1 (exact) + S2 (low parts), then mod(S, P) in dd."""
    ef = e_res.astype(_F64)
    s1 = jnp.zeros(e_res.shape[1:], dtype=_F64)
    s2 = jnp.zeros(e_res.shape[1:], dtype=_F64)
    for l in range(ctx.n):  # fixed-order accumulation => bitwise reproducible
        s1 = s1 + float(ctx.w_hi[l]) * ef[l]
        s2 = s2 + float(ctx.w_lo[l]) * ef[l]
    return _mod_P_dd(s1, s2, ctx)


def reconstruct_dd(e_res: jnp.ndarray, ctx: CRTContext):
    """Full double-double accumulation (beyond-paper precision)."""
    ef = e_res.astype(_F64)
    hi = jnp.zeros(e_res.shape[1:], dtype=_F64)
    lo = jnp.zeros(e_res.shape[1:], dtype=_F64)
    for l in range(ctx.n):
        ph, pl = two_prod(jnp.asarray(float(ctx.w_dd_hi[l]), _F64), ef[l])
        pl = pl + float(ctx.w_dd_lo[l]) * ef[l]
        hi, lo = dd_add(hi, lo, ph, pl)
    return _mod_P_dd(hi, lo, ctx)


def _mod_P_dd(s_hi, s_lo, ctx: CRTContext):
    """mod(S, P) = S - P*round(S/P), P held as an exact 3-term expansion.

    |S/P| <= N * max|w_l| * 127 / P < 2^15, so z = round(S/P) is a small exact
    integer; each P_t * z is formed with two_prod (error-free) and subtracted
    in double-double.  This is the paper's 'simplified double-double modulo'.
    """
    z = jnp.round(s_hi / float(ctx.P))
    hi, lo = s_hi, s_lo
    for t in range(3):
        pt = float(ctx.P_exp[t])
        if pt == 0.0:
            continue
        ph, pl = two_prod(jnp.asarray(pt, _F64), z)
        hi, lo = dd_add(hi, lo, -ph, -pl)
    # one correction step in case round(S/P) was off by one.  The compare
    # runs in double-double: results within one f64 ulp of +/- P/2 would
    # otherwise compare equal to `half` and miss the correction.
    hh = float(ctx.P_exp[0]) / 2.0  # exact (power-of-two division)
    hl = (float(ctx.P_exp[1]) + float(ctx.P_exp[2])) / 2.0
    dpos_hi, dpos_lo = dd_add(hi, lo, -hh, -hl)  # result - P/2
    dneg_hi, dneg_lo = dd_add(hi, lo, hh, hl)    # result + P/2
    pos = (dpos_hi > 0) | ((dpos_hi == 0) & (dpos_lo > 0))
    neg = (dneg_hi < 0) | ((dneg_hi == 0) & (dneg_lo < 0))
    adj = jnp.where(pos, -1.0, jnp.where(neg, 1.0, 0.0))
    for t in range(3):
        pt = float(ctx.P_exp[t])
        if pt == 0.0:
            continue
        ph, pl = two_prod(jnp.asarray(pt, _F64), adj)
        hi, lo = dd_add(hi, lo, ph, pl)
    return hi, lo


def garner_digits(e_res: jnp.ndarray, ctx: CRTContext) -> jnp.ndarray:
    """Symmetric mixed-radix digits d_t, C' = sum_t d_t * prod_{s<t} p_s.

    Pure small-integer arithmetic: |(r - d_s) * inv| <= 254*254 < 2^16.
    Runs identically in int32 on TPU and on host.
    """
    e32 = e_res.astype(jnp.int32)
    digits = []
    for t in range(ctx.n):
        p_t = int(ctx.moduli_arr[t])
        half_t = int(ctx.half_arr[t])
        r = e32[t]
        for s in range(t):
            r = (r - digits[s]) * int(ctx.garner_inv[s, t])
            r = sym_mod_small(r, p_t, half_t).astype(jnp.int32)
        digits.append(r)
    return jnp.stack(digits, axis=0)


def reconstruct_garner(e_res: jnp.ndarray, ctx: CRTContext):
    """Garner digits -> double-double value (exact digits; dd conversion)."""
    digits = garner_digits(e_res, ctx)
    hi = jnp.zeros(e_res.shape[1:], dtype=_F64)
    lo = jnp.zeros(e_res.shape[1:], dtype=_F64)
    for t in range(ctx.n - 1, -1, -1):  # most-significant first
        d = digits[t].astype(_F64)
        wh, wl = float(ctx.weights_dd[t, 0]), float(ctx.weights_dd[t, 1])
        ph, pl = two_prod(jnp.asarray(wh, _F64), d)
        pl = pl + wl * d
        hi, lo = dd_add(hi, lo, ph, pl)
    return hi, lo


RECONSTRUCTORS = {
    "paper": reconstruct_paper,
    "dd": reconstruct_dd,
    "garner": reconstruct_garner,
}


def reconstruct(e_res: jnp.ndarray, ctx: CRTContext, method: str = "paper"):
    try:
        fn = RECONSTRUCTORS[method]
    except KeyError:
        raise ValueError(f"unknown reconstruction {method!r}") from None
    return fn(e_res, ctx)


def inverse_scale(hi, lo, e_mu, e_nu, out_dtype):
    """C = diag(mu)^-1 C' diag(nu)^-1 — exact (powers of two)."""
    inv = jnp.ldexp(jnp.asarray(1.0, _F64), -(e_mu[:, None] + e_nu[None, :]))
    return ((hi * inv) + (lo * inv)).astype(out_dtype)


# ==================================== partial (sharded) reconstruction support
#
# A device holding only a SUBSET S of the N residue planes cannot run any of
# the reconstructors above (Garner's digit recursion is sequential over the
# moduli, and the eq. (5) low-part sum rounds order-dependently).  What it CAN
# do exactly is accumulate its planes' share of the eq. (5) linear form
#
#     S = sum_l w_l E_l,      w_l = (P/p_l) q_l  (exact Python integers)
#
# in an *unevaluated multi-part f64 split*: w_l is cut at fixed absolute bit
# positions into parts of at most 53 - 7 - ceil(log2 N) bits, so every
# product u_{j,l} * E_l and every partial/total sum of them is an exact f64
# integer — addition of exact integers below 2^53 is associative, hence a
# `psum` over devices is bitwise order-independent.  Since w_l === delta_{li}
# (mod p_i), the full S satisfies S === E_i (mod p_i), so after the psum each
# device re-derives the COMPLETE residue planes from the exact parts in local
# small-integer arithmetic (`residues_from_partial`) and hands them to the
# ordinary (kernel or reference) reconstructor — whose output is therefore
# bitwise identical to the single-device run on the same planes, for every
# sharding of the residue dimension.


@functools.lru_cache(maxsize=None)
def partial_split(moduli: tuple[int, ...]):
    """Exact multi-part split of the eq. (5) weights for partial combines.

    Returns ``(u, radix, part_bits)``:

    * ``u``: (n_parts, N) f64 — ``u[j, l]`` is bits [j*part_bits, (j+1)*
      part_bits) of w_l as an exact small float, so
      ``w_l == sum_j u[j, l] * 2**(j*part_bits)`` exactly;
    * ``radix``: (n_parts, N) int32 — symmetric residues of
      ``2**(j*part_bits) mod p_l`` (the rebuild table);
    * ``part_bits``: the per-part width, 53 - 7 - ceil(log2 N), sized so
      ``sum_l u[j, l] * E_l`` over all N planes stays below 2^53 (|E| <= 127
      needs 7 bits, the N-term sum ceil(log2 N) more) — i.e. every partial
      sum any device or collective can form is an exact f64 integer.
    """
    n = len(moduli)
    P = 1
    for p in moduli:
        P *= p
    ws = []
    for p in moduli:
        M = P // p
        ws.append(M * pow(M % p, -1, p))
    part_bits = 53 - 7 - max(1, math.ceil(math.log2(max(n, 2))))
    n_parts = max(1, -(-max(w.bit_length() for w in ws) // part_bits))
    u = np.zeros((n_parts, n), dtype=np.float64)
    radix = np.zeros((n_parts, n), dtype=np.int32)
    mask = (1 << part_bits) - 1
    for l, (w, p) in enumerate(zip(ws, moduli)):
        half = (p - 1) // 2
        for j in range(n_parts):
            u[j, l] = float((w >> (j * part_bits)) & mask)
            r = pow(2, j * part_bits, p)
            radix[j, l] = r - p if r > half else r
    return u, radix, part_bits


def partial_combine(e_res: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """(..., N_local, m, n) int8 planes -> (..., n_parts, m, n) f64 partials.

    ``u`` is this shard's (n_parts, N_local) column slice of the
    `partial_split` table (zero columns for padding planes).  Every product
    and sum is an exact f64 integer by the part_bits budget, so the result
    can be `psum`-reduced over the residue mesh axis bitwise
    order-independently.
    """
    ef = e_res.astype(_F64)
    # contract the plane axis (third from last) against u's columns
    return jnp.moveaxis(
        jnp.tensordot(u, jnp.moveaxis(ef, -3, 0), axes=[[1], [0]]), 0, -3
    )


def residues_from_partial(t_parts: jnp.ndarray, ctx: CRTContext) -> jnp.ndarray:
    """Exact f64 partial sums (n_parts, ...) -> full (N, ...) int8 residues.

    ``t_parts[j] == sum_l u[j, l] * E_l`` summed over ALL planes (i.e. after
    the psum).  Rebuilds E_i = sym_mod(sum_j t_j 2^(j*part_bits), p_i) in
    small exact integer arithmetic: each t_j (< 2^53) limb-splits through the
    standard residue decomposition, then combines with the 2^(j*part_bits)
    radix residues.  The output equals the residues a single device holding
    every plane would have computed — bit for bit.
    """
    u, radix, _ = partial_split(ctx.moduli)
    n_parts = u.shape[0]
    nl = num_limbs_for_bits(53.0)
    acc = None
    for j in range(n_parts):
        planes = residues_from_quantized(t_parts[j], ctx, nl).astype(jnp.int32)
        r = jnp.asarray(radix[j], jnp.int32).reshape(
            (ctx.n,) + (1,) * (t_parts.ndim - 1)
        )
        term = planes * r  # |term| <= 127^2
        acc = term if acc is None else acc + term
    # |acc| <= n_parts * 127^2 << 2^31: exact final symmetric reduction
    outs = [sym_mod_int32(acc[l], int(p)) for l, p in enumerate(ctx.moduli)]
    return jnp.stack(outs, axis=0).astype(jnp.int8)
