"""Componentwise error bounds for the Ozaki-II scheme (arXiv:2602.02549).

The Error Analysis paper bounds the emulated product C = A.B componentwise:
quantization a' = trunc(a * 2^e_mu) is the ONLY inexact step (residue
decomposition, the int8/fp8 GEMMs and CRT reconstruction are all exact by
construction — `repro.analysis` certifies the overflow windows statically),
so with eps_a := 2^{-e_mu_i} / max_h|a_ih| the error telescopes to

    |C - C_emul|_ij  <=  k * amax_i * bmax_j * (eps_a + eps_b + eps_a eps_b)
                          + (output rounding)                       [thm. 3.1]

and everything reduces to bounding eps from the scaling exponents of
`core/scaling` (Alg. 1 step III).  Equation map (docs/accuracy.md spells it
out next to the paper):

  * fast mode (paper eqs. 11-12): e_mu = floor(P'_fast - bnd) - ilogb(amax)
    with bnd = max(1, DELTA log2 t) and t the scaled row 2-norm, so
    eps <= 2^{1 + bnd - P'_fast}.  A priori t <= 4k (real) / 8k (complex
    block embedding); `probe_operands` measures the actual t.
  * accu mode (paper eqs. 13-14): e_mu = floor(P'_accu - DELTA log2 cbar)
    + 5 - ilogb(amax), so eps <= 2^{-4 + DELTA log2 cbar - P'_accu}.  The
    7-bit bars are <= 64, so a priori cbar <= 4096k (real) / 12288k
    (complex Karatsuba combination); the probe bounds the actual cbar in
    O(mk + kn) without forming the int8 product.
  * complex formulations (paper eqs. 7/8/10): the eq.(10) Karatsuba
    combination C_I = F - D - E amplifies the per-product bound 6x (F's
    operands are 2x larger and three products combine); the eq.(7)/(8)
    block embeddings run one real GEMM over 2k, a 2x factor.
  * output rounding: reconstruction is exact, but the final cast to the
    output dtype plus block/chunk/Karatsuba accumulation round in
    floating point — ROUND_SLACK ulps of the real output dtype cover it
    and set the floor no rtol can go below.

The bound is *execution-independent*: every execution path ("reference",
"kernel", "fused", "sharded", "fp8", ...) is bitwise identical (asserted in
tier-1), so one static bound certifies them all — that is what
`analysis.AccuracyPass` checks against a policy's declared ``rtol``.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .moduli import MAX_MODULI, CRTContext, make_crt_context
from .scaling import DELTA

__all__ = [
    "GemmStats",
    "ROUND_SLACK",
    "min_moduli_for",
    "probe_operands",
    "rel_bound",
    "rel_error",
]

#: ulps of the real output dtype charged for output rounding (final cast,
#: blocked/chunked accumulation, Karatsuba combines).  This is the floor
#: below which no ``rtol`` is reachable at any moduli count.
ROUND_SLACK = 16.0

_REAL_ULP = {
    "float32": 2.0**-24,
    "float64": 2.0**-53,
    "complex64": 2.0**-24,
    "complex128": 2.0**-53,
}
_COMPLEX = ("complex64", "complex128")

#: amplification of the per-product bound by the complex formulation
#: (paper eqs. (7)/(8)/(10); "real" operands have no combination step).
FORMULATION_FACTOR = {
    "real": 1.0,
    "karatsuba": 6.0,
    "block_a": 2.0,
    "block_b": 2.0,
}


@dataclasses.dataclass(frozen=True)
class GemmStats:
    """Dynamic-range probe of one GEMM's operands (see `probe_operands`).

    Any field left ``None`` falls back to the a-priori worst case, so a
    partially-filled (or absent) stats object is always safe.
    """

    k: int
    #: fast mode — log2 of max_i sum_h (a_ih / 2^ilogb(amax_i))^2 (and the
    #: column twin for B).  A priori <= log2(4k) real / log2(8k) complex.
    log2_norm_a: float | None = None
    log2_norm_b: float | None = None
    #: accu mode — log2 upper bound of the largest cbar entry.  A priori
    #: <= log2(4096k) real / log2(12288k) complex.
    log2_cbar: float | None = None


def _real_ulp(dtype: str, out_dtype: str | None) -> float:
    key = out_dtype or dtype
    if key not in _REAL_ULP:
        raise ValueError(f"unknown dtype {key!r}")
    return _REAL_ULP[key]


def _formulation_factor(dtype: str, formulation: str | None) -> float:
    if dtype not in _COMPLEX:
        return FORMULATION_FACTOR["real"]
    if formulation in (None, "auto"):
        # unresolved: charge the worst complex strategy (Karatsuba)
        return FORMULATION_FACTOR["karatsuba"]
    if formulation not in FORMULATION_FACTOR:
        raise ValueError(f"unknown formulation {formulation!r}")
    return FORMULATION_FACTOR[formulation]


def _eps_pair(
    dtype: str, mode: str, ctx: CRTContext, k: int, stats: GemmStats | None
) -> tuple[float, float]:
    """Per-operand quantization grids (eps_a, eps_b) = 2^{-e_mu}/amax bounds."""
    cplx = dtype in _COMPLEX
    if mode == "fast":
        # paper eqs. (11)-(12) via core/scaling._fast_exponent
        p = (ctx.log2_P - 1.0) / 2.0 - 1.0
        worst = math.log2((8.0 if cplx else 4.0) * k)
        la = worst if stats is None or stats.log2_norm_a is None else stats.log2_norm_a
        lb = worst if stats is None or stats.log2_norm_b is None else stats.log2_norm_b
        ea = 2.0 ** (1.0 + max(1.0, DELTA * min(la, worst)) - p)
        eb = 2.0 ** (1.0 + max(1.0, DELTA * min(lb, worst)) - p)
        return ea, eb
    if mode == "accu":
        # paper eqs. (13)-(14) via core/scaling._accu_exponent
        p = ctx.log2_P / 2.0 - 0.5
        worst = math.log2((12288.0 if cplx else 4096.0) * k)
        lc = worst if stats is None or stats.log2_cbar is None else stats.log2_cbar
        e = 2.0 ** (-4.0 + DELTA * max(min(lc, worst), 0.0) - p)
        return e, e
    raise ValueError(f"mode must be 'fast' or 'accu', got {mode!r}")


def rel_bound(
    dtype: str,
    mode: str,
    n_moduli: int,
    k: int,
    *,
    formulation: str | None = None,
    stats: GemmStats | None = None,
    out_dtype: str | None = None,
) -> float:
    """Static componentwise error bound, relative to ``k * amax_i * bmax_j``.

    Upper-bounds ``max_ij |C - C_emul|_ij / (k * amax_i * bmax_j)`` where
    ``amax_i = max_h |a_ih|`` (componentwise max for complex) and
    ``bmax_j`` the column twin — the certified metric of `rel_error` and of
    every accuracy-band test.  With ``stats=None`` the bound holds for ANY
    operands; a `probe_operands` result tightens it to these operands.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if not 1 <= n_moduli <= MAX_MODULI:
        raise ValueError(f"n_moduli must be in [1, {MAX_MODULI}], got {n_moduli}")
    ctx = make_crt_context(n_moduli)
    ea, eb = _eps_pair(dtype, mode, ctx, k, stats)
    factor = _formulation_factor(dtype, formulation)
    return factor * (ea + eb + ea * eb) + ROUND_SLACK * _real_ulp(dtype, out_dtype)


def min_moduli_for(
    rtol: float,
    dtype: str,
    *,
    k: int,
    mode: str = "fast",
    formulation: str | None = None,
    stats: GemmStats | None = None,
    out_dtype: str | None = None,
) -> int:
    """Smallest moduli count whose `rel_bound` meets ``rtol`` (inverse lookup).

    Monotone in ``rtol`` (looser tolerance never needs more moduli) and
    consistent with the forward bound by construction:
    ``rel_bound(..., min_moduli_for(rtol, ...), ...) <= rtol``.

    Raises ``ValueError`` when the tolerance is unreachable — either below
    the output-dtype rounding floor (``ROUND_SLACK`` ulps) or beyond the
    moduli the 159-bit CRT reconstruction supports.
    """
    if not rtol > 0.0:
        raise ValueError(f"rtol must be > 0, got {rtol}")
    best = math.inf
    for n in range(1, MAX_MODULI + 1):
        try:
            b = rel_bound(
                dtype, mode, n, k,
                formulation=formulation, stats=stats, out_dtype=out_dtype,
            )
        except ValueError:
            break  # make_crt_context: P exceeds the 159-bit reconstruction
        if b <= rtol:
            return n
        best = min(best, b)
    floor = ROUND_SLACK * _real_ulp(dtype, out_dtype)
    raise ValueError(
        f"rtol={rtol:g} is unreachable for dtype={dtype}/mode={mode} at k={k}: "
        f"the bound bottoms out at {best:g} (output-dtype rounding floor "
        f"{floor:g}); loosen rtol or move to a wider backend"
    )


def _fast_log2norm(parts: list[np.ndarray], axis: int) -> float:
    """log2 of the max scaled 2-norm sum along ``axis`` — the quantity whose
    log the fast-mode exponent formula bounds (`scaling._fast_exponent`)."""
    red = 1 - axis
    absmax = None
    for p in parts:
        m = np.max(np.abs(p), axis=red)
        absmax = m if absmax is None else np.maximum(absmax, m)
    _, e = np.frexp(np.where(absmax > 0, absmax, 1.0))
    scale = np.ldexp(1.0, -(e - 1))
    shape = [1, 1]
    shape[axis] = -1
    t = sum(np.sum((p * scale.reshape(shape)) ** 2, axis=red) for p in parts)
    # headroom for f64 summation-order differences vs the on-device norm
    t_max = float(np.max(np.maximum(t, 1.0))) * (1.0 + 2.0**-20)
    return math.log2(t_max)


def _bar(parts: list[np.ndarray], axis: int) -> np.ndarray:
    """The 7-bit upper-bound matrices of `scaling`'s accu mode, as f64."""
    red = 1 - axis
    absmax = None
    for p in parts:
        m = np.max(np.abs(p), axis=red)
        absmax = m if absmax is None else np.maximum(absmax, m)
    _, e = np.frexp(np.where(absmax > 0, absmax, 1.0))
    e_bar = 5 - (e - 1)
    shape = [1, 1]
    shape[axis] = -1
    s = np.ldexp(1.0, e_bar).reshape(shape)
    return [np.clip(np.ceil(np.abs(p) * s), 0, 127) for p in parts]


def _parts(x: np.ndarray) -> list[np.ndarray]:
    if np.iscomplexobj(x):
        return [np.ascontiguousarray(x.real), np.ascontiguousarray(x.imag)]
    return [x]


def probe_operands(a, b) -> GemmStats | None:
    """Cheap O(mk + kn) dynamic-range probe of a GEMM's operands.

    Returns ``None`` when either operand is a tracer (inside ``jit`` the
    data is not available) — callers then fall back to `rel_bound`'s static
    worst case, which is also valid, just looser.  The accu-mode cbar is
    bounded from row/column sums of the 7-bit bars without forming the
    O(mkn) int8 product: cbar_ij <= min(rowsum_i(abar) * max(bbar),
    max(abar) * colsum_j(bbar)), doubled for the complex combination.
    """
    from jax.core import Tracer

    if isinstance(a, Tracer) or isinstance(b, Tracer):
        return None
    a = np.asarray(a, dtype=np.complex128 if np.iscomplexobj(np.asarray(a)) else np.float64)
    b = np.asarray(b, dtype=np.complex128 if np.iscomplexobj(np.asarray(b)) else np.float64)
    k = a.shape[-1]
    a2 = a.reshape(-1, k)
    b2 = b.reshape(k, -1)
    pa, pb = _parts(a2), _parts(b2)
    la = _fast_log2norm(pa, axis=0)
    lb = _fast_log2norm(pb, axis=1)
    abar, bbar = _bar(pa, axis=0), _bar(pb, axis=1)
    a_sum = sum(abar)  # real: the bar itself; complex: bar_r + bar_i
    b_sum = sum(bbar)
    row = float(np.max(np.sum(a_sum, axis=1))) * float(np.max(b_sum, initial=0.0))
    col = float(np.max(a_sum, initial=0.0)) * float(np.max(np.sum(b_sum, axis=0)))
    cbar = min(row, col) * (2.0 if len(pa) == 2 else 1.0)
    return GemmStats(
        k=k, log2_norm_a=la, log2_norm_b=lb,
        log2_cbar=math.log2(max(cbar, 1.0)),
    )


def rel_error(c_emul, c_ref, a, b) -> float:
    """Measured counterpart of `rel_bound`: the certified accuracy metric.

    ``max_ij |c_emul - c_ref|_ij / (k * amax_i * bmax_j)`` with the complex
    max taken componentwise (real and imaginary parts separately) — exactly
    the quantity `rel_bound` upper-bounds, so ``rel_error(...) <=
    rel_bound(...)`` is the accuracy certificate asserted in tier-1.
    """
    a = np.asarray(a)
    b = np.asarray(b)
    k = a.shape[-1]
    a2, b2 = a.reshape(-1, k), b.reshape(k, -1)
    amax = np.max([np.max(np.abs(p), axis=1) for p in _parts(a2)], axis=0)
    bmax = np.max([np.max(np.abs(p), axis=0) for p in _parts(b2)], axis=0)
    cplx = np.iscomplexobj(np.asarray(c_ref))
    d = np.asarray(c_emul, dtype=np.complex128 if cplx else np.float64)
    d = d.reshape(a2.shape[0], b2.shape[1]) - np.asarray(c_ref).reshape(a2.shape[0], b2.shape[1])
    err = np.maximum.reduce([np.abs(p) for p in _parts(d)])
    denom = k * np.outer(amax, bmax)
    mask = denom > 0
    if not np.any(mask):
        return 0.0
    return float(np.max(err[mask] / denom[mask]))
