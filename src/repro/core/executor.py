"""The single executor for Ozaki-II emulation plans (real and complex).

One code path drives Alg. 1 for every public entry point:

    scale -> quantize -> residue-cast -> per-modulus int8 GEMMs
          -> CRT reconstruct -> exact inverse scaling

parameterized by an :class:`EmulationPlan` (static decisions) and a
*residue backend* supplying the three data-touching primitives:

  cast(x, e, axis)            scale+trunc+limb-split -> (N, ...) int8 residues
  residue_matmul(ares, bres)  (N,m,k) x (N,k,n) -> (N,m,n) int8 residues
  karatsuba(arr, ari, brr, bri)  fused complex residue product (3 GEMMs)
  reconstruct(e_res, e_mu, e_nu, method, out_dtype)  CRT + inverse scaling

plus two OPTIONAL stacked variants — `cast_stack` / `reconstruct_stack`
operating on an (S, ...) leading stack that shares scale exponents — which
the complex pipeline uses (via `_cast_pair` / `_reconstruct_pair`) to cast
and reconstruct real/imag parts together; backends without them (the
reference and per-modulus kernel backends) transparently fall back to two
calls with bitwise-identical results.

`ReferenceBackend` is the jnp path (exact f64 host arithmetic, all three CRT
methods); `repro.kernels.ops.KernelBackend` is the Pallas TPU path.  The two
block-embedding formulations (paper eqs. 7/8) are composed here from
`residue_matmul`, so any backend gets all three Fig. 1 strategies for free.

Everything is jit-compatible: plans and backends are static (hashable), and
batching over leading operand dims is provided by `run_plan`.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import crt, scaling
from .intmul import int8_matmul
from .moduli import CRTContext, K_CHUNK_LIMIT, make_crt_context
from .plan import EmulationPlan, make_plan, n_limbs_for_ctx
from .residues import quantize, residues_from_quantized, sym_mod_int32


def _sym_mod_stack(d: jnp.ndarray, ctx: CRTContext) -> jnp.ndarray:
    outs = [sym_mod_int32(d[l], int(ctx.moduli_arr[l])) for l in range(ctx.n)]
    return jnp.stack(outs, axis=0)


def chunked_residue_matmul(
    mod_gemm_stack, ares, bres, ctx: CRTContext, carry_epilogue: bool = False,
    chunk_limit: int | None = None,
):
    """K-chunk an (N,m,k)x(N,k,n) residue product so every engine GEMM
    accumulates exactly (k <= `chunk_limit` per call), reducing mod p
    between chunks (residue arithmetic is closed).  `chunk_limit` defaults
    to the int8 engine's int32 bound (`K_CHUNK_LIMIT`, 2^17 — resolved at
    call time, so tests can patch the module constant); the fp8 engine
    passes its tighter f32 digit-accumulator bound (`FP8_K_CHUNK_LIMIT`,
    2^16).

    Two chunk-combine strategies share this single implementation of the
    chunking invariant:

      * ``carry_epilogue=False`` — `mod_gemm_stack(ares, bres) -> (N,m,n)
        int8`: chunk residues are summed as int32 host-side and reduced once
        (the jnp reference path).
      * ``carry_epilogue=True`` — `mod_gemm_stack(ares, bres, carry) ->
        (N,m,n) int8`: the previous chunk's residues are threaded through the
        backend's carry input and folded into its *kernel epilogue* mod, so
        on the kernel path chunked-K stays one batched launch per chunk with
        no host-side per-modulus loop.  On this path `ares`/`bres` (and the
        carry) may be pytrees of same-K stacks — the fused-Karatsuba product
        passes its (R, I) plane pairs and carries (CR, CI) — keeping this
        loop the ONLY implementation of the chunk limit.

    Both produce the exact canonical symmetric residues of the full-k
    product, hence bitwise-identical outputs; the stacked planes pass
    through unchanged either way.
    """
    if chunk_limit is None:
        chunk_limit = K_CHUNK_LIMIT
    if carry_epilogue:
        k = jax.tree.leaves(ares)[0].shape[-1]
        carry = None
        for k0 in range(0, k, chunk_limit):
            sl = slice(k0, k0 + chunk_limit)
            carry = mod_gemm_stack(
                jax.tree.map(lambda x: x[..., sl], ares),
                jax.tree.map(lambda x: x[:, sl, :], bres),
                carry,
            )
        return carry
    k = ares.shape[-1]
    if k <= chunk_limit:
        return mod_gemm_stack(ares, bres)
    acc = None
    for k0 in range(0, k, chunk_limit):
        e = mod_gemm_stack(
            ares[..., k0 : k0 + chunk_limit],
            bres[:, k0 : k0 + chunk_limit, :],
        ).astype(jnp.int32)
        acc = e if acc is None else acc + e
    # |acc| <= n_chunks*127 << 2^31
    return _sym_mod_stack(acc, ctx).astype(jnp.int8)


def _cast_pair(backend, xr, xi, e, axis, ctx, n_limbs):
    """Residue-cast a real/imag pair sharing one scale vector.

    Backends exposing `cast_stack` (the batched kernel path) cast both parts
    in a single launch; others fall back to two `cast` calls.  Bitwise
    identical either way (the stacked kernel runs the same per-part math).
    """
    cast_stack = getattr(backend, "cast_stack", None)
    if cast_stack is None:
        return (
            backend.cast(xr, e, axis, ctx, n_limbs),
            backend.cast(xi, e, axis, ctx, n_limbs),
        )
    res = cast_stack(jnp.stack([xr, xi]), e, axis, ctx, n_limbs)
    return res[0], res[1]


def _reconstruct_pair(backend, er, ei, e_mu, e_nu, ctx, method, out_dtype):
    """Reconstruct a CR/CI residue pair (one stacked launch when the backend
    provides `reconstruct_stack`, else two `reconstruct` calls)."""
    rec_stack = getattr(backend, "reconstruct_stack", None)
    if rec_stack is None:
        return (
            backend.reconstruct(er, e_mu, e_nu, ctx, method, out_dtype),
            backend.reconstruct(ei, e_mu, e_nu, ctx, method, out_dtype),
        )
    out = rec_stack(jnp.stack([er, ei]), e_mu, e_nu, ctx, method, out_dtype)
    return out[0], out[1]


# ================================================================ backends


def _composed_karatsuba(backend, arr, ari, brr, bri, ctx):
    """Residues of (CR', CI') via 3 residue products (paper eq. 10), composed
    from `backend.residue_matmul` — used by backends without a fused
    Karatsuba kernel (the jnp reference and the fp8 engine).  Every product
    returns canonical symmetric residues (|r| <= 127), so the host-side
    int32 combines stay exact."""
    asum = _sym_mod_stack(
        arr.astype(jnp.int32) + ari.astype(jnp.int32), ctx
    ).astype(jnp.int8)
    bsum = _sym_mod_stack(
        brr.astype(jnp.int32) + bri.astype(jnp.int32), ctx
    ).astype(jnp.int8)
    d = backend.residue_matmul(arr, brr, ctx).astype(jnp.int32)  # already mod p
    e = backend.residue_matmul(ari, bri, ctx).astype(jnp.int32)
    f = backend.residue_matmul(asum, bsum, ctx).astype(jnp.int32)
    er = _sym_mod_stack(d - e, ctx).astype(jnp.int8)
    ei = _sym_mod_stack(f - d - e, ctx).astype(jnp.int8)
    return er, ei


@dataclasses.dataclass(frozen=True)
class ReferenceBackend:
    """jnp reference data path (exact f64 host arithmetic; core/intmul.py)."""

    # launch capabilities consulted by the perfmodel-driven 'auto'
    # selections (make_plan): the reference path composes Karatsuba from 3
    # separate products and runs one launch per modulus
    fused_karatsuba = False
    modulus_batched = False
    uses_pallas = False

    def analyze(self, plan, shape=None):
        """Static-analysis suite certifying this engine (repro.analysis):
        overflow/exactness, collective safety, scan index width, and —
        given ``shape=(m, k, n)`` — the launch-count certificate (0 for
        the jnp reference path)."""
        from ..analysis import passes_for_backend

        return passes_for_backend(self, plan, shape)

    def cast(self, x, e, axis, ctx, n_limbs):
        """quantize by 2^e along `axis` and residue-decompose (steps IV/V-i/ii)."""
        xq = quantize(x.astype(jnp.float64), scaling.exp2_vector(e), axis)
        return residues_from_quantized(xq, ctx, n_limbs)

    def residue_matmul(self, ares, bres, ctx):
        """(N,m,k) x (N,k,n) -> (N,m,n) int8 residues of A'B' (steps V-iii/iv),
        K-chunked by the shared `chunked_residue_matmul`."""
        return chunked_residue_matmul(
            lambda a, b: _sym_mod_stack(int8_matmul(a, b), ctx).astype(jnp.int8),
            ares,
            bres,
            ctx,
        )

    def karatsuba(self, arr, ari, brr, bri, ctx):
        """Residues of (CR', CI') via 3 int8 GEMMs per modulus (paper eq. 10)."""
        return _composed_karatsuba(self, arr, ari, brr, bri, ctx)

    def reconstruct(self, e_res, e_mu, e_nu, ctx, method, out_dtype):
        """CRT reconstruction (steps V-v/vi) + exact inverse scaling."""
        hi, lo = crt.reconstruct(e_res, ctx, method)
        return crt.inverse_scale(hi, lo, e_mu, e_nu, out_dtype)


REFERENCE = ReferenceBackend()


@dataclasses.dataclass(frozen=True)
class Fp8Backend:
    """Residue backend running the modular products on the **FP8 (e4m3)
    engine** (`kernels/fp8_mod_gemm.py`, the arXiv:2603.10634 variant):
    residues split into balanced base-16 digits — exact in e4m3 — and each
    plane's product runs as three fp8 GEMMs accumulated in f32, rescaled
    into the residue ring per plane in the kernel epilogue.

    The first non-int8 engine through the residue-backend protocol: casts
    and Garner reconstruction are shared with the batched int8 kernel path
    (delegated to `KernelBackend`, so the plane layout and f32 quantization
    grade are identical), only the products run on the fp8 engine:
    `residue_matmul` as one batched digit-triple launch and `karatsuba` as
    the fused D/E/F digit kernel (one launch per K-chunk, declared via
    ``fused_karatsuba = True`` so the perfmodel-driven 'auto' selections
    charge the right launch count).  The digit split is exact,
    hence the whole pipeline is **bitwise identical** to
    ``execution="kernel"`` — what changes is the engine the MACs run on and
    therefore the `perfmodel` pricing (``engine = "fp8"``: 4 digit-MAC
    volumes at the e4m3 rate).

    Select via ``GemmPolicy(execution="fp8")``.  Off-TPU the kernels run in
    interpreted Pallas (bit-identical: the digits are exactly
    representable), so hosts without native fp8 matmul support fall back
    transparently.
    """

    interpret: bool | None = None

    # capability flags consulted by the perfmodel-driven 'auto' selections
    fused_karatsuba = True
    modulus_batched = True
    engine = "fp8"
    uses_pallas = True

    def analyze(self, plan, shape=None):
        """Static-analysis suite certifying the fp8 engine: the overflow
        pass uses `FP8_K_CHUNK_LIMIT` for the digit dots (see
        repro.analysis.passes_for_backend)."""
        from ..analysis import passes_for_backend

        return passes_for_backend(self, plan, shape)

    def _shared(self):
        # lazy import: core stays importable without the Pallas stack
        from ..kernels.ops import KernelBackend

        return KernelBackend(self.interpret)

    def cast(self, x, e, axis, ctx, n_limbs):
        return self._shared().cast(x, e, axis, ctx, n_limbs)

    def cast_stack(self, xs, e, axis, ctx, n_limbs):
        return self._shared().cast_stack(xs, e, axis, ctx, n_limbs)

    def reconstruct(self, e_res, e_mu, e_nu, ctx, method, out_dtype):
        return self._shared().reconstruct(e_res, e_mu, e_nu, ctx, method, out_dtype)

    def reconstruct_stack(self, e_res, e_mu, e_nu, ctx, method, out_dtype):
        return self._shared().reconstruct_stack(
            e_res, e_mu, e_nu, ctx, method, out_dtype
        )

    def residue_matmul(self, ares, bres, ctx):
        """One batched fp8 launch per K-chunk (chunked at the f32 digit
        accumulator's exactness bound, not the int8 engine's int32 bound)."""
        from ..kernels.fp8_mod_gemm import FP8_K_CHUNK_LIMIT, fp8_mod_gemm_batched

        return chunked_residue_matmul(
            lambda a, b, carry: fp8_mod_gemm_batched(
                a, b, moduli=ctx.moduli, carry=carry, interpret=self.interpret
            ),
            ares,
            bres,
            ctx,
            carry_epilogue=True,
            chunk_limit=FP8_K_CHUNK_LIMIT,
        )

    def karatsuba(self, arr, ari, brr, bri, ctx):
        """Fused fp8 Karatsuba: the D/E/F digit triples all run in ONE
        launch per K-chunk (`fp8_karatsuba_mod_gemm_batched`, 9 f32
        accumulators in VMEM) instead of 3 composed products with host
        combines — bitwise identical, chunked at the fp8 digit bound."""
        from ..kernels.fp8_mod_gemm import (
            FP8_K_CHUNK_LIMIT,
            fp8_karatsuba_mod_gemm_batched,
        )

        return chunked_residue_matmul(
            lambda a, b, carry: fp8_karatsuba_mod_gemm_batched(
                a[0], a[1], b[0], b[1],
                moduli=ctx.moduli, carry=carry, interpret=self.interpret,
            ),
            (arr, ari),
            (brr, bri),
            ctx,
            carry_epilogue=True,
            chunk_limit=FP8_K_CHUNK_LIMIT,
        )


# ------------------------------------------------- composed complex embeds


def _block_a(backend, arr, ari, brr, bri, ctx):
    """eq. (7): [[AR,-AI],[AI,AR]] @ [BR;BI] = [CR;CI] — one GEMM of (2m,2k,n)."""
    top = jnp.concatenate([arr, -ari], axis=-1)
    bot = jnp.concatenate([ari, arr], axis=-1)
    ahat = jnp.concatenate([top, bot], axis=-2)  # (N, 2m, 2k)
    bhat = jnp.concatenate([brr, bri], axis=-2)  # (N, 2k, n)
    chat = backend.residue_matmul(ahat, bhat, ctx)  # (N, 2m, n) int8 residues
    m = arr.shape[-2]
    return chat[:, :m, :], chat[:, m:, :]


def _block_b(backend, arr, ari, brr, bri, ctx):
    """eq. (8): [AI,AR] @ [[BR,-BI],[BI,BR]] = [CI,CR] — one GEMM of (m,2k,2n)."""
    ahat = jnp.concatenate([ari, arr], axis=-1)  # (N, m, 2k)
    left = jnp.concatenate([brr, bri], axis=-2)  # (N, 2k, n)
    right = jnp.concatenate([-bri, brr], axis=-2)
    bhat = jnp.concatenate([left, right], axis=-1)  # (N, 2k, 2n)
    chat = backend.residue_matmul(ahat, bhat, ctx)
    n = brr.shape[-1]
    return chat[:, :, n:], chat[:, :, :n]


def _complex_product(backend, plan, arr, ari, brr, bri, ctx):
    if plan.formulation == "karatsuba":
        return backend.karatsuba(arr, ari, brr, bri, ctx)
    if plan.formulation == "block_a":
        return _block_a(backend, arr, ari, brr, bri, ctx)
    if plan.formulation == "block_b":
        return _block_b(backend, arr, ari, brr, bri, ctx)
    raise ValueError(f"unknown formulation {plan.formulation!r}")


# ================================================================ executor


def execute_plan(plan: EmulationPlan, a, b, backend=REFERENCE):
    """Run one 2D emulated GEMM per `plan`: C ~= A @ B, a: (m,k), b: (k,n)."""
    return (
        _execute_complex(plan, a, b, backend)
        if plan.is_complex
        else _execute_real(plan, a, b, backend)
    )


def _blocked_pipeline_real(plan, backend, ctx, e_mu, ares, e_nu, bres_slice, n):
    """The shared residue-GEMM -> reconstruct loop over output-column blocks.

    `bres_slice(sl)` yields the B-side residues for one block — freshly cast
    by the executor, or sliced out of a `PreparedOperand`.

    Backends exposing the `psum_partial`/`psum_combine` hooks (the sharded
    worker with a sharded residue axis) get the overlap-friendly two-phase
    structure: every block's residue product is issued before ANY partial is
    psummed, then ONE collective reduces the collected partial pytree, then
    the reconstructions run — so the collective is no longer serialized
    between consecutive blocks' products and XLA's async collectives can
    hide it behind them.  Bitwise identical (a pytree psum is the same
    per-leaf psum of exact f64 integer partials).
    """
    psum_partial = getattr(backend, "psum_partial", None)
    slices = list(plan.n_block_slices(n))
    if psum_partial is not None:
        partials = [
            psum_partial(backend.residue_matmul(ares, bres_slice(sl), ctx))
            for sl in slices
        ]
        planes = backend.psum_combine(partials)
        blocks = [
            backend.reconstruct_post(
                e_r, e_mu, e_nu[sl], ctx, plan.method, plan.real_out_dtype
            )
            for e_r, sl in zip(planes, slices)
        ]
        return (
            blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=1)
        )
    blocks = []
    for sl in slices:
        e_r = backend.residue_matmul(ares, bres_slice(sl), ctx)
        blocks.append(
            backend.reconstruct(
                e_r, e_mu, e_nu[sl], ctx, plan.method, plan.real_out_dtype
            )
        )
    return blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=1)


def _blocked_pipeline_complex(
    plan, backend, ctx, e_mu, arr, ari, e_nu, bres_slice, n
):
    """Complex twin of `_blocked_pipeline_real`; `bres_slice(sl)` yields the
    (brr, bri) residue pair for one output-column block.  The two-phase
    psum hooks apply to the stacked CR/CI partials the same way."""
    rdt = plan.real_out_dtype
    psum_partial = getattr(backend, "psum_partial", None)
    slices = list(plan.n_block_slices(n))
    if psum_partial is not None:
        partials = []
        for sl in slices:
            brr, bri = bres_slice(sl)
            er, ei = _complex_product(backend, plan, arr, ari, brr, bri, ctx)
            partials.append(psum_partial(jnp.stack([er, ei])))
        planes = backend.psum_combine(partials, stacked=True)
        blocks = []
        for full, sl in zip(planes, slices):
            out = backend.reconstruct_post_stack(
                full, e_mu, e_nu[sl], ctx, plan.method, rdt
            )
            blocks.append(jax.lax.complex(out[0], out[1]))
        return (
            blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=1)
        )
    blocks = []
    for sl in slices:
        brr, bri = bres_slice(sl)
        er, ei = _complex_product(backend, plan, arr, ari, brr, bri, ctx)
        cr, ci = _reconstruct_pair(
            backend, er, ei, e_mu, e_nu[sl], ctx, plan.method, rdt
        )
        blocks.append(jax.lax.complex(cr, ci))
    return blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=1)


# ------------------------------------------------------- fused megakernel


def _fused_pipeline_real(plan, backend, ctx, e_mu, a, e_nu, b_slice,
                         b_res_slice, n):
    """Real pipeline on a megakernel backend: ONE `fused_gemm` launch per
    output-column block (cast prologue + products + Garner epilogue all
    in-kernel).  `b_slice(sl)` yields the raw B block, or `b_res_slice(sl)`
    the pre-cast (N, k, n_blk) planes of a prepared operand."""
    blocks = []
    for sl in plan.n_block_slices(n):
        if b_res_slice is not None:
            out = backend.fused_gemm(
                a, None, e_mu, e_nu[sl], ctx, plan.n_limbs,
                plan.real_out_dtype, b_res=b_res_slice(sl),
            )
        else:
            out = backend.fused_gemm(
                a, b_slice(sl), e_mu, e_nu[sl], ctx, plan.n_limbs,
                plan.real_out_dtype,
            )
        blocks.append(out)
    return blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=1)


def _fused_complex_block(
    backend, plan, ctx, e_mu, ar, ai, e_nu_sl, b_blk, b_res_blk, nl, rdt
):
    """One output-column block of the fused complex pipeline -> (cr, ci).

    'karatsuba' runs the fused complex megakernel directly.  The block
    embeddings (paper eqs. 7/8) embed the RAW operands (or, prepared, the
    int8 residue planes) and run the real megakernel once: the residue cast
    commutes bitwise with negation (trunc and round are symmetric), so
    cast(-AI) equals the composed path's negated int8 planes exactly.
    """
    if plan.formulation == "karatsuba":
        if b_res_blk is not None:
            return backend.fused_karatsuba_gemm(
                ar, ai, None, None, e_mu, e_nu_sl, ctx, nl, rdt,
                b_res=b_res_blk,
            )
        return backend.fused_karatsuba_gemm(
            ar, ai, b_blk[0], b_blk[1], e_mu, e_nu_sl, ctx, nl, rdt
        )
    if plan.formulation == "block_a":
        # eq. (7): [[AR,-AI],[AI,AR]] @ [BR;BI] = [CR;CI]
        ahat = jnp.concatenate(
            [
                jnp.concatenate([ar, -ai], axis=-1),
                jnp.concatenate([ai, ar], axis=-1),
            ],
            axis=-2,
        )
        ehat = jnp.concatenate([e_mu, e_mu])
        if b_res_blk is not None:
            chat = backend.fused_gemm(
                ahat, None, ehat, e_nu_sl, ctx, nl, rdt,
                b_res=jnp.concatenate(b_res_blk, axis=-2),
            )
        else:
            bhat = jnp.concatenate(b_blk, axis=-2)
            chat = backend.fused_gemm(ahat, bhat, ehat, e_nu_sl, ctx, nl, rdt)
        m = ar.shape[-2]
        return chat[..., :m, :], chat[..., m:, :]
    if plan.formulation == "block_b":
        # eq. (8): [AI,AR] @ [[BR,-BI],[BI,BR]] = [CI,CR]
        ahat = jnp.concatenate([ai, ar], axis=-1)
        ehat_nu = jnp.concatenate([e_nu_sl, e_nu_sl])
        if b_res_blk is not None:
            brr, bri = b_res_blk
            bhat = jnp.concatenate(
                [
                    jnp.concatenate([brr, bri], axis=-2),
                    jnp.concatenate([-bri, brr], axis=-2),
                ],
                axis=-1,
            )
            chat = backend.fused_gemm(
                ahat, None, e_mu, ehat_nu, ctx, nl, rdt, b_res=bhat
            )
        else:
            br, bi = b_blk
            bhat = jnp.concatenate(
                [
                    jnp.concatenate([br, bi], axis=-2),
                    jnp.concatenate([-bi, br], axis=-2),
                ],
                axis=-1,
            )
            chat = backend.fused_gemm(ahat, bhat, e_mu, ehat_nu, ctx, nl, rdt)
        n = chat.shape[-1] // 2
        return chat[..., :, n:], chat[..., :, :n]
    raise ValueError(f"unknown formulation {plan.formulation!r}")


def _fused_pipeline_complex(
    plan, backend, ctx, e_mu, ar, ai, e_nu, b_slice, b_res_slice, n
):
    """Complex pipeline on a megakernel backend: one launch per block."""
    nl = plan.n_limbs
    rdt = plan.real_out_dtype
    blocks = []
    for sl in plan.n_block_slices(n):
        b_blk = None if b_res_slice is not None else b_slice(sl)
        b_res_blk = b_res_slice(sl) if b_res_slice is not None else None
        cr, ci = _fused_complex_block(
            backend, plan, ctx, e_mu, ar, ai, e_nu[sl], b_blk, b_res_blk,
            nl, rdt,
        )
        blocks.append(jax.lax.complex(cr, ci))
    return blocks[0] if len(blocks) == 1 else jnp.concatenate(blocks, axis=1)


def _accu_combines(backend):
    """Sharded backends expose `accu_row_combine` / `accu_col_combine`
    (lax.pmax over the n-/m-sharded mesh axes) so the accurate-mode bound
    maxima cover the whole output row/column, not just this shard's tile."""
    return (
        getattr(backend, "accu_row_combine", None),
        getattr(backend, "accu_col_combine", None),
    )


def _execute_real(plan, a, b, backend):
    ctx = plan.ctx
    if plan.mode == "fast":
        e_mu, e_nu = scaling.scale_fast_real(a, b, ctx)
    else:
        rc, cc = _accu_combines(backend)
        e_mu, e_nu = scaling.scale_accurate_real(a, b, ctx, rc, cc)
    nl = plan.n_limbs
    if getattr(backend, "megakernel", False):
        # fast AND accu mode: the scaling pass above is pallas-free, so the
        # whole emulated GEMM is the megakernel's single launch per block
        return _fused_pipeline_real(
            plan, backend, ctx, e_mu, a, e_nu,
            lambda sl: b[:, sl], None, b.shape[1],
        )
    ares = backend.cast(a, e_mu, 0, ctx, nl)
    return _blocked_pipeline_real(
        plan, backend, ctx, e_mu, ares, e_nu,
        lambda sl: backend.cast(b[:, sl], e_nu[sl], 1, ctx, nl),
        b.shape[1],
    )


def _execute_complex(plan, a, b, backend):
    ctx = plan.ctx
    ar, ai = jnp.real(a), jnp.imag(a)
    br, bi = jnp.real(b), jnp.imag(b)
    if plan.mode == "fast":
        e_mu, e_nu = scaling.scale_fast_complex(ar, ai, br, bi, ctx)
    else:
        rc, cc = _accu_combines(backend)
        e_mu, e_nu = scaling.scale_accurate_complex(ar, ai, br, bi, ctx, rc, cc)
    nl = plan.n_limbs
    if getattr(backend, "megakernel", False):
        return _fused_pipeline_complex(
            plan, backend, ctx, e_mu, ar, ai, e_nu,
            lambda sl: (br[:, sl], bi[:, sl]), None, b.shape[1],
        )
    arr, ari = _cast_pair(backend, ar, ai, e_mu, 0, ctx, nl)
    return _blocked_pipeline_complex(
        plan, backend, ctx, e_mu, arr, ari, e_nu,
        lambda sl: _cast_pair(backend, br[:, sl], bi[:, sl], e_nu[sl], 1, ctx, nl),
        b.shape[1],
    )


@functools.partial(
    jnp.vectorize, excluded=(2, 3), signature="(m,k),(k,n)->(m,n)"
)
def _run_plan_2d(a, b, plan, backend):
    return execute_plan(plan, a, b, backend)


def run_plan(plan: EmulationPlan, a, b, backend=REFERENCE):
    """Execute `plan` on (..., m, k) x (..., k, n), batched over leading dims.

    A backend may take over the whole execution by providing `run_plan`
    (the sharded backend does: it shard_maps `execute_plan` over the mesh
    with a per-shard worker, so batching/vectorize does not apply there).
    """
    runner = getattr(backend, "run_plan", None)
    if runner is not None:
        return runner(plan, a, b)
    return _run_plan_2d(a, b, plan, backend)


# ====================================================== prepared operands


class PreparedOperand:
    """Beyond-paper optimization: one-time residue-cast of a reused operand.

    In iterative solvers, repeated applications (C_i = A @ B_i with a fixed
    A) and weight-stationary serving (Y = X_i @ W), step 1 of the scheme
    (scaling + truncation + N residue planes of the fixed operand) can be
    computed once and amortized: the paper's step-1 memory term
    ((3N + 32 + c) k (m+n) / b) loses the prepared side's contribution
    entirely on every call after the first.  Scaling uses the fast
    (Cauchy-Schwarz) per-row/column bound, which is independent of the other
    operand — so `gemm_prepared` is bit-identical to the direct fast-mode
    pipeline.

    Accurate mode (``keep_raw=True``, done by `prepare_weights` for accu
    policies): the operand additionally stores its per-row/column 7-bit
    bound matrix (`bound`/`e_bound`, paper eqs. 13-14) — the only
    accurate-mode quantity that depends on one operand alone — plus the raw
    operand.  The residue planes themselves CANNOT be pre-cast for accu
    calls: the accurate exponents couple both operands through the
    auxiliary product `cbar = abar @ bbar`, so the truncation position of
    the prepared side depends on the streaming operand.  An accu-mode
    `gemm_prepared` therefore reuses the stored bound (bitwise what the
    direct pipeline recomputes) and re-casts from the raw operand per
    call.  Fast-mode operands skip both extras, staying exactly
    residue-planes-sized (and checkpoint-compatible with older saves).

    Supports real and complex operands, either side of the product
    (`side='left'` prepares A row-wise; `side='right'` prepares B
    column-wise) and leading batch dims (e.g. scan-stacked layer weights:
    a (L, k, n) weight yields residues (L, N, k, n), sliced per layer by
    `lax.scan` like any other parameter leaf).  Instances are registered as
    jax pytrees so they can live inside jitted parameter trees.

    `backend` selects who runs the residue cast (default: the jnp reference
    backend).  Preparing with the execution backend that will consume the
    residues keeps prepared and unprepared runs bit-identical on that
    backend — e.g. the Pallas kernel cast quantizes through f32, so a
    kernel-path server must prepare with the kernel backend (the policy
    layer's `prepare_weights` does this automatically).

    Example — prepare a weight once, multiply many times::

        >>> import jax.numpy as jnp
        >>> from repro.core import PreparedOperand, gemm_prepared
        >>> w = jnp.asarray([[1.0, 2.0], [4.0, 0.5], [8.0, 1.0]])  # (k, n)
        >>> prep = PreparedOperand(w, n_moduli=6, side="right")
        >>> prep.res.shape                    # N int8 residue planes of w
        (6, 3, 2)
        >>> x = jnp.eye(3, dtype=jnp.float64) * 2.0
        >>> y = gemm_prepared(prep, x)        # C ~= x @ w, w-side amortized
        >>> bool(jnp.all(y == 2.0 * w))       # exact: power-of-two operands
        True
    """

    def __init__(
        self, x, n_moduli: int | None = None, side: str = "left", backend=None,
        keep_raw: bool = False,
    ):
        if side not in ("left", "right"):
            raise ValueError(side)
        if backend is None:
            backend = REFERENCE
        dt = jnp.dtype(x.dtype)
        if n_moduli is None:
            from .plan import default_n_moduli

            n_moduli = default_n_moduli(dt, "fast")
        n_moduli = int(n_moduli)
        ctx = make_crt_context(n_moduli)
        nl = n_limbs_for_ctx(ctx)
        is_complex = jnp.issubdtype(dt, jnp.complexfloating)
        axis = 0 if side == "left" else 1
        evec = "(m)" if side == "left" else "(k)"

        # the two preparation flavours store disjoint things, because the
        # executions read disjoint things: fast-mode calls consume the
        # pre-cast residue planes (the amortization), accu-mode calls
        # consume the bound + raw operand and re-cast at the coupled
        # exponents.  Skipping the unused half keeps fast-mode operands
        # exactly residue-planes-sized (bit-compatible with older
        # checkpoints) and accu preparation free of a dead residue cast.
        e_scale = None
        res: list = []
        if not keep_raw:
            sig = f"(m,k)->{evec},(l,m,k)"
            if is_complex:

                @functools.partial(
                    jnp.vectorize, signature=f"(m,k)->{evec},(l,m,k),(l,m,k)"
                )
                def _prep(x2):
                    xr, xi = jnp.real(x2), jnp.imag(x2)
                    e = _solo_scale_complex(xr, xi, ctx, side)
                    rr, ri = _cast_pair(backend, xr, xi, e, axis, ctx, nl)
                    return e, rr, ri

                e_scale, *res = _prep(x)
            else:

                @functools.partial(jnp.vectorize, signature=sig)
                def _prep(x2):
                    e = _solo_scale_real(x2, ctx, side)
                    return e, backend.cast(x2, e, axis, ctx, nl)

                e_scale, *res = _prep(x)

        bound: tuple = ()
        e_bound = None
        if keep_raw:
            if is_complex:

                @functools.partial(
                    jnp.vectorize, signature=f"(m,k)->(m,k),(m,k),{evec}"
                )
                def _bound(x2):
                    bars, e_bar, _ = scaling.accu_bound_complex(
                        jnp.real(x2), jnp.imag(x2), side
                    )
                    return bars[0], bars[1], e_bar

                *bound, e_bound = _bound(x)
            else:

                @functools.partial(
                    jnp.vectorize, signature=f"(m,k)->(m,k),{evec}"
                )
                def _bound(x2):
                    bar, e_bar, _ = scaling.accu_bound_real(x2, side)
                    return bar, e_bar

                *bound, e_bound = _bound(x)

        self.side = side
        self.n_moduli = n_moduli
        self.n_limbs = nl
        self.dtype = dt.name
        self.e_scale = e_scale
        self.residues = tuple(res)
        self.bound = tuple(bound)
        self.e_bound = e_bound
        self.raw = jnp.asarray(x) if keep_raw else None

    # residues of the real part (kept under the historical name)
    @property
    def res(self):
        return self.residues[0]

    @property
    def is_complex(self) -> bool:
        return jnp.issubdtype(jnp.dtype(self.dtype), jnp.complexfloating)

    @property
    def mode(self) -> str:
        """The scaling mode this operand was prepared for, recorded by what
        it stores: fast preparation stores residue planes, accu preparation
        stores the 7-bit bound + raw operand (`keep_raw`).  Derived rather
        than carried in the pytree aux, so older fast-mode checkpoints
        round-trip unchanged.  The policy layer checks this against the
        (possibly adaptively resolved) calling policy and raises instead of
        returning silently wrong answers."""
        return "fast" if self.residues else "accu"

    @property
    def ctx(self) -> CRTContext:
        return make_crt_context(self.n_moduli)

    @property
    def batch_ndim(self) -> int:
        """Leading batch dims of the prepared operand (0 = a plain matrix)."""
        if self.residues:
            return self.residues[0].ndim - 3  # (.., L, m, k) planes
        return self.bound[0].ndim - 2  # (.., m, k) bound matrix

    @property
    def operand_shape(self) -> tuple[int, int]:
        """Logical (rows, cols) of the prepared operand (per batch element)."""
        arrs = self.residues if self.residues else self.bound
        return arrs[0].shape[-2:]

    def __repr__(self):
        return (
            f"PreparedOperand(side={self.side!r}, dtype={self.dtype}, "
            f"mode={self.mode!r}, n_moduli={self.n_moduli}, "
            f"shape={self.operand_shape})"
        )


def _prepared_flatten(p: PreparedOperand):
    children = (p.e_scale, p.residues, p.bound, p.e_bound, p.raw)
    return children, (p.side, p.n_moduli, p.n_limbs, p.dtype)


def _prepared_unflatten(aux, children):
    p = object.__new__(PreparedOperand)
    p.side, p.n_moduli, p.n_limbs, p.dtype = aux
    p.e_scale, res, bound, p.e_bound, p.raw = children
    p.residues = tuple(res)
    p.bound = tuple(bound)
    return p


jax.tree_util.register_pytree_node(
    PreparedOperand, _prepared_flatten, _prepared_unflatten
)


def _solo_scale_real(x, ctx, side):
    """Fast-mode exponent of one operand alone (dummy other operand)."""
    if side == "left":
        e, _ = scaling.scale_fast_real(x, jnp.zeros((x.shape[1], 1)), ctx)
    else:
        _, e = scaling.scale_fast_real(jnp.zeros((1, x.shape[0])), x, ctx)
    return e


def _solo_scale_complex(xr, xi, ctx, side):
    if side == "left":
        z = jnp.zeros((xr.shape[1], 1))
        e, _ = scaling.scale_fast_complex(xr, xi, z, z, ctx)
    else:
        z = jnp.zeros((1, xr.shape[0]))
        _, e = scaling.scale_fast_complex(z, z, xr, xi, ctx)
    return e


def _gemm_prepared_accu(prep, x, plan, backend):
    """Accurate-mode prepared product: reuse the stored 7-bit bound, re-cast
    from the raw operand at the call-time coupled exponents.

    The accurate exponents couple both operands (`cbar = abar @ bbar`), so
    the only amortizable step-1 work is the prepared side's bound matrix —
    this path computes exactly the operations of `_execute_real` /
    `_execute_complex` in the same order, sourcing (bar, e_bar) from the
    preparation, and is therefore bitwise identical to the unprepared accu
    run on every backend.
    """
    if prep.raw is None:
        raise ValueError(
            "accu-mode prepared matmuls re-cast from the raw operand (the "
            "accurate exponents couple both operands); prepare with "
            "keep_raw=True / prepare_weights(accu policy)"
        )
    ctx = prep.ctx
    nl = prep.n_limbs
    other = "left" if prep.side == "right" else "right"

    if prep.is_complex:
        xr, xi = jnp.real(x), jnp.imag(x)
        xbar, e_xbar, x_nz = scaling.accu_bound_complex(xr, xi, other)
        pbar, e_pbar = prep.bound, prep.e_bound
        p_nz = jnp.max(
            jnp.maximum(*[b.astype(jnp.int32) for b in pbar]),
            axis=1 if prep.side == "left" else 0,
        ) > 0
        wr, wi = jnp.real(prep.raw), jnp.imag(prep.raw)
        if prep.side == "left":
            cmax = scaling.accu_cbar_complex(pbar, xbar)
            e_mu, e_nu = scaling.accu_exponents(
                cmax, e_pbar, e_xbar, p_nz, x_nz, ctx
            )
            ar_, ai_ = wr, wi
            br_, bi_ = xr, xi
        else:
            cmax = scaling.accu_cbar_complex(xbar, pbar)
            e_mu, e_nu = scaling.accu_exponents(
                cmax, e_xbar, e_pbar, x_nz, p_nz, ctx
            )
            ar_, ai_ = xr, xi
            br_, bi_ = wr, wi
        if getattr(backend, "megakernel", False):
            # accu re-casts from raw anyway, so the fused prologue applies
            return _fused_pipeline_complex(
                plan, backend, ctx, e_mu, ar_, ai_, e_nu,
                lambda sl: (br_[:, sl], bi_[:, sl]), None, br_.shape[1],
            )
        arr, ari = _cast_pair(backend, ar_, ai_, e_mu, 0, ctx, nl)
        return _blocked_pipeline_complex(
            plan, backend, ctx, e_mu, arr, ari, e_nu,
            lambda sl: _cast_pair(
                backend, br_[:, sl], bi_[:, sl], e_nu[sl], 1, ctx, nl
            ),
            br_.shape[1],
        )

    xbar, e_xbar, x_nz = scaling.accu_bound_real(x, other)
    pbar, e_pbar = prep.bound[0], prep.e_bound
    p_nz = jnp.max(
        pbar.astype(jnp.int32), axis=1 if prep.side == "left" else 0
    ) > 0
    if prep.side == "left":
        cbar = int8_matmul(pbar, xbar)
        e_mu, e_nu = scaling.accu_exponents(
            cbar, e_pbar, e_xbar, p_nz, x_nz, ctx
        )
        a_, b_ = prep.raw, x
    else:
        cbar = int8_matmul(xbar, pbar)
        e_mu, e_nu = scaling.accu_exponents(
            cbar, e_xbar, e_pbar, x_nz, p_nz, ctx
        )
        a_, b_ = x, prep.raw
    if getattr(backend, "megakernel", False):
        return _fused_pipeline_real(
            plan, backend, ctx, e_mu, a_, e_nu,
            lambda sl: b_[:, sl], None, b_.shape[1],
        )
    ares = backend.cast(a_, e_mu, 0, ctx, nl)
    return _blocked_pipeline_real(
        plan, backend, ctx, e_mu, ares, e_nu,
        lambda sl: backend.cast(b_[:, sl], e_nu[sl], 1, ctx, nl),
        b_.shape[1],
    )


def gemm_prepared(
    prep: PreparedOperand,
    x: jnp.ndarray,
    method: str = "paper",
    formulation: str = "karatsuba",
    out_dtype=None,
    n_block=None,
    backend=REFERENCE,
    mode: str = "fast",
) -> jnp.ndarray:
    """Emulated product with one prepared side.

    side='left':  C ~= prep @ x   (x is B, cast per call)
    side='right': C ~= x @ prep   (x is A, cast per call)

    `formulation` (complex operands) accepts 'auto' and `n_block` accepts
    int | None | 'auto', resolved exactly as in the direct pipeline.

    Bit-identical to the direct pipeline in both modes.  mode='fast': the
    fast scaling bound of each operand is independent of the other, so the
    prepared exponents and residues match what the direct run computes and
    the prepared side's cast is skipped entirely.  mode='accu': the stored
    per-row/column bound replaces its recomputation, and the residue casts
    run per call at the coupled exponents (`_gemm_prepared_accu`).
    """
    ctx = prep.ctx
    if prep.batch_ndim != 0:
        raise ValueError(
            "gemm_prepared expects an unbatched (2D) prepared operand; "
            f"got a {prep.batch_ndim}-batched preparation of "
            f"shape {prep.operand_shape}"
        )
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    if prep.side == "left":
        m, k = prep.operand_shape
        n = x.shape[1]
    else:
        k, n = prep.operand_shape
        m = x.shape[0]
    plan = make_plan(
        prep.dtype,
        n_moduli=prep.n_moduli,
        mode=mode,
        method=method,
        formulation=formulation if prep.is_complex else None,
        out_dtype=out_dtype,
        n_block=n_block,
        shape=(m, k, n),
        # the 'auto' selections must charge launches and engine ops exactly
        # as the executing backend issues them, or a prepared run could pick
        # a different formulation than the unprepared run it must bit-match
        fused_karatsuba=getattr(backend, "fused_karatsuba", False),
        modulus_batched=getattr(backend, "modulus_batched", False),
        engine=getattr(backend, "engine", "int8"),
        megakernel=getattr(backend, "megakernel", False),
    )
    nl = prep.n_limbs
    other_side = "left" if prep.side == "right" else "right"

    if mode == "accu":
        return _gemm_prepared_accu(prep, x, plan, backend)
    if mode != "fast":
        raise ValueError(f"unknown mode {mode!r}")
    if not prep.residues:
        raise ValueError(
            "this operand was prepared for accu mode (bound + raw only); "
            "fast-mode calls consume pre-cast residue planes — re-prepare "
            "with prepare_weights(fast policy)"
        )

    # the fused megakernel casts the streaming side in its prologue and
    # consumes the prepared side's planes directly — one launch per block.
    # A LEFT-prepared fast operand stores planes but no raw matrix, and the
    # megakernel prologue needs the raw A tile, so side='left' falls through
    # to the composed kernel path the megakernel backend inherits.
    fused = getattr(backend, "megakernel", False) and prep.side == "right"

    if prep.is_complex:
        xr, xi = jnp.real(x), jnp.imag(x)
        e_other = _solo_scale_complex(xr, xi, ctx, other_side)
        if prep.side == "left":
            e_mu, e_nu = prep.e_scale, e_other
            arr, ari = prep.residues
            bres_slice = lambda sl: _cast_pair(  # noqa: E731
                backend, xr[:, sl], xi[:, sl], e_nu[sl], 1, ctx, nl
            )
        else:
            e_mu, e_nu = e_other, prep.e_scale
            if fused:
                return _fused_pipeline_complex(
                    plan, backend, ctx, e_mu, xr, xi, e_nu, None,
                    lambda sl: tuple(r[..., sl] for r in prep.residues), n,
                )
            arr, ari = _cast_pair(backend, xr, xi, e_mu, 0, ctx, nl)
            bres_slice = lambda sl: tuple(  # noqa: E731
                r[..., sl] for r in prep.residues
            )
        return _blocked_pipeline_complex(
            plan, backend, ctx, e_mu, arr, ari, e_nu, bres_slice, n
        )

    e_other = _solo_scale_real(x, ctx, other_side)
    if prep.side == "left":
        e_mu, e_nu, ares = prep.e_scale, e_other, prep.res
        bres_slice = lambda sl: backend.cast(  # noqa: E731
            x[:, sl], e_nu[sl], 1, ctx, nl
        )
    else:
        e_mu, e_nu = e_other, prep.e_scale
        if fused:
            return _fused_pipeline_real(
                plan, backend, ctx, e_mu, x, e_nu, None,
                lambda sl: prep.res[..., sl], n,
            )
        ares = backend.cast(x, e_mu, 0, ctx, nl)
        bres_slice = lambda sl: prep.res[..., sl]  # noqa: E731
    return _blocked_pipeline_real(
        plan, backend, ctx, e_mu, ares, e_nu, bres_slice, n
    )
