"""The paper's performance model (SIII-C), parameterized by hardware.

  t = mem_bytes(m, n, k, N, c, mode, prec) / b  +  int8_ops(...) / p

with b = sustained memory bandwidth (B/s) and p = int8 engine throughput
(OPS).  TFLOPS is reported as 8 m n k / t * 1e-12 (complex GEMM flops).

Hardware presets include the paper's GPUs and our TPU v5e target
(819 GB/s HBM, 394 TOPS int8 = 2x the 197 TFLOP/s bf16 MXU rate).

Beyond-paper terms live here too, because the 'auto' plan selections
(`formulation="auto"` / `n_block="auto"` in `core/plan.py`) must price them:

* an *engine axis* for the residue products: the int8 MXU path is the
  paper's model verbatim; the FP8 (e4m3) engine of `execution="fp8"`
  (arXiv:2603.10634) charges `ENGINE_OP_FACTOR["fp8"]` = 4 digit-GEMM
  volumes at the hardware's e4m3 rate (`HW.fp8_ops`, `engine_rate`), with
  unchanged memory terms (both engines move the same int8 residue planes).
  `select_engine` compares the two per shape;

* a *communication term* for `GemmPolicy(execution="sharded")` — the exact
  partial-reconstruction combine psums `crt_partial_parts(N)` f64 planes of
  the output over the residue mesh axis (`sharded_comm_time_s`), so plans
  for sharded GEMMs are selected on per-shard shapes plus that cost;
* the *kernel block-selection* rule (`select_block`) shared with
  `kernels/common.block_and_padded`: when a dimension is just above a block
  multiple (m=257 vs bm=256), the kernels shrink the block to the next
  smaller aligned size instead of padding ~2x, and the model sees the same
  padded shapes the kernels actually run (`padded_dim`).  `BLOCK_SHRINK`
  is the knob (tests flip it to measure the padding saved).
"""
from __future__ import annotations

import dataclasses

# Fixed per-GEMM-launch overhead (dispatch + epilogue barrier), used by the
# formulation auto-selection: Karatsuba issues 3N small GEMMs per product,
# the block embeddings one 4x-sized GEMM per modulus — at small m,n,k the
# launch term dominates and the embeddings win (paper Fig. 1 crossover).
# The modulus-batched Pallas kernels fold the N planes into one grid
# dimension, collapsing the per-modulus factor to 1 (`modulus_batched`).
# This module constant is the *preset* default; a calibrated `HW`
# (`HW.from_calibration`, `repro.tune`) carries the measured value in its
# `gemm_launch_s` field, which is what the model terms actually read.
GEMM_LAUNCH_S = 5e-6


# Fixed per-collective dispatch overhead (psum/all-gather launch + barrier),
# charged once per output-column block by the sharded execution (each block
# reconstructs — and therefore combines — separately).  Preset default of
# `HW.collective_launch_s`, same calibration story as `GEMM_LAUNCH_S`.
COLLECTIVE_LAUNCH_S = 2e-5


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    mem_bw: float          # B/s
    int8_ops: float        # OPS
    native_c64: float      # native CGEMM flop/s (for speedup comparisons)
    native_c128: float     # native ZGEMM flop/s
    # sustained per-device collective (all-reduce) bandwidth, B/s — the
    # denominator of the sharded-execution psum term.  Order-of-magnitude
    # presets (v5e: 4x ICI links); refine with the calibration microbench.
    ici_bw: float = 9e10
    # e4m3 MAC throughput (OPS) of the fp8 engine (`execution="fp8"`); 0.0
    # means "no native fp8 matmul" — the engine then runs at the upconvert
    # (bf16-grade) rate, approximated as int8_ops / 2.  NVIDIA/AMD parts
    # run e4m3 at the int8 rate; B200's fp8 tensor cores match its int8
    # dense rate; v5e has no fp8 MXU (v5p/v6 do).
    fp8_ops: float = 0.0
    # per-launch / per-collective dispatch overheads (s).  The presets keep
    # the historical module constants; `HW.from_calibration` replaces them
    # with values measured on the live backend (`repro.tune.calibrate`).
    gemm_launch_s: float = GEMM_LAUNCH_S
    collective_launch_s: float = COLLECTIVE_LAUNCH_S

    @classmethod
    def from_calibration(cls, meas, name: str = "calibrated") -> "HW":
        """An `HW` built from the `repro.tune.calibrate` measurement dict.

        Required keys: ``mem_bw`` (B/s) and ``int8_ops`` (OPS, mul+add
        counted separately — the model's `p`).  Optional keys fall back to
        the field defaults (`fp8_ops=0` = no native fp8; `ici_bw`, launch
        overheads = the preset constants), so a partial measurement — e.g.
        single-device hosts never measure psum bandwidth — still yields a
        usable model.  Zero/negative optional values are treated as "not
        measured".
        """
        def _opt(key, default):
            v = float(meas.get(key) or 0.0)
            return v if v > 0 else default

        return cls(
            name=name,
            mem_bw=float(meas["mem_bw"]),
            int8_ops=float(meas["int8_ops"]),
            native_c64=_opt("native_c64", 0.0),
            native_c128=_opt("native_c128", 0.0),
            ici_bw=_opt("ici_bw", 9e10),
            fp8_ops=_opt("fp8_ops", 0.0),
            gemm_launch_s=_opt("gemm_launch_s", GEMM_LAUNCH_S),
            collective_launch_s=_opt("collective_launch_s", COLLECTIVE_LAUNCH_S),
        )


TPU_V5E = HW("tpu-v5e", 819e9, 394e12, 197e12, 0.0)  # no native f64 at all
GH200 = HW("gh200", 4000e9, 1979e12, 67e12, 34e12, ici_bw=45e10,
           fp8_ops=1979e12)
B200 = HW("b200", 8000e9, 4500e12, 75e12, 37e12, ici_bw=90e10,
          fp8_ops=4500e12)
RTX5080 = HW("rtx5080", 960e9, 450e12, 56e12, 0.88e12, ici_bw=3e10,
             fp8_ops=450e12)
MI300X = HW("mi300x", 5300e9, 2615e12, 163e12, 163e12, ici_bw=45e10,
            fp8_ops=2615e12)

HARDWARE = {h.name: h for h in (TPU_V5E, GH200, B200, RTX5080, MI300X)}


def default_hw() -> HW:
    """The `HW` every ``hw=None`` model query prices against.

    The active calibration's *measured* hardware when a `repro.tune`
    calibration scope is live (`use_calibration` / `set_calibration` /
    a `GemmPolicy(calibration=...)` pin), else the TPU v5e preset — the
    historical default, so with no calibration present every 'auto'
    decision is bitwise identical to the pre-calibration behaviour.
    """
    # lazy import: tune depends on this module, not the other way around
    from ..tune.cache import current_calibration

    cal = current_calibration()
    return cal.hw if cal is not None else TPU_V5E


# ------------------------------------------------------------ engine terms

# MAC-volume multiplier of each residue-product engine, relative to the int8
# path's one (m,k,n) GEMM per plane.  The fp8 engine (e4m3 significand = 4
# bits < the 7-bit residues) splits every residue into two balanced base-16
# digits and runs HH + LL + the doubled-K cross GEMM — 4 digit-GEMM volumes
# per plane (`kernels/fp8_mod_gemm.py`).
ENGINE_OP_FACTOR = {"int8": 1.0, "fp8": 4.0}


def engine_rate(hw: HW, engine: str) -> float:
    """Sustained MAC throughput (OPS) of `engine` on `hw` (see `HW.fp8_ops`)."""
    if engine == "int8":
        return hw.int8_ops
    if engine == "fp8":
        return hw.fp8_ops if hw.fp8_ops > 0 else hw.int8_ops / 2.0
    raise ValueError(f"unknown engine {engine!r}")


ENGINES = tuple(ENGINE_OP_FACTOR)


def complex_time_s(
    m: int,
    n: int,
    k: int,
    n_moduli: int,
    hw: HW,
    mode: str = "fast",
    prec: str = "z",     # 'z' (complex128 in) | 'c' (complex64 in)
    c: float | None = None,
    engine: str = "int8",
) -> float:
    """Paper SIII-C total-time model for complex GEMM emulation.

    `engine` prices the residue-product MACs: 'int8' is the paper's model
    verbatim; 'fp8' charges `ENGINE_OP_FACTOR` digit-GEMM volumes at the
    e4m3 rate (the memory terms are unchanged — both engines move the same
    int8 residue planes; the digit split happens in-register).
    """
    N = n_moduli
    cc = float(c if c is not None else N)
    b, p = hw.mem_bw, engine_rate(hw, engine) / ENGINE_OP_FACTOR[engine]
    if mode == "fast":
        if prec == "z":
            mem = ((3 * N + 32 + cc) * k + 4) * (m + n) + (16 * N + 16 + 2 * cc) * m * n
        else:
            mem = ((3 * N + 16 + cc) * k + 4) * (m + n) + (16 * N + 8 + 2 * cc) * m * n
        ops = 6 * N * m * n * k
    elif mode == "accu":
        if prec == "z":
            mem = ((35 + 3 * N + cc) * k + 8) * (m + n) + (16 * N + 40 + 2 * cc) * m * n
        else:
            mem = ((19 + 3 * N + cc) * k + 8) * (m + n) + (16 * N + 32 + 2 * cc) * m * n
        ops = 6 * (N + 1) * m * n * k
    else:
        raise ValueError(mode)
    return mem / b + ops / p


def complex_tflops(m, n, k, n_moduli, hw: HW, mode="fast", prec="z", c=None,
                   engine="int8"):
    t = complex_time_s(m, n, k, n_moduli, hw, mode, prec, c, engine)
    return 8.0 * m * n * k / t * 1e-12


def real_time_s(m, n, k, n_moduli, hw: HW, mode="fast", prec="d", c=None,
                engine="int8"):
    """Real-GEMM variant ([30] + SIV-C): N engine GEMMs of (m,k,n)."""
    N = n_moduli
    cc = float(c if c is not None else N)
    b, p = hw.mem_bw, engine_rate(hw, engine) / ENGINE_OP_FACTOR[engine]
    in_bytes = 8 if prec == "d" else 4
    mem = ((N + 2 * in_bytes + cc) * k + 2) * (m + n) + (6 * N + in_bytes + 2 * cc) * m * n
    ops = 2 * (N if mode == "fast" else N + 1) * m * n * k
    return mem / b + ops / p


def real_tflops(m, n, k, n_moduli, hw: HW, mode="fast", prec="d", c=None,
                engine="int8"):
    t = real_time_s(m, n, k, n_moduli, hw, mode, prec, c, engine)
    return 2.0 * m * n * k / t * 1e-12


def crt_partial_parts(n_moduli: int) -> int:
    """Number of exact f64 part-planes the sharded combine psums per output
    element (the `core/crt.partial_split` width for the default moduli)."""
    from .crt import partial_split
    from .moduli import default_moduli

    return partial_split(default_moduli(n_moduli))[0].shape[0]


def sharded_comm_time_s(
    m: int,
    n: int,
    n_moduli: int,
    residue_shards: int,
    hw: HW | None = None,
    complex_: bool = False,
    n_blocks: int = 1,
) -> float:
    """Communication term of one sharded emulated GEMM (per-shard m, n).

    The residue-sharded pipeline communicates exactly one thing: the psum of
    the `crt_partial_parts(N)` exact f64 partial-reconstruction planes over
    the residue axis (complex outputs stack CR/CI, 2x).  No int8 residue
    plane ever crosses the mesh — that invariant is CI-asserted against the
    traced jaxpr.  Ring all-reduce moves ~(r-1)/r of the payload per device.
    """
    if residue_shards <= 1:
        return 0.0
    hw = hw or default_hw()
    parts = crt_partial_parts(n_moduli)
    stack = 2 if complex_ else 1
    byts = parts * 8 * m * n * stack * (residue_shards - 1) / residue_shards
    return n_blocks * hw.collective_launch_s + byts / hw.ici_bw


def formulation_time_s(
    formulation: str,
    m: int,
    n: int,
    k: int,
    n_moduli: int,
    hw: HW,
    mode: str = "fast",
    prec: str = "z",
    karatsuba_launches: int = 3,
    modulus_batched: bool = False,
    megakernel: bool = False,
    comm_s: float = 0.0,
    engine: str = "int8",
) -> float:
    """SIII-C time model specialized per Fig. 1 complex-product strategy.

    `complex_time_s` assumes the Karatsuba op count (6 N m n k int8 ops);
    the block embeddings (eqs. 7/8) do 4 real products worth (8 N m n k) and
    additionally materialize the embedded operands in HBM, but need only one
    GEMM launch per modulus.  Accu mode prices one extra modulus plane
    (matching `complex_time_s`'s 6(N+1) op count) in every per-plane term.
    `karatsuba_launches` is per modulus-plane-group: 3 for the composed
    reference path, 1 when the backend fuses the D/E/F triple into one
    kernel (`kernels/karatsuba_fused.py`).  `modulus_batched` collapses the
    per-modulus launch factor to 1 (the batched kernels run all N planes in
    one grid), leaving only the op/byte terms to scale with N.  `megakernel`
    (the `execution='fused'` single-launch path) collapses the launch term of
    *every* strategy to exactly one `GEMM_LAUNCH_S` — cast, products and
    reconstruction share one kernel — so the selection degenerates to the
    op/byte terms (the block embeddings still pay their HBM embed traffic
    and 8N-vs-6N op volume).  `comm_s` is
    the sharded execution's collective cost (`sharded_comm_time_s`, charged
    on the per-shard shape the caller passes) — the same for every strategy
    today, but kept in the totals so sharded 'auto' selections model what
    actually runs.  `engine` prices every MAC term at that engine's rate and
    volume factor ('fp8': 4 digit-GEMM volumes at the e4m3 rate,
    `ENGINE_OP_FACTOR`/`engine_rate`), so an fp8 policy's launch-vs-compute
    crossover shifts with e4m3 throughput.
    """
    neff = n_moduli if mode == "fast" else n_moduli + 1
    launch_planes = 1 if modulus_batched else neff
    base = complex_time_s(m, n, k, n_moduli, hw, mode, prec, engine=engine) + comm_s
    if formulation == "karatsuba":
        if megakernel:
            return base + hw.gemm_launch_s
        return base + karatsuba_launches * launch_planes * hw.gemm_launch_s
    # 8N mnk vs the model's 6N, charged at the engine's effective rate
    extra_ops = (
        2 * neff * m * n * k
        * ENGINE_OP_FACTOR[engine] / engine_rate(hw, engine)
    )
    if formulation == "block_a":
        embed_bytes = 2 * neff * (4 * m * k + 2 * k * n)  # write+read Ahat/Bhat
    elif formulation == "block_b":
        embed_bytes = 2 * neff * (2 * m * k + 4 * k * n)
    else:
        raise ValueError(f"unknown formulation {formulation!r}")
    launches = 1 if megakernel else launch_planes
    return (
        base + extra_ops + embed_bytes / hw.mem_bw
        + launches * hw.gemm_launch_s
    )


def select_formulation(
    m: int,
    n: int,
    k: int,
    n_moduli: int,
    hw: HW | None = None,
    mode: str = "fast",
    prec: str = "z",
    karatsuba_launches: int = 3,
    modulus_batched: bool = False,
    megakernel: bool = False,
    comm_s: float = 0.0,
    engine: str = "int8",
) -> str:
    """Pick the fastest Fig. 1 complex-product strategy under the SIII-C
    model (used by `core/plan.py` for ``formulation='auto'``).  Sharded
    callers pass per-shard (m, n) and their `sharded_comm_time_s` so the
    launch-vs-compute crossover reflects the local problem each shard runs;
    fp8 policies pass ``engine="fp8"`` so the crossover reflects the e4m3
    engine's op volume and rate; megakernel (`execution='fused'`) policies
    charge one launch per strategy, so only op/byte terms differentiate.
    ``hw=None`` prices against `default_hw()` — the active calibration's
    measured hardware, else the TPU v5e preset.
    """
    hw = hw or default_hw()
    return min(
        ("karatsuba", "block_a", "block_b"),
        key=lambda f: formulation_time_s(
            f, m, n, k, n_moduli, hw, mode, prec,
            karatsuba_launches, modulus_batched, megakernel, comm_s, engine,
        ),
    )


def engine_time_s(
    engine: str,
    m: int,
    n: int,
    k: int,
    n_moduli: int,
    hw: HW | None = None,
    mode: str = "fast",
    prec: str = "z",
    complex_: bool | None = None,
) -> float:
    """Total SIII-C time of one emulated GEMM on `engine` ('int8' | 'fp8').

    `prec` follows the model conventions: 'c'/'z' for complex (the default),
    's'/'d' for real.  Used by `select_engine` and the throughput benchmark
    to compare the two engines per shape on one hardware preset.
    """
    hw = hw or default_hw()
    if complex_ is None:
        complex_ = prec in ("c", "z")
    if complex_:
        return complex_time_s(m, n, k, n_moduli, hw, mode, prec, engine=engine)
    return real_time_s(
        m, n, k, n_moduli, hw, mode, "d" if prec in ("z", "d") else "s",
        engine=engine,
    )


def select_engine(
    m: int,
    n: int,
    k: int,
    n_moduli: int,
    hw: HW | None = None,
    mode: str = "fast",
    prec: str = "z",
) -> str:
    """The faster residue-product engine for this shape under the SIII-C
    model: 'fp8' wins exactly when its rate advantage beats its 4x digit-MAC
    volume (e.g. hardware whose e4m3 rate is >4x its int8 rate, or
    memory-bound shapes where the op term hardly matters)."""
    hw = hw or default_hw()
    return min(
        ENGINES, key=lambda e: engine_time_s(e, m, n, k, n_moduli, hw, mode, prec)
    )


def select_mode(
    m: int,
    n: int,
    k: int,
    candidates,
    hw: HW | None = None,
    prec: str = "z",
    engine: str = "int8",
) -> tuple[str, int]:
    """Cheapest (mode, n_moduli) pair among ``candidates`` (SIII-C model).

    The accuracy-adaptive resolver (`GemmPolicy(rtol=...)` / ``mode="auto"``)
    computes the *admissible* pairs from `core.accuracy.min_moduli_for` and
    hands them here, so "auto" means: the cheapest plan on this machine —
    `default_hw()` returns the live `repro.tune` calibration when one is
    active — that provably meets the tolerance.  Ties keep the earlier
    candidate (callers list 'fast' first)."""
    hw = hw or default_hw()
    cands = list(candidates)
    if not cands:
        raise ValueError("select_mode needs at least one (mode, n_moduli) candidate")
    best = cands[0]
    best_t = float("inf")
    for mode, n_moduli in cands:
        t = engine_time_s(engine, m, n, k, n_moduli, hw, mode, prec)
        if t < best_t:
            best, best_t = (mode, n_moduli), t
    return best


def kernel_launch_count(
    n_moduli: int,
    formulation: str = "real",
    *,
    modulus_batched: bool = True,
    fused_karatsuba: bool = True,
    n_chunks: int = 1,
    n_blocks: int = 1,
    prepared: bool = False,
    fused: bool = False,
) -> int:
    """Pallas-launch count of one emulated GEMM on the kernel path.

    The batched backend (`modulus_batched=True`) issues exactly one
    `pallas_call` per cast (complex operands stack real+imag into one), one
    per modular product per K-chunk, and one per reconstruction (CR/CI
    stacked) — 2 + n_chunks + 1 per output-column block at any N.  The
    per-modulus backend pays a factor N on products, 2x on complex casts /
    reconstructions, and 3x on unfused Karatsuba.  `prepared=True` drops the
    weight-side cast entirely (its residue planes were cast once up front by
    `prepare_weights` / `PreparedOperand` — the serving fast path), leaving
    cast + product + reconstruct = 3 launches per GEMM.

    `fused=True` is the `execution='fused'` megakernel: the residue casts
    run as the kernel prologue, Garner reconstruction as its epilogue, and
    the K-chunk carry loop becomes an in-kernel grid dimension — so the
    whole GEMM is exactly one `pallas_call` per output-column block,
    regardless of n_moduli, mode, formulation or K-chunking:

        path                    batched kernel      fused megakernel
        fast real/complex       4  (2+1+1)          1
        prepared fast (right)   3  (1+1+1)          1
        K-chunked (c chunks)    3 + c               1

    Asserted against the actually-traced jaxpr in tests and the CI smoke
    benchmark.
    """
    if fused:
        return n_blocks
    planes = 1 if modulus_batched else n_moduli
    complex_ = formulation != "real"
    per_part = 1 if modulus_batched else 2  # real+imag stacked vs separate
    cast_a = per_part if complex_ else 1
    cast_b = 0 if prepared else (per_part if complex_ else 1)
    if formulation == "karatsuba":
        products = (1 if fused_karatsuba else 3) * planes * n_chunks
    else:  # 'real' or a block embedding: one real product per chunk
        products = planes * n_chunks
    reconstructs = per_part if complex_ else 1
    return cast_a + n_blocks * (cast_b + products + reconstructs)


# --------------------------------------------- kernel block selection (pads)

# Knob for the just-over-a-multiple block shrink: when a GEMM dimension is
# barely above a block multiple (m=257 with bm=256), padding to the next
# block multiple wastes ~2x compute/memory; shrinking the block to the next
# smaller aligned size pads far less (257 -> 384 at bm=128 instead of 512).
# The kernels (`kernels/common.block_and_padded`) and this model share the
# single `select_block` rule, so perfmodel-visible padded shapes are exactly
# what the kernels launch.  Setting BLOCK_SHRINK = False restores the
# legacy round-up-to-the-default-block behaviour.
BLOCK_SHRINK = True


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def select_block(dim: int, block: int, align: int | None = None) -> int:
    """Block size one kernel axis actually uses for `dim` (default `block`).

    dim <= block: the block shrinks to the axis (single block, no padding —
    the pre-existing rule; this includes dims below the hardware alignment,
    where the padded extent is the dim itself).  dim > block: with
    BLOCK_SHRINK on and a hardware alignment given, scan the *align-multiple*
    block sizes <= block and keep the one whose padded dim
    (`_round_up(dim, b)`) is smallest, preferring the largest such block
    (fewer grid steps).  `block` itself is always a candidate — even when it
    is not an align multiple (autotuned or caller-chosen blocks feed this
    same path) — so the padded dim never exceeds the static round-up
    `_round_up(dim, block)`.

    Invariants (hypothesis-checked in tests/test_property.py): the selected
    block always divides `padded_dim(dim, block, align)`, and that padded
    dim never exceeds the legacy round-up to `block`.
    """
    if dim <= 0:
        raise ValueError(f"dim must be positive, got {dim}")
    if block <= 0:
        raise ValueError(f"block must be positive, got {block}")
    if dim <= block:
        return dim
    if not BLOCK_SHRINK or align is None or block <= align:
        return block
    best, best_pad = block, _round_up(dim, block)
    # largest align multiple <= block (strictly below it when block is
    # itself an align multiple — that case is already `best`)
    start = block // align * align
    if start == block:
        start -= align
    for b in range(start, align - 1, -align):
        pad = _round_up(dim, b)
        if pad < best_pad:
            best, best_pad = b, pad
    return best


def padded_dim(dim: int, block: int, align: int | None = None) -> int:
    """The padded extent a kernel axis runs at under `select_block`."""
    return _round_up(dim, select_block(dim, block, align))


def ozaki1_complex_time_s(m, n, k, slices: int, hw: HW) -> float:
    """Ozaki-I cost shape (SIV-B): S(S+1)/2 int8 complex products, each a
    Karatsuba triple => 3*S(S+1)/2 real int8 GEMMs (memory terms omitted —
    used only for the >=algorithmic-factor comparison)."""
    s = slices
    return (3 * s * (s + 1) / 2) * 2 * m * n * k / hw.int8_ops
