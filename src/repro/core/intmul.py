"""Exact int8 x int8 -> int32 matrix multiplication (the MXU workhorse).

On TPU this is a single MXU pass (`preferred_element_type=int32`); on the CPU
host XLA lowers it to integer dot.  Exactness requires
k * 127^2 < 2^31  =>  k <= 2^17 (paper SII assumption); callers chunk K above
that (`core/gemm.py`), reducing mod p between chunks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .moduli import K_CHUNK_LIMIT


def int8_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(..., m, k) x (..., k, n) int8 -> int32, exact. Batched over leading dims."""
    if a.shape[-1] > K_CHUNK_LIMIT:
        raise ValueError(
            f"k={a.shape[-1]} exceeds exact-int32 limit {K_CHUNK_LIMIT}; chunk K"
        )
    batch = tuple(range(a.ndim - 2))
    return jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((a.ndim - 1,), (b.ndim - 2,)), (batch, batch)),
        preferred_element_type=jnp.int32,
    )
