"""Scaling-vector determination (Alg. 1 step III; paper SIII-B).

Two modes, both for real and complex operands:

* fast  — Cauchy-Schwarz bound on the row/column 2-norms of the block
          embedding (paper eqs. 11-12).  One pass over A and B.
* accu  — auxiliary 7-bit int8 product bounds sum_h |a'||b'| directly
          (paper eqs. 13-14).  Tighter => fewer moduli for target accuracy.

All scale factors are exact powers of two; we carry their integer exponents
(the paper stores them as INT16) and materialize mu = 2^e via ldexp (exact).

GPU->TPU adaptation: the paper bounds CUDA's __log2f error with
delta = 0.5/(1-4u) in round-down/round-up mode; we use f64 log2 with an
explicit safety factor DELTA = 0.5*(1+2^-40) and floor() — same contract
(the computed bound always over-estimates log2 of the true norm).
"""
from __future__ import annotations

import jax.numpy as jnp

from .intmul import int8_matmul
from .moduli import CRTContext

DELTA = 0.5 * (1.0 + 2.0**-40)
_F64 = jnp.float64


def ilogb(x: jnp.ndarray) -> jnp.ndarray:
    """floor(log2 |x|) for x > 0, exact (frexp-based; paper uses ilogb())."""
    _, e = jnp.frexp(x)
    return (e - 1).astype(jnp.int32)


def _p_fast(ctx: CRTContext) -> float:
    # P'_fast = (log2(P-1) - 1)/2 - 1  (precomputed host-side)
    return (ctx.log2_P - 1.0) / 2.0 - 1.0


def _p_accu(ctx: CRTContext) -> float:
    # P'_accu = log2(P-1)/2 - 0.5
    return ctx.log2_P / 2.0 - 0.5


def _exp2i(e: jnp.ndarray) -> jnp.ndarray:
    return jnp.ldexp(jnp.asarray(1.0, dtype=_F64), e.astype(jnp.int32))


def _fast_exponent(
    absmax: jnp.ndarray, norm2_scaled: jnp.ndarray, ctx: CRTContext
) -> jnp.ndarray:
    """floor(P'fast - max(1, delta*log2(sum a_hat^2))) - ilogb(max|a|).

    `norm2_scaled` is sum of (a * 2^-ilogb(max))^2 per row/col, in [1, 4k] —
    the explicit normalization that the paper folds into __log2f range
    reduction.  Zero rows get exponent 0 (mu = 1).
    """
    e_max = ilogb(jnp.where(absmax > 0, absmax, 1.0))
    t = jnp.maximum(norm2_scaled, 1.0)
    bound = jnp.maximum(1.0, DELTA * jnp.log2(t))
    e = jnp.floor(_p_fast(ctx) - bound).astype(jnp.int32) - e_max
    return jnp.where(absmax > 0, e, 0).astype(jnp.int32)


def scale_fast_real(a: jnp.ndarray, b: jnp.ndarray, ctx: CRTContext):
    """Returns integer exponents (e_mu[m], e_nu[n]); mu = 2^e_mu etc."""
    a = a.astype(_F64)
    b = b.astype(_F64)
    amax = jnp.max(jnp.abs(a), axis=1)
    bmax = jnp.max(jnp.abs(b), axis=0)
    an = a * _exp2i(-ilogb(jnp.where(amax > 0, amax, 1.0)))[:, None]
    bn = b * _exp2i(-ilogb(jnp.where(bmax > 0, bmax, 1.0)))[None, :]
    e_mu = _fast_exponent(amax, jnp.sum(an * an, axis=1), ctx)
    e_nu = _fast_exponent(bmax, jnp.sum(bn * bn, axis=0), ctx)
    return e_mu, e_nu


def scale_fast_complex(ar, ai, br, bi, ctx: CRTContext):
    """Complex fast mode: block embedding (eq. 6) makes row i and i+m of
    A-hat share norms, so mu stays an m-vector (paper SIII-B)."""
    ar, ai = ar.astype(_F64), ai.astype(_F64)
    br, bi = br.astype(_F64), bi.astype(_F64)
    amax = jnp.maximum(jnp.max(jnp.abs(ar), axis=1), jnp.max(jnp.abs(ai), axis=1))
    bmax = jnp.maximum(jnp.max(jnp.abs(br), axis=0), jnp.max(jnp.abs(bi), axis=0))
    sa = _exp2i(-ilogb(jnp.where(amax > 0, amax, 1.0)))[:, None]
    sb = _exp2i(-ilogb(jnp.where(bmax > 0, bmax, 1.0)))[None, :]
    na = jnp.sum((ar * sa) ** 2 + (ai * sa) ** 2, axis=1)
    nb = jnp.sum((br * sb) ** 2 + (bi * sb) ** 2, axis=0)
    e_mu = _fast_exponent(amax, na, ctx)
    e_nu = _fast_exponent(bmax, nb, ctx)
    return e_mu, e_nu


def _bar_int8(x_abs: jnp.ndarray, e_bar: jnp.ndarray, axis: int) -> jnp.ndarray:
    """ceil(|x| * 2^e_bar) as int8 (<= 64; 7-bit upper-bound matrix)."""
    shape = [1] * x_abs.ndim
    shape[axis] = -1
    v = jnp.ceil(x_abs * _exp2i(e_bar).reshape(shape))
    return jnp.clip(v, 0, 127).astype(jnp.int8)


def _accu_exponent(cbar_max: jnp.ndarray, e_bar: jnp.ndarray, ctx: CRTContext):
    t = jnp.maximum(cbar_max.astype(_F64), 1.0)
    e = jnp.floor(_p_accu(ctx) - DELTA * jnp.log2(t)).astype(jnp.int32)
    return e + e_bar


def accu_bound_real(x: jnp.ndarray, side: str):
    """One operand's accurate-mode 7-bit bound: (bar, e_bar, nonzero).

    side='left' bounds rows of A, side='right' columns of B.  This is the
    only accurate-mode quantity that depends on one operand alone, which is
    why `PreparedOperand` can store it (the exponents themselves couple both
    operands through `cbar` and must be recomputed per call).
    """
    x = x.astype(_F64)
    xmax = jnp.max(jnp.abs(x), axis=1 if side == "left" else 0)
    # scale so the max-abs integer part fits 6 bits: max*2^e in [32, 64)
    e_bar = 5 - ilogb(jnp.where(xmax > 0, xmax, 1.0))
    bar = _bar_int8(jnp.abs(x), e_bar, 0 if side == "left" else 1)
    return bar, e_bar, xmax > 0


def accu_bound_complex(xr: jnp.ndarray, xi: jnp.ndarray, side: str):
    """Complex twin of `accu_bound_real`: ((bar_r, bar_i), e_bar, nonzero)."""
    xr, xi = xr.astype(_F64), xi.astype(_F64)
    red = 1 if side == "left" else 0
    xmax = jnp.maximum(
        jnp.max(jnp.abs(xr), axis=red), jnp.max(jnp.abs(xi), axis=red)
    )
    e_bar = 5 - ilogb(jnp.where(xmax > 0, xmax, 1.0))
    axis = 0 if side == "left" else 1
    bar_r = _bar_int8(jnp.abs(xr), e_bar, axis)
    bar_i = _bar_int8(jnp.abs(xi), e_bar, axis)
    return (bar_r, bar_i), e_bar, xmax > 0


def accu_cbar_complex(abar, bbar) -> jnp.ndarray:
    """Paper SIII-B accurate mode: Cbar_I = AbarI BbarR + AbarR BbarI,
    Cbar_R = Cbar_I + (AbarR - AbarI)(BbarR - BbarI); returns max(R, I)."""
    abar_r, abar_i = abar
    bbar_r, bbar_i = bbar
    cbar_i = int8_matmul(abar_i, bbar_r) + int8_matmul(abar_r, bbar_i)
    # (AbarR - AbarI) etc. are error-free in int8 (values in [-64, 64])
    cbar_r = cbar_i + int8_matmul(abar_r - abar_i, bbar_r - bbar_i)
    return jnp.maximum(cbar_r, cbar_i)


def accu_exponents(
    cbar, e_abar, e_bbar, a_nz, b_nz, ctx: CRTContext,
    row_combine=None, col_combine=None,
):
    """cbar bound -> (e_mu, e_nu) integer exponents.

    `row_combine` / `col_combine` are optional collectives for sharded
    execution: cbar's row max only covers this shard's output columns (and
    the col max this shard's rows), so a shard combines them (`lax.pmax`,
    exact on int32) across the n- and m-sharded mesh axes before the
    exponent formula.  With both None this is exactly the paper's
    single-device computation.
    """
    rmax = jnp.max(cbar, axis=1)
    cmax = jnp.max(cbar, axis=0)
    if row_combine is not None:
        rmax = row_combine(rmax)
    if col_combine is not None:
        cmax = col_combine(cmax)
    e_mu = _accu_exponent(rmax, e_abar, ctx)
    e_nu = _accu_exponent(cmax, e_bbar, ctx)
    return jnp.where(a_nz, e_mu, 0), jnp.where(b_nz, e_nu, 0)


def scale_accurate_real(
    a: jnp.ndarray, b: jnp.ndarray, ctx: CRTContext,
    row_combine=None, col_combine=None,
):
    abar, e_abar, a_nz = accu_bound_real(a, "left")
    bbar, e_bbar, b_nz = accu_bound_real(b, "right")
    cbar = int8_matmul(abar, bbar)  # exact upper bound of sum mu|a| nu|b|
    return accu_exponents(
        cbar, e_abar, e_bbar, a_nz, b_nz, ctx, row_combine, col_combine
    )


def scale_accurate_complex(
    ar, ai, br, bi, ctx: CRTContext, row_combine=None, col_combine=None
):
    abar, e_abar, a_nz = accu_bound_complex(ar, ai, "left")
    bbar, e_bbar, b_nz = accu_bound_complex(br, bi, "right")
    cmax = accu_cbar_complex(abar, bbar)
    return accu_exponents(
        cmax, e_abar, e_bbar, a_nz, b_nz, ctx, row_combine, col_combine
    )


def exp2_vector(e: jnp.ndarray) -> jnp.ndarray:
    """Materialize the power-of-two scale vector from integer exponents."""
    return _exp2i(e)
