"""Ozaki-II CRT GEMM emulation — core API (the paper's contribution).

The numeric pipeline lives once in `plan.py` (static decisions) +
`executor.py` (data path, pluggable residue backends).  `GemmPolicy`
(`policy.py`) is the one public knob object — backend (compute dtype
class), mode, formulation, blocking, and the *execution* axis selecting the
residue backend ("reference" | "kernel" | "per_modulus_kernel" | "sharded"
| "fp8").  The user-facing entry point is `repro.linalg.matmul`
scoped by `repro.use_policy(policy)`; the `ozaki2_gemm` / `ozaki2_cgemm`
wrappers retained here are deprecation shims over that route.
"""
from .accuracy import GemmStats, min_moduli_for, probe_operands, rel_bound, rel_error
from .cgemm import ozaki2_cgemm
from .executor import (
    Fp8Backend,
    PreparedOperand,
    REFERENCE,
    ReferenceBackend,
    execute_plan,
    gemm_prepared,
    run_plan,
)
from .gemm import default_n_moduli, ozaki2_gemm
from .moduli import CRTContext, default_moduli, make_crt_context, min_moduli_for_bits
from .plan import DEFAULT_MODULI, DEFAULT_N_BLOCK, EmulationPlan, make_plan
from .policy import (
    GemmPolicy,
    NATIVE,
    emulated_matmul,
    policy_matmul,
    prepare_weights,
)

__all__ = [
    "CRTContext",
    "DEFAULT_MODULI",
    "DEFAULT_N_BLOCK",
    "EmulationPlan",
    "Fp8Backend",
    "GemmPolicy",
    "GemmStats",
    "NATIVE",
    "PreparedOperand",
    "REFERENCE",
    "ReferenceBackend",
    "default_moduli",
    "default_n_moduli",
    "emulated_matmul",
    "execute_plan",
    "gemm_prepared",
    "make_crt_context",
    "make_plan",
    "min_moduli_for",
    "min_moduli_for_bits",
    "ozaki2_cgemm",
    "ozaki2_gemm",
    "policy_matmul",
    "prepare_weights",
    "probe_operands",
    "rel_bound",
    "rel_error",
    "run_plan",
]
