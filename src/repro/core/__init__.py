"""Ozaki-II CRT GEMM emulation — public API (the paper's contribution)."""
from .cgemm import ozaki2_cgemm
from .gemm import PreparedOperand, default_n_moduli, gemm_prepared, ozaki2_gemm
from .moduli import CRTContext, default_moduli, make_crt_context, min_moduli_for_bits
from .policy import GemmPolicy, NATIVE, emulated_matmul, policy_matmul

__all__ = [
    "CRTContext",
    "GemmPolicy",
    "NATIVE",
    "PreparedOperand",
    "default_moduli",
    "default_n_moduli",
    "emulated_matmul",
    "gemm_prepared",
    "make_crt_context",
    "min_moduli_for_bits",
    "ozaki2_cgemm",
    "ozaki2_gemm",
    "policy_matmul",
]
