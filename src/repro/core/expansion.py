"""Error-free floating-point transformations (Dekker/Knuth) used by the CRT
reconstruction (paper eq. (5) + the double-double `mod P` step).

All functions are dtype-generic (f32 on TPU, f64 on the CPU host) and built
only from +,-,* so XLA keeps them exact (no unsafe reassociation).
"""
from __future__ import annotations

import jax.numpy as jnp

_SPLITTERS = {
    jnp.dtype("float32"): 4097.0,        # 2^12 + 1
    jnp.dtype("float64"): 134217729.0,   # 2^27 + 1
}


def two_sum(a, b):
    """s + e == a + b exactly, s = fl(a+b)."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def quick_two_sum(a, b):
    """Requires |a| >= |b|. s + e == a + b exactly."""
    s = a + b
    e = b - (s - a)
    return s, e


def _split(a):
    c = _SPLITTERS[jnp.dtype(a.dtype)] * a
    hi = c - (c - a)
    lo = a - hi
    return hi, lo


def two_prod(a, b):
    """p + e == a * b exactly (Dekker; no FMA dependence)."""
    p = a * b
    ah, al = _split(a)
    bh, bl = _split(b)
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


def dd_add(xh, xl, yh, yl):
    """Double-double addition (Dekker add2, ~106-bit f64 / ~48-bit f32)."""
    sh, se = two_sum(xh, yh)
    te = xl + yl + se
    return quick_two_sum(sh, te)


def dd_add_fp(xh, xl, y):
    sh, se = two_sum(xh, y)
    return quick_two_sum(sh, xl + se)


def dd_mul_fp(xh, xl, y):
    """(xh, xl) * y in double-double."""
    ph, pe = two_prod(xh, y)
    return quick_two_sum(ph, pe + xl * y)


def dd_neg(xh, xl):
    return -xh, -xl


def dd_to_fp(xh, xl):
    return xh + xl
