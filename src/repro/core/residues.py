"""Integer conversion + residue decomposition (Alg. 1 steps IV, V-i/ii/iv).

Exactness strategy (DESIGN.md S2, TPU adaptation):

The scaled integers a' = trunc(a * mu) can be as large as ~2^(log2(P)/2), far
beyond 2^53, so a naive float `mod` is wrong.  But a' is always *exactly
representable* (mu is a power of two and trunc is exact), so we peel it into
base-2^24 limbs, each limb exactly representable and < 2^24, then reduce each
limb with precomputed (2^24)^i mod p_l in small exact arithmetic.  The same
code path is exact in f64 (CPU host) and in f32 (TPU kernels), because every
intermediate stays below 2^24 (f32-exact) after the peel.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from .moduli import CRTContext

LIMB_BITS = 24
LIMB = float(1 << LIMB_BITS)


def num_limbs_for_bits(bits: float) -> int:
    """Limbs needed to hold |a'| <= 2^bits."""
    return max(1, math.ceil((bits + 1) / LIMB_BITS))


def quantize(a: jnp.ndarray, scale: jnp.ndarray, axis: int) -> jnp.ndarray:
    """a' = trunc(a * scale) with the scale broadcast along `axis`.

    `scale` holds exact powers of two, so the product and trunc are exact.
    """
    shape = [1] * a.ndim
    shape[axis] = -1
    return jnp.trunc(a * scale.reshape(shape))


def split_limbs(x: jnp.ndarray, n_limbs: int) -> jnp.ndarray:
    """Exactly split integer-valued float x into signed base-2^24 limbs.

    Returns (n_limbs, *x.shape) with x == sum_i limbs[i] * 2^(24*i) and
    |limbs[i]| < 2^24.  Each peel is exact: the low part is a contiguous
    lower-bit slice of x's significand (see DESIGN.md S2).
    """
    limbs = []
    rem = x
    for i in reversed(range(1, n_limbs)):
        base = jnp.asarray(LIMB**i, dtype=x.dtype)
        hi = jnp.trunc(rem / base)
        rem = rem - hi * base
        limbs.append(hi)
    limbs.append(rem)
    return jnp.stack(limbs[::-1], axis=0)


def _limb_radix_table(ctx: CRTContext, n_limbs: int) -> np.ndarray:
    """(n_limbs, N) table of 2^(24*i) mod p_l, symmetric range."""
    tab = np.zeros((n_limbs, ctx.n), dtype=np.int32)
    for i in range(n_limbs):
        for l, p in enumerate(ctx.moduli):
            r = pow(1 << LIMB_BITS, i, p)
            if r > (p - 1) // 2:
                r -= p
            tab[i, l] = r
    return tab


def sym_mod_small(v: jnp.ndarray, p, half) -> jnp.ndarray:
    """Symmetric mod for |v| small enough that v/p rounds within +/-1.

    v may be any float/int array with |v| <= ~2^44 (f64) / ~2^20 (f32).
    Result in [-(p-1)/2, (p-1)/2].  Exact: n is an integer, v - n*p is exact
    (small magnitudes), and one correction step fixes a +/-1 rounding of n.
    """
    v = jnp.asarray(v)
    n = jnp.round(v / p)
    r = v - n * p
    r = jnp.where(r > half, r - p, r)
    r = jnp.where(r < -half, r + p, r)
    return r


def sym_mod_int32(v: jnp.ndarray, p: int) -> jnp.ndarray:
    """Exact symmetric mod of int32 values (post-GEMM reduction, step V-iv)."""
    half = (p - 1) // 2
    r = jnp.remainder(v, jnp.int32(p))  # in [0, p)
    return jnp.where(r > half, r - p, r).astype(jnp.int32)


def residues_from_quantized(
    aq: jnp.ndarray, ctx: CRTContext, n_limbs: int
) -> jnp.ndarray:
    """Map integer-valued float a' -> (N, *shape) int8 symmetric residues.

    Steps V-i/ii of Alg. 1.  Exact for |a'| < 2^(24 * n_limbs).
    """
    limbs = split_limbs(aq, n_limbs)  # (L, ...) floats, |limb| < 2^24
    radix = _limb_radix_table(ctx, n_limbs)  # (L, N) int32 host constants
    outs = []
    for l, p in enumerate(ctx.moduli):
        half = (p - 1) // 2
        acc = jnp.zeros_like(aq)
        for i in range(n_limbs):
            # |limb mod| <= (p-1)/2; times |radix| <= (p-1)/2 => < 2^14
            r_i = sym_mod_small(limbs[i], float(p), float(half))
            acc = acc + r_i * float(radix[i, l])
        # |acc| <= n_limbs * 127^2 < 2^17 -> exact final reduction
        outs.append(sym_mod_small(acc, float(p), float(half)))
    return jnp.stack(outs, axis=0).astype(jnp.int8)


def residues(
    a: jnp.ndarray, scale: jnp.ndarray, axis: int, ctx: CRTContext, n_limbs: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """quantize + residue-decompose; returns (a_quantized_float, int8 residues)."""
    aq = quantize(a, scale, axis)
    return aq, residues_from_quantized(aq, ctx, n_limbs)
