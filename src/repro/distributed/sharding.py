"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Parameters/caches declare logical axis names in their ParamMeta ('vocab',
'ff', 'qkv', 'experts', ...); these rules map them onto the physical mesh
axes ('pod', 'data', 'model').  Changing the parallelism layout = changing
this table, not the model code.
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.params import ParamMeta, _map_like

# tensor-parallel over 'model'; DP/batch over ('pod','data'); ZeRO-1 for
# optimizer state adds 'data' on the first free axis (see optimizer_spec).
# The KV cache shards its *sequence* dim over 'model' (flash-decoding style)
# because kv_heads (1-24 on the assigned archs) rarely divide the 16-way
# model axis, while the 32k/512k cache length always does.
DEFAULT_RULES: dict[str, Any] = {
    "vocab": "model",
    "ff": "model",
    "qkv": "model",
    "kv_qkv": "model",
    "heads": "model",
    "kv_heads": None,
    "kv_seq": "model",
    "experts": "model",      # expert parallelism
    "ssm_inner": "model",
    "embed": None,
    "layers": None,          # scan axis (pipeline axis when --pp is used)
    "batch": ("pod", "data"),
    "seq": None,             # flipped to 'model' under sequence parallelism
}


def _mesh_axes(mesh: Mesh) -> set[str]:
    return set(mesh.axis_names)


def _axes_size(target, mesh: Mesh) -> int:
    if isinstance(target, (tuple, list)):
        n = 1
        for t in target:
            n *= mesh.shape[t]
        return n
    return mesh.shape[target]


def _resolve(
    axis: str | None, rules: Mapping[str, Any], mesh: Mesh, dim=None, used=None
):
    """Map a logical axis onto mesh axes; drop to replicated when the mesh
    axes are absent, already claimed by an earlier dimension (left-to-right
    precedence — e.g. MoE experts take 'model' before the per-expert ff), or
    the dimension size is not divisible (pjit arguments require exact
    divisibility)."""
    if axis is None:
        return None
    target = rules.get(axis, None)
    if target is None:
        return None
    used = used if used is not None else set()
    if isinstance(target, (tuple, list)):
        kept = tuple(
            t for t in target if t in _mesh_axes(mesh) and t not in used
        )
        if not kept:
            return None
        if dim is not None and dim % _axes_size(kept, mesh):
            return None
        used.update(kept)
        return kept
    if target not in _mesh_axes(mesh) or target in used:
        return None
    if dim is not None and dim % mesh.shape[target]:
        return None
    used.add(target)
    return target


def pspec_for_axes(
    axes: Sequence[str | None], rules, mesh: Mesh, shape=None
) -> P:
    dims = shape if shape is not None else [None] * len(axes)
    used: set = set()
    return P(*[_resolve(a, rules, mesh, d, used) for a, d in zip(axes, dims)])


def pspec_for_meta(meta: ParamMeta, rules, mesh: Mesh) -> P:
    return pspec_for_axes(meta.axes, rules, mesh, meta.shape)


def tree_pspecs(abstract_params, rules, mesh: Mesh):
    """ParamMeta tree -> PartitionSpec tree (size-aware)."""
    return _map_like(abstract_params, lambda _, m: pspec_for_meta(m, rules, mesh))


def tree_shardings(abstract_params, rules, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_pspecs(abstract_params, rules, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


def optimizer_spec(param_spec: P, shape, mesh: Mesh) -> P:
    """ZeRO-1: shard optimizer state over 'data' on the first free axis
    whose size divides the data axis.

    The m/v/master leaves mirror the parameter but additionally split one
    unsharded dimension across the data axis, so AdamW state for the
    26-32B archs fits v5e HBM (DESIGN.md S4).
    """
    if "data" not in _mesh_axes(mesh):
        return param_spec
    nd = mesh.shape["data"]
    parts = list(param_spec)
    parts += [None] * (len(shape) - len(parts))
    used = {
        a
        for p in parts
        if p is not None
        for a in (p if isinstance(p, (tuple, list)) else (p,))
    }
    if "data" in used:  # already data-sharded (e.g. ZeRO-3 param rules)
        return P(*parts)
    for i, (p, d) in enumerate(zip(parts, shape)):
        if p is None and d % nd == 0:
            parts[i] = "data"
            return P(*parts)
    return param_spec


def batch_pspec(mesh: Mesh, rules=None) -> P:
    rules = rules or DEFAULT_RULES
    return P(_resolve("batch", rules, mesh))


def batch_sharding(mesh: Mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, batch_pspec(mesh, rules))
