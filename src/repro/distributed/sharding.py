"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Parameters/caches declare logical axis names in their ParamMeta ('vocab',
'ff', 'qkv', 'experts', ...); these rules map them onto the physical mesh
axes ('pod', 'data', 'model').  Changing the parallelism layout = changing
this table, not the model code.

Alongside the parameter rules live the *residue-plane* rules of the sharded
emulated GEMM (`GemmPolicy(execution="sharded")`): the (N, m, k) / (N, k, n)
int8 residue stacks shard their plane dimension N over the 'residue' mesh
axis (falling back to 'model' when the mesh has no dedicated residue axis),
and m/n shard like a normal GEMM — m over 'data', n over 'model' unless the
residue fallback claimed it.  `resolve_gemm_axes` performs that resolution
size-aware (indivisible m/n drop to replicated, exactly like the parameter
rules), and `residue_plane_specs` spells the resulting PartitionSpecs for
every array of the pipeline.  K is never sharded: each shard contracts the
full k so the int8 planes it produces are complete, and only the exact f64
partial-reconstruction planes are ever communicated (one psum per output
block — see `distributed/sharded_gemm.py`).
"""
from __future__ import annotations

import dataclasses

from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.params import ParamMeta, _map_like

RESIDUE_AXIS = "residue"

# tensor-parallel over 'model'; DP/batch over ('pod','data'); ZeRO-1 for
# optimizer state adds 'data' on the first free axis (see optimizer_spec).
# The KV cache shards its *sequence* dim over 'model' (flash-decoding style)
# because kv_heads (1-24 on the assigned archs) rarely divide the 16-way
# model axis, while the 32k/512k cache length always does.
DEFAULT_RULES: dict[str, Any] = {
    "vocab": "model",
    "ff": "model",
    "qkv": "model",
    "kv_qkv": "model",
    "heads": "model",
    "kv_heads": None,
    "kv_seq": "model",
    "experts": "model",      # expert parallelism
    "ssm_inner": "model",
    "embed": None,
    "layers": None,          # scan axis (pipeline axis when --pp is used)
    "batch": ("pod", "data"),
    "seq": None,             # flipped to 'model' under sequence parallelism
}


def _mesh_axes(mesh: Mesh) -> set[str]:
    return set(mesh.axis_names)


def _axes_size(target, mesh: Mesh) -> int:
    if isinstance(target, (tuple, list)):
        n = 1
        for t in target:
            n *= mesh.shape[t]
        return n
    return mesh.shape[target]


def _resolve(
    axis: str | None, rules: Mapping[str, Any], mesh: Mesh, dim=None, used=None
):
    """Map a logical axis onto mesh axes; drop to replicated when the mesh
    axes are absent, already claimed by an earlier dimension (left-to-right
    precedence — e.g. MoE experts take 'model' before the per-expert ff), or
    the dimension size is not divisible (pjit arguments require exact
    divisibility)."""
    if axis is None:
        return None
    target = rules.get(axis, None)
    if target is None:
        return None
    used = used if used is not None else set()
    if isinstance(target, (tuple, list)):
        kept = tuple(
            t for t in target if t in _mesh_axes(mesh) and t not in used
        )
        if not kept:
            return None
        if dim is not None and dim % _axes_size(kept, mesh):
            return None
        used.update(kept)
        return kept
    if target not in _mesh_axes(mesh) or target in used:
        return None
    if dim is not None and dim % mesh.shape[target]:
        return None
    used.add(target)
    return target


def pspec_for_axes(
    axes: Sequence[str | None], rules, mesh: Mesh, shape=None
) -> P:
    dims = shape if shape is not None else [None] * len(axes)
    used: set = set()
    return P(*[_resolve(a, rules, mesh, d, used) for a, d in zip(axes, dims)])


def pspec_for_meta(meta: ParamMeta, rules, mesh: Mesh) -> P:
    return pspec_for_axes(meta.axes, rules, mesh, meta.shape)


def tree_pspecs(abstract_params, rules, mesh: Mesh):
    """ParamMeta tree -> PartitionSpec tree (size-aware)."""
    return _map_like(abstract_params, lambda _, m: pspec_for_meta(m, rules, mesh))


def tree_shardings(abstract_params, rules, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_pspecs(abstract_params, rules, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


def optimizer_spec(param_spec: P, shape, mesh: Mesh) -> P:
    """ZeRO-1: shard optimizer state over 'data' on the first free axis
    whose size divides the data axis.

    The m/v/master leaves mirror the parameter but additionally split one
    unsharded dimension across the data axis, so AdamW state for the
    26-32B archs fits v5e HBM (DESIGN.md S4).
    """
    if "data" not in _mesh_axes(mesh):
        return param_spec
    nd = mesh.shape["data"]
    parts = list(param_spec)
    parts += [None] * (len(shape) - len(parts))
    used = {
        a
        for p in parts
        if p is not None
        for a in (p if isinstance(p, (tuple, list)) else (p,))
    }
    if "data" in used:  # already data-sharded (e.g. ZeRO-3 param rules)
        return P(*parts)
    for i, (p, d) in enumerate(zip(parts, shape)):
        if p is None and d % nd == 0:
            parts[i] = "data"
            return P(*parts)
    return param_spec


# ------------------------------------------- sharded residue GEMM resolution


@dataclasses.dataclass(frozen=True)
class GemmShardAxes:
    """Resolved mesh axes of one sharded emulated GEMM (names or None).

    `residue` carries the N residue planes, `m` the output rows, `n` the
    output columns.  Hashable (rides inside the jit-static ShardedBackend).
    """

    residue: str | None = None
    m: str | None = None
    n: str | None = None

    def sizes(self, mesh: Mesh) -> tuple[int, int, int]:
        """(residue_shards, m_shards, n_shards) on `mesh`."""
        sz = lambda ax: mesh.shape[ax] if ax is not None else 1  # noqa: E731
        return sz(self.residue), sz(self.m), sz(self.n)


def resolve_gemm_axes(
    mesh: Mesh,
    m: int | None = None,
    n: int | None = None,
    overrides: tuple | None = None,
) -> GemmShardAxes:
    """Map the (residue, m, n) logical GEMM axes onto `mesh`.

    residue -> 'residue' when the mesh has one, else 'model'; m -> 'data';
    n -> 'model' unless the residue fallback already claimed it (one mesh
    axis is used at most once, same precedence rule as `_resolve`).  With
    shape hints, an m/n axis whose size does not divide the dimension drops
    to replicated (shard_map requires exact divisibility; the residue axis
    never drops — plane chunks zero-pad instead).  `overrides` is the
    policy's explicit (residue, m, n) name triple, taken verbatim apart
    from the divisibility check.
    """
    names = set(mesh.axis_names)
    if overrides is not None:
        residue, m_ax, n_ax = overrides
        for ax in (residue, m_ax, n_ax):
            if ax is not None and ax not in names:
                raise ValueError(
                    f"shard axis {ax!r} not on mesh axes {tuple(mesh.axis_names)}"
                )
        given = [ax for ax in (residue, m_ax, n_ax) if ax is not None]
        if len(given) != len(set(given)):
            # one mesh axis per role: e.g. residue and n both on 'model'
            # would psum partial outputs computed from DIFFERENT column
            # tiles — silently wrong, so reject it here
            raise ValueError(
                f"shard_axes must use each mesh axis at most once; got "
                f"(residue={residue!r}, m={m_ax!r}, n={n_ax!r})"
            )
    else:
        residue = (
            RESIDUE_AXIS
            if RESIDUE_AXIS in names
            else ("model" if "model" in names else None)
        )
        m_ax = "data" if "data" in names else None
        n_ax = "model" if "model" in names and residue != "model" else None
    if m_ax is not None and m is not None and m % mesh.shape[m_ax]:
        m_ax = None
    if n_ax is not None and n is not None and n % mesh.shape[n_ax]:
        n_ax = None
    return GemmShardAxes(residue=residue, m=m_ax, n=n_ax)


def residue_plane_specs(axes: GemmShardAxes) -> dict[str, P]:
    """PartitionSpecs of every array in the sharded residue pipeline.

    The spec table is the distributed design in one place: operands split
    rows/columns only, residue stacks additionally split the plane
    dimension, the exact f64 partial-reconstruction planes are the ONLY
    psum payload, and the reconstructed output is sharded like a normal
    GEMM result (no int8 array ever appears in a collective).
    """
    return {
        "a": P(axes.m, None),                       # (m, k) operand
        "b": P(None, axes.n),                       # (k, n) operand
        "a_residues": P(axes.residue, axes.m, None),  # (N, m, k) int8
        "b_residues": P(axes.residue, None, axes.n),  # (N, k, n) int8
        "product_residues": P(axes.residue, axes.m, axes.n),  # (N, m, n)
        "partial": P(None, axes.m, axes.n),         # (parts, m, n) f64, psum
        "out": P(axes.m, axes.n),                   # (m, n) reconstructed
    }


def batch_pspec(mesh: Mesh, rules=None) -> P:
    rules = rules or DEFAULT_RULES
    return P(_resolve("batch", rules, mesh))


def batch_sharding(mesh: Mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, batch_pspec(mesh, rules))
