"""GPipe-style pipeline parallelism over a mesh axis (DESIGN.md S4: the
'pod' axis doubles as the pipeline axis for cross-DCN-friendly training).

The stacked layer parameters of a homogeneous decoder are split into
`n_stages` contiguous stages sharded over the pipeline axis; microbatches
flow stage-to-stage via `ppermute` inside `shard_map`.  Everything is plain
differentiable JAX: `jax.grad` of the pipelined loss yields the reverse
pipeline automatically (ppermute transposes to the reverse shift).

Schedule: classic GPipe fill-drain — T = M + S - 1 ticks for M microbatches
over S stages; bubble fraction (S-1)/T.  Embedding + head run outside the
pipelined region (they are cheap relative to the stack and keep the stage
function homogeneous).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..models.config import ModelConfig
from ..models.layers import apply_mlp, apply_norm
from ..models.blocks import BLOCKS


def _stage_fn(cfg: ModelConfig, lp, x, positions):
    """Run this stage's layers (scan) on activations x: (B, S, d)."""
    bk, mk, _ = cfg.layer_groups[0]

    def body(carry, layer_p):
        h = carry
        hn = apply_norm(cfg.norm, layer_p["norm1"], h)
        h = h + BLOCKS[bk]["apply"](cfg, layer_p["block"], hn, positions)
        if mk != "none":
            hn2 = apply_norm(cfg.norm, layer_p["norm2"], h)
            h = h + apply_mlp(mk, layer_p["mlp"], hn2, cfg.gemm_policy)
        return h, None

    out, _ = jax.lax.scan(body, x, lp)
    return out


def pipeline_apply(
    cfg: ModelConfig,
    group_params,
    h: jnp.ndarray,
    positions: jnp.ndarray,
    mesh: Mesh,
    axis: str = "pp",
    n_micro: int = 4,
):
    """Pipelined layer stack. h: (B, S, d) embedded activations (replicated
    over `axis`); returns transformed activations, bit-equal to the
    sequential stack (tests/test_pipeline.py)."""
    if len(cfg.layer_groups) != 1:
        raise ValueError("pipeline supports homogeneous layer stacks")
    n_stages = mesh.shape[axis]
    n_layers = cfg.n_layers
    if n_layers % n_stages:
        raise ValueError(f"{n_layers} layers not divisible into {n_stages} stages")
    b = h.shape[0]
    if b % n_micro:
        raise ValueError(f"batch {b} not divisible by n_micro {n_micro}")

    # (L, ...) -> (S, L/S, ...): stage dim sharded over the pipeline axis
    per = n_layers // n_stages
    staged = jax.tree.map(
        lambda x: x.reshape((n_stages, per) + x.shape[1:]), group_params
    )
    mb = h.reshape((n_micro, b // n_micro) + h.shape[1:])  # (M, b/M, S, d)
    pos_mb = positions[: b // n_micro]

    @functools.partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(P(axis), P(), P()),
        out_specs=P(),
        check_vma=False,
    )
    def run(stage_p, mb_in, pos):
        stage_p = jax.tree.map(lambda x: x[0], stage_p)  # local (per, ...)
        idx = jax.lax.axis_index(axis)
        m = mb_in.shape[0]
        ticks = m + n_stages - 1
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            state, outputs = carry
            # stage 0 ingests microbatch t (clamped; masked later)
            feed = mb_in[jnp.clip(t, 0, m - 1)]
            x = jnp.where(idx == 0, feed, state)
            y = _stage_fn(cfg, stage_p, x, pos)
            # emit from the last stage at ticks t >= S-1 -> microbatch t-S+1
            out_slot = jnp.clip(t - (n_stages - 1), 0, m - 1)
            write = (idx == n_stages - 1) & (t >= n_stages - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(write, y, outputs[out_slot]),
                out_slot,
                axis=0,
            )
            # pass activations downstream
            nxt = jax.lax.ppermute(y, axis, fwd_perm)
            return (nxt, outputs), None

        init = (jnp.zeros_like(mb_in[0]), jnp.zeros_like(mb_in))
        (_, outputs), _ = jax.lax.scan(
            tick, init, jnp.arange(ticks, dtype=jnp.int32)
        )
        # outputs live on the last stage; broadcast to all shards
        outputs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            axis,
        )
        return outputs

    out = run(staged, mb, pos_mb)
    return out.reshape(h.shape)


def pipeline_loss(model, params, batch, mesh, axis: str = "pp", n_micro: int = 4):
    """Drop-in pipelined Model.loss for homogeneous decoder configs."""
    cfg = model.cfg
    h, positions, _ = _embed(model, params, batch)
    h = pipeline_apply(cfg, params["groups"][0], h, positions, mesh, axis, n_micro)
    h = apply_norm(cfg.norm, params["final_norm"], h)
    logits = model._head(params, h)
    tokens = batch["tokens"]
    targets = jnp.concatenate([tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], 1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], jnp.float32),
         jnp.zeros_like(tokens[:, :1], jnp.float32)], 1,
    )
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _embed(model, params, batch):
    return model._embed_inputs(params, batch) + (None,)
