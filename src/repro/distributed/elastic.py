"""Elastic scaling: resume the same checkpoint on a different mesh.

Node failure at scale => rebuild a smaller (or later, larger) mesh from the
healthy hosts and continue.  Because (a) checkpoints are mesh-agnostic host
arrays and (b) the data pipeline is a pure function of (step, shard), the
only work is re-deriving shardings for the new mesh and device_put'ing —
`reshard` below.  Training then continues bit-compatibly modulo batch
layout.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from ..checkpoint import Checkpointer, latest_step
from .sharding import DEFAULT_RULES, tree_shardings


def reshard(tree, shardings):
    """Move a (host or device) pytree onto new shardings (new mesh)."""
    return jax.tree.map(jax.device_put, tree, shardings)


def elastic_restore(
    ckpt_dir: str,
    abstract_params,
    new_mesh: Mesh,
    rules=None,
    like=None,
):
    """Load the latest checkpoint and shard it for `new_mesh`.

    Returns (step, params) with params laid out per the rules on the new
    mesh.  `like` defaults to materialized shapes from abstract_params.
    """
    rules = rules or DEFAULT_RULES
    step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    ckpt = Checkpointer(ckpt_dir)
    from ..models.params import abstract_arrays

    like = like if like is not None else abstract_arrays(abstract_params)
    shardings = tree_shardings(abstract_params, rules, new_mesh)
    params = ckpt.restore(step, like, shardings)
    return step, params
