"""`GemmPolicy(execution="sharded")` — the residue pipeline over the mesh.

Distributes one emulated GEMM over a production mesh by sharding exactly
the axes the scheme makes cheap (ROADMAP "Sharded residue GEMMs"):

* the N residue planes over the `residue` mesh axis (falling back to
  `model`) — each modulus plane is an independent int8 GEMM, so this axis
  is embarrassingly parallel (arXiv:2504.08009);
* output rows m over `data` and columns n over `model`, like a normal GEMM.

K is never sharded.  Each shard casts the operand tiles it consumes itself
(no residue-cast output is ever communicated), runs the UNCHANGED batched
Pallas kernels on its plane chunk — the modulus arrives via scalar
prefetch, so the compiled kernel is modulus-agnostic and takes the shard's
dynamically-sliced chunk — and the only cross-device traffic of the whole
pipeline is ONE psum per output block of the *reconstructed* output in its
exact partial form (never the int8 planes, which at N moduli would be N
bytes/element against `crt_partial_parts(N)` f64 words here but grow with
every operand, not just the output).

Exactness/bitwise contract (the falsifiable part): residue arithmetic is
exact integers end to end, and the partial reconstruction is combined in
the order-independent exact f64 split of `core/crt.partial_split` — each
device psums `sum_{l in chunk} u_{j,l} E_l` part-planes whose every partial
sum is an exact integer below 2^53, then rebuilds the COMPLETE residue
planes locally (`crt.residues_from_partial`) and runs the ordinary Garner
kernel on them.  The sharded output is therefore bitwise identical to the
single-device kernel path on ANY mesh shape, not just numerically close;
the parity suite (tests/test_sharded.py) asserts equality across meshes
and that no int8 array appears in any collective.

Scaling: fast mode is row/column-local, so it needs no communication at
all.  Accurate mode's bound maxima span the full output row/column, so the
per-shard maxima are combined with `lax.pmax` on int32 (exact) over the m/n
mesh axes — wired through the executor's `accu_row_combine`/
`accu_col_combine` backend hooks.

The f64 partial planes are exact on CPU/GPU; a TPU deployment would carry
them as two-f32 pairs (the same trick as the Garner kernel's double-single
output) — noted in ROADMAP as the follow-up.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

try:  # jax >= 0.6 exposes shard_map at the top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - version-dependent import
    from jax.experimental.shard_map import shard_map as _shard_map

from ..core import crt
from ..core.executor import chunked_residue_matmul, execute_plan
from ..core.intmul import int8_matmul
from ..core.moduli import CRTContext
from ..core.residues import sym_mod_small
from ..kernels.common import round_up
from .sharding import GemmShardAxes, residue_plane_specs, resolve_gemm_axes

__all__ = ["ShardedBackend"]


class _ShardWorker:
    """The per-shard residue backend one `shard_map` program runs.

    Implements the executor's backend protocol (cast / residue_matmul /
    karatsuba / reconstruct, plus the stacked variants and the accu scale
    combines) for THIS shard's plane chunk, delegating the data-touching
    kernels to the wrapped single-device backend.  Instances close over
    traced values (`lax.axis_index` slices) and live only inside one
    shard_map trace — they are deliberately not hashable/jit-static.
    """

    def __init__(self, inner, ctx: CRTContext, axes: GemmShardAxes, mesh: Mesh):
        self.inner = inner
        self.ctx = ctx
        self.axes = axes
        self.r = mesh.shape[axes.residue] if axes.residue is not None else 1
        # mirror the stacked-launch capabilities so the executor's
        # _cast_pair/_reconstruct_pair take the same single-launch paths
        if hasattr(inner, "cast_stack"):
            self.cast_stack = self._cast_stack
        if hasattr(inner, "reconstruct_stack"):
            self.reconstruct_stack = self._reconstruct_stack
        # megakernel inners run fused per-shard when every shard holds ALL
        # residue planes (r == 1: the moduli stay compile-time static, which
        # the fused Garner epilogue requires); with a sharded residue axis
        # the worker falls back to the composed primitives + the two-phase
        # psum hooks below (the Garner table cannot take a dynamic chunk).
        self.megakernel = self.r == 1 and getattr(inner, "megakernel", False)
        if self.megakernel:
            self.fused_gemm = inner.fused_gemm
            self.fused_karatsuba_gemm = inner.fused_karatsuba_gemm
        if self.r > 1:
            # overlap hooks: the executor issues every block's product, then
            # ONE psum of the collected partial pytree (async-friendly),
            # then the per-block reconstructions
            self.psum_partial = self._psum_partial
            self.psum_combine = self._psum_combine
            self.reconstruct_post = self._reconstruct_post
            self.reconstruct_post_stack = self._reconstruct_post_stack
        # accurate-mode bound maxima must cover the full row/column
        if axes.n is not None:
            self.accu_row_combine = lambda v: lax.pmax(v, axes.n)
        if axes.m is not None:
            self.accu_col_combine = lambda v: lax.pmax(v, axes.m)
        # Pallas-capable inners run the batched kernels with the dynamic
        # modulus chunk; the jnp reference inner gets exact f64 dyn ops
        self._pallas = getattr(inner, "interpret", None) is not None
        if self.r > 1:
            n_pad = round_up(ctx.n, self.r)
            self.chunk = n_pad // self.r
            self.n_pad = n_pad
            # modulus 1 pads: every residue is 0, so padded planes are inert
            mod_pad = np.concatenate(
                [np.asarray(ctx.moduli_arr), np.ones(n_pad - ctx.n, np.int32)]
            )
            u, _, _ = crt.partial_split(ctx.moduli)
            u_pad = np.zeros((u.shape[0], n_pad), np.float64)
            u_pad[:, : ctx.n] = u
            start = lax.axis_index(axes.residue) * self.chunk
            self.mod_loc = lax.dynamic_slice(
                jnp.asarray(mod_pad, jnp.int32), (start,), (self.chunk,)
            )
            self.u_loc = lax.dynamic_slice(
                jnp.asarray(u_pad), (jnp.int32(0), start),
                (u.shape[0], self.chunk),
            )
            pf = self.mod_loc.astype(jnp.float64)
            self._p3 = pf[:, None, None]
            self._half3 = ((pf - 1.0) * 0.5)[:, None, None]

    # ------------------------------------------------------------ casting

    def _slice_planes(self, res, axis):
        """Keep this shard's plane chunk of a full (.., N, ..) residue stack."""
        if self.r == 1:
            return res
        pad = [(0, 0)] * res.ndim
        pad[axis] = (0, self.n_pad - res.shape[axis])
        res = jnp.pad(res, pad)
        return lax.dynamic_slice_in_dim(
            res, lax.axis_index(self.axes.residue) * self.chunk,
            self.chunk, axis,
        )

    def cast(self, x, e, axis, ctx, n_limbs):
        # the shard casts the tile it consumes itself (never communicated);
        # the cast kernel is static over the full moduli tuple, so each
        # shard casts all N planes and keeps its chunk — the cast is the
        # cheap memory-bound stage, and slicing keeps the expensive product
        # and all storage at N/R planes (ROADMAP notes the redundant-cast
        # follow-up).
        return self._slice_planes(self.inner.cast(x, e, axis, ctx, n_limbs), 0)

    def _cast_stack(self, xs, e, axis, ctx, n_limbs):
        return self._slice_planes(
            self.inner.cast_stack(xs, e, axis, ctx, n_limbs), 1
        )

    # ----------------------------------------------------------- products

    def _dyn_mod(self, v):
        """Exact symmetric mod of |v| < 2^44 by this shard's dynamic moduli."""
        return sym_mod_small(v.astype(jnp.float64), self._p3, self._half3)

    def residue_matmul(self, ares, bres, ctx):
        if self.r == 1:
            return self.inner.residue_matmul(ares, bres, ctx)
        if self._pallas:
            from ..kernels.int8_mod_gemm import int8_mod_gemm_batched

            return chunked_residue_matmul(
                lambda a, b, carry: int8_mod_gemm_batched(
                    a, b, moduli=self.mod_loc, carry=carry,
                    interpret=self.inner.interpret,
                ),
                ares, bres, ctx, carry_epilogue=True,
            )

        def gemm(a, b, carry):
            d = int8_matmul(a, b).astype(jnp.float64)
            if carry is not None:
                d = d + carry.astype(jnp.float64)
            return self._dyn_mod(d).astype(jnp.int8)

        return chunked_residue_matmul(gemm, ares, bres, ctx, carry_epilogue=True)

    def karatsuba(self, arr, ari, brr, bri, ctx):
        if self.r == 1:
            return self.inner.karatsuba(arr, ari, brr, bri, ctx)
        if self._pallas:
            from ..kernels.karatsuba_fused import karatsuba_mod_gemm_batched

            return chunked_residue_matmul(
                lambda a, b, carry: karatsuba_mod_gemm_batched(
                    a[0], a[1], b[0], b[1], moduli=self.mod_loc, carry=carry,
                    interpret=self.inner.interpret,
                ),
                (arr, ari), (brr, bri), ctx, carry_epilogue=True,
            )
        # jnp reference flavour: compose the D/E/F triple with dynamic mods
        asum = self._dyn_mod(
            arr.astype(jnp.int32) + ari.astype(jnp.int32)
        ).astype(jnp.int8)
        bsum = self._dyn_mod(
            brr.astype(jnp.int32) + bri.astype(jnp.int32)
        ).astype(jnp.int8)
        d = self.residue_matmul(arr, brr, ctx).astype(jnp.int32)
        e = self.residue_matmul(ari, bri, ctx).astype(jnp.int32)
        f = self.residue_matmul(asum, bsum, ctx).astype(jnp.int32)
        er = self._dyn_mod(d - e).astype(jnp.int8)
        ei = self._dyn_mod(f - d - e).astype(jnp.int8)
        return er, ei

    # ------------------------------------------------------ reconstruction

    def _full_planes(self, e_res, ctx, stacked: bool):
        """Local plane chunk -> COMPLETE (.., N, m, n) residue planes.

        The single communication point: psum the exact f64 partial planes
        over the residue axis (bitwise order-independent by construction),
        then rebuild all N residues locally.
        """
        t = crt.partial_combine(e_res, self.u_loc)
        t = lax.psum(t, self.axes.residue)
        if not stacked:
            return crt.residues_from_partial(t, ctx)
        planes = crt.residues_from_partial(jnp.moveaxis(t, 0, 1), ctx)
        return jnp.moveaxis(planes, 0, 1)

    # -- two-phase psum hooks (r > 1): the executor's blocked pipelines
    # issue ALL blocks' products before any collective, psum the collected
    # partial pytree ONCE, then rebuild + reconstruct per block — so the
    # only cross-device traffic of the pipeline is one async-overlappable
    # collective instead of one serialized psum between consecutive blocks.
    # Bitwise identical to the per-block `_full_planes` route: a pytree
    # psum is the same per-leaf reduction of exact f64 integer partials.

    def _psum_partial(self, e_res):
        """Local (.., N_loc, m, n) plane chunk -> exact f64 partial planes
        (NO collective — collected by the executor across blocks)."""
        return crt.partial_combine(e_res, self.u_loc)

    def _psum_combine(self, partials, stacked: bool = False):
        """ONE psum of all blocks' partials, then rebuild the COMPLETE
        (.., N, m, n) residue planes of every block locally."""
        partials = lax.psum(partials, self.axes.residue)
        out = []
        for t in partials:
            if stacked:
                planes = crt.residues_from_partial(
                    jnp.moveaxis(t, 0, 1), self.ctx
                )
                out.append(jnp.moveaxis(planes, 0, 1))
            else:
                out.append(crt.residues_from_partial(t, self.ctx))
        return out

    def _reconstruct_post(self, e_res, e_mu, e_nu, ctx, method, out_dtype):
        """Reconstruct from already-complete planes (post `psum_combine`)."""
        return self.inner.reconstruct(e_res, e_mu, e_nu, ctx, method, out_dtype)

    def _reconstruct_post_stack(self, e_res, e_mu, e_nu, ctx, method, out_dtype):
        rec = getattr(self.inner, "reconstruct_stack", None)
        if rec is None:
            return (
                self.inner.reconstruct(e_res[0], e_mu, e_nu, ctx, method, out_dtype),
                self.inner.reconstruct(e_res[1], e_mu, e_nu, ctx, method, out_dtype),
            )
        return rec(e_res, e_mu, e_nu, ctx, method, out_dtype)

    def reconstruct(self, e_res, e_mu, e_nu, ctx, method, out_dtype):
        if self.r > 1:
            e_res = self._full_planes(e_res, ctx, stacked=False)
        return self.inner.reconstruct(e_res, e_mu, e_nu, ctx, method, out_dtype)

    def _reconstruct_stack(self, e_res, e_mu, e_nu, ctx, method, out_dtype):
        if self.r > 1:
            e_res = self._full_planes(e_res, ctx, stacked=True)
        return self.inner.reconstruct_stack(
            e_res, e_mu, e_nu, ctx, method, out_dtype
        )


@dataclasses.dataclass(frozen=True)
class ShardedBackend:
    """Residue backend running the plan under `shard_map` over `mesh`.

    Hashable (rides in jit-static slots like every backend); the per-shard
    worker is built inside the traced program.  `shard_axes` is the
    policy's explicit (residue, m, n) axis-name override, None = resolve
    per `distributed.sharding.resolve_gemm_axes`.
    """

    inner: Any
    mesh: Mesh
    shard_axes: tuple | None = None

    # plan 'auto' selections charge launches as the per-shard inner does
    @property
    def fused_karatsuba(self) -> bool:
        return getattr(self.inner, "fused_karatsuba", False)

    @property
    def modulus_batched(self) -> bool:
        return getattr(self.inner, "modulus_batched", False)

    @property
    def megakernel(self) -> bool:
        # advertised for plan pricing; per-shard workers actually run fused
        # only when the residue axis is unsharded (r == 1, static moduli)
        return getattr(self.inner, "megakernel", False)

    @property
    def uses_pallas(self) -> bool:
        return getattr(self.inner, "uses_pallas", True)

    def analyze(self, plan, shape=None):
        """Static-analysis suite certifying the sharded pipeline: the
        collective-safety pass is the load-bearing one here (only exact
        f64 CRT partials may psum), and the launch-count certificate is
        derived from `shard_factors` (the fused worker engages only on
        m/n-only meshes).  See repro.analysis.passes_for_backend."""
        from ..analysis import passes_for_backend

        return passes_for_backend(self, plan, shape)

    def resolve_axes(self, m: int, n: int) -> GemmShardAxes:
        return resolve_gemm_axes(self.mesh, m, n, self.shard_axes)

    def shard_factors(self, m: int, n: int) -> tuple[int, int, int]:
        """(m_shards, n_shards, residue_shards) actually applied at (m, n) —
        consulted by `GemmPolicy.plan_for` so the perfmodel-driven 'auto'
        selections price the per-shard problem plus the psum term."""
        axes = self.resolve_axes(m, n)
        r, md, nd = axes.sizes(self.mesh)
        return md, nd, r

    def run_plan(self, plan, a, b):
        """Execute `plan` on (m, k) x (k, n) sharded over the mesh."""
        if getattr(a, "ndim", 0) != 2 or getattr(b, "ndim", 0) != 2:
            raise ValueError(
                "sharded execution supports 2D operands; reshape leading "
                "batch dims into rows (policy_matmul does) — got "
                f"{getattr(a, 'shape', None)} @ {getattr(b, 'shape', None)}"
            )
        axes = self.resolve_axes(a.shape[0], b.shape[1])
        specs = residue_plane_specs(axes)

        def body(al, bl):
            worker = _ShardWorker(self.inner, plan.ctx, axes, self.mesh)
            return execute_plan(plan, al, bl, worker)

        fn = _shard_map(
            body,
            mesh=self.mesh,
            in_specs=(specs["a"], specs["b"]),
            out_specs=specs["out"],
            check_rep=False,
        )
        return fn(a, b)
