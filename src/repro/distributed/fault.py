"""Fault-tolerance runtime policies: preemption handling + straggler watch.

At 1000+ nodes the per-step failure probability is O(nodes * MTBF^-1); the
framework's contract is:

  * SIGTERM/SIGINT (preemption notice) => finish the in-flight step, write a
    blocking checkpoint, exit cleanly (`PreemptionGuard`).
  * Straggler mitigation: per-step wall-clock EWMA; a step slower than
    `threshold x` the EWMA is logged with its data shard so the launcher can
    re-balance or evict the slow host (`StragglerWatch`).  On TPU pods the
    collectives are synchronous, so detection (not async execution) is the
    actionable knob; the deterministic (step, shard) data pipeline makes
    shard re-assignment safe.
  * Elastic restart path: distributed/elastic.py.
"""
from __future__ import annotations

import signal
import time


class PreemptionGuard:
    """Context manager: converts SIGTERM/SIGINT into a 'should_stop' flag
    checked at step boundaries, guaranteeing a final checkpoint."""

    def __init__(self):
        self.should_stop = False
        self._prev = {}

    def _handler(self, signum, frame):
        self.should_stop = True

    def __enter__(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except ValueError:  # non-main thread (tests)
                pass
        return self

    def __exit__(self, *exc):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        return False


class StragglerWatch:
    def __init__(self, threshold: float = 2.0, alpha: float = 0.1):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma = None
        self.flagged: list[tuple[int, float]] = []
        self._t0 = None

    def step_begin(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> bool:
        dt = time.monotonic() - self._t0
        slow = False
        if self.ewma is not None and dt > self.threshold * self.ewma:
            self.flagged.append((step, dt))
            slow = True
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt
        )
        return slow
