from .sharding import (
    DEFAULT_RULES,
    batch_pspec,
    batch_sharding,
    optimizer_spec,
    pspec_for_axes,
    tree_pspecs,
    tree_shardings,
)

__all__ = [
    "DEFAULT_RULES",
    "batch_pspec",
    "batch_sharding",
    "optimizer_spec",
    "pspec_for_axes",
    "tree_pspecs",
    "tree_shardings",
]
