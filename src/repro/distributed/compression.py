"""Int8 error-feedback gradient compression for the DP all-reduce.

At 1000+ node scale the data-parallel gradient all-reduce crosses DCN (pod)
links; int8 quantization cuts those bytes 4x (vs f32 / 2x vs bf16).  Error
feedback keeps the *accumulated* quantization error in an f32 buffer that is
re-injected the next step, so convergence matches uncompressed SGD/Adam to
first order (validated in tests/test_distributed.py).

Reuses the paper's machinery: symmetric scaling + round-to-nearest int8 is
exactly the residue-cast quantizer with a single 'modulus' of 2^8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def error_feedback_psum(grad, err, axis_name: str):
    """Compressed psum of `grad` over `axis_name` with error feedback.

    Must run inside shard_map.  Returns (mean_grad, new_err).
    """
    g32 = grad.astype(jnp.float32) + err
    _, scale = quantize_int8(g32)
    # shared scale across shards so the int32 reduction is exact
    smax = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(g32 / smax), -127, 127).astype(jnp.int32)
    new_err = g32 - q.astype(jnp.float32) * smax
    total = jax.lax.psum(q, axis_name).astype(jnp.float32) * smax
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total / n).astype(grad.dtype), new_err


def tree_error_feedback_psum(grads, errs, axis_name: str):
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errs)
    out = [error_feedback_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def init_error_buffers(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
