"""python -m repro.tune — run the calibration microbench + block autotuner.

Measures the live backend (`repro.tune.calibrate`), autotunes the Pallas
block shapes (`repro.tune.autotune`), and persists both to the calibration
cache.  CI runs this in ``--smoke`` mode (the `tier1-tune` job) and then
re-certifies the full policy matrix with the cache loaded::

    PYTHONPATH=src python -m repro.tune --smoke --out calibration.json
    PYTHONPATH=src python -m repro.analysis --matrix smoke \\
        --calibration calibration.json
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="one-shot on-device calibration + Pallas block autotune",
    )
    ap.add_argument("--smoke", action="store_true",
                    help="tiny probes/shapes (CI: seconds on a CPU host; "
                         "numbers are noisy but structurally valid)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="cache file to write (default: the per-backend "
                         "default_cache_path())")
    ap.add_argument("--no-blocks", dest="blocks", action="store_false",
                    help="skip the block autotuner (measure HW only)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every measurement and candidate timing")
    args = ap.parse_args(argv)

    import repro  # noqa: F401  (x64 on, matching every other entry point)
    from .cache import calibration_hash, default_cache_path, save_calibration
    from .calibrate import calibrate

    cal = calibrate(smoke=args.smoke, blocks=args.blocks,
                    verbose=args.verbose)
    path = save_calibration(cal, args.out or default_cache_path())
    print(
        f"repro.tune: calibrated {cal.device_kind} x{cal.device_count} "
        f"(jax {cal.jax_version}) -> {path}\n"
        f"  hw: mem_bw={cal.hw.mem_bw:.3e} B/s int8={cal.hw.int8_ops:.3e} "
        f"OPS fp8={cal.hw.fp8_ops:.3e} OPS "
        f"launch={cal.hw.gemm_launch_s:.2e} s\n"
        f"  blocks: {len(cal.blocks)} tuned slots; "
        f"cache hash {calibration_hash(cal)}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
