"""The calibration cache: measured `HW` + tuned Pallas blocks, persisted.

One JSON file holds everything `repro.tune` measured on a machine, keyed by
the (device kind, device count, jax version) triple it was measured on:

.. code-block:: json

    {
      "schema": 1,
      "key": {"device_kind": "cpu", "device_count": 1,
              "jax_version": "0.4.37"},
      "hw": {"name": "calibrated/cpu", "mem_bw": 1.2e10, "int8_ops": 4.1e10,
             "native_c64": 3.0e9, "native_c128": 1.0e9, "ici_bw": 9e10,
             "fp8_ops": 0.0, "gemm_launch_s": 2.1e-4,
             "collective_launch_s": 2e-5},
      "blocks": {"kernel/real/m256n256k512": [256, 256, 256]}
    }

* ``hw`` is a full `perfmodel.HW` field dict (see `HW.from_calibration` for
  which entries come from measurement vs preset fallbacks);
* ``blocks`` maps ``"{family}/{dclass}/{bucket}"`` keys — family in
  ``kernel``/``fused``/``fp8``, dclass in ``real``/``complex``, bucket the
  power-of-two shape bucket of `shape_bucket` — to the autotuned
  ``[bm, bn, bk]`` winner for that slot (`repro.tune.autotune`).

Staleness: `load_calibration` compares the stored key against the live
backend and warns + returns None on mismatch (so callers fall back to the
presets + static default blocks), likewise for unreadable/corrupt files.
Loading never raises for a bad cache — a broken calibration must degrade to
exactly the uncalibrated behaviour, not take the run down.

Scoping mirrors the policy/mesh pattern: `use_calibration` pushes onto a
thread-local stack (innermost wins), `set_calibration` installs a
process-global default underneath it, and `current_calibration` is what
`perfmodel.default_hw` / `kernels.common.resolve_blocks` consult at trace
time.  Calibrations are frozen/hashable, so holding one inside jit-static
machinery is safe.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import hashlib
import json
import os
import threading
import warnings

from ..core.perfmodel import HW

SCHEMA_VERSION = 1

#: Pallas kernel families the autotuner covers, by policy execution
FAMILIES = ("kernel", "fused", "fp8")

#: operand dtype classes (complex runs the Karatsuba kernels)
DCLASSES = ("real", "complex")


def shape_bucket(m: int, n: int, k: int) -> str:
    """The cache bucket one (m, k) x (k, n) GEMM shape falls into.

    Each dim rounds up to a power of two, floored at 128 (the MXU tile) and
    capped at 16384 (the paper's largest benchmark dim) — nearby shapes
    share one tuned block triple, so a handful of autotuned shapes covers
    the whole size sweep.
    """
    def _b(d: int) -> int:
        v = 128
        while v < d and v < 16384:
            v <<= 1
        return v

    return f"m{_b(m)}n{_b(n)}k{_b(k)}"


def block_key(family: str, dclass: str, m: int, n: int, k: int) -> str:
    """The ``blocks`` mapping key for one (family, dclass, shape) slot."""
    if family not in FAMILIES:
        raise ValueError(f"unknown kernel family {family!r}; one of {FAMILIES}")
    if dclass not in DCLASSES:
        raise ValueError(f"unknown dtype class {dclass!r}; one of {DCLASSES}")
    return f"{family}/{dclass}/{shape_bucket(m, n, k)}"


@dataclasses.dataclass(frozen=True)
class Calibration:
    """One machine's measured model: `HW` + tuned blocks + the backend key.

    Frozen and hashable (``blocks`` is a sorted tuple of items, not a dict)
    so a calibration can ride wherever a `GemmPolicy` does.
    """

    device_kind: str
    device_count: int
    jax_version: str
    hw: HW
    blocks: tuple[tuple[str, tuple[int, int, int]], ...] = ()

    def block_for(self, key: str) -> tuple[int, int, int] | None:
        """The tuned (bm, bn, bk) for one `block_key`, or None (untuned)."""
        for k, v in self.blocks:
            if k == key:
                return v
        return None

    def with_blocks(self, blocks: dict) -> "Calibration":
        """A copy with `blocks` replaced by the (canonically sorted) dict."""
        items = tuple(
            (str(k), tuple(int(x) for x in v))
            for k, v in sorted(blocks.items())
        )
        return dataclasses.replace(self, blocks=items)

    def to_json(self) -> dict:
        return {
            "schema": SCHEMA_VERSION,
            "key": {
                "device_kind": self.device_kind,
                "device_count": self.device_count,
                "jax_version": self.jax_version,
            },
            "hw": dataclasses.asdict(self.hw),
            "blocks": {k: list(v) for k, v in self.blocks},
        }

    @classmethod
    def from_json(cls, obj: dict) -> "Calibration":
        if obj.get("schema") != SCHEMA_VERSION:
            raise ValueError(
                f"calibration schema {obj.get('schema')!r} != {SCHEMA_VERSION}"
            )
        key = obj["key"]
        blocks = obj.get("blocks", {})
        bad = {
            k: v for k, v in blocks.items()
            if not (isinstance(v, (list, tuple)) and len(v) == 3
                    and all(int(x) > 0 for x in v))
        }
        if bad:
            raise ValueError(f"malformed block winners: {bad}")
        return cls(
            device_kind=str(key["device_kind"]),
            device_count=int(key["device_count"]),
            jax_version=str(key["jax_version"]),
            hw=HW(**obj["hw"]),
        ).with_blocks(blocks)


def live_key() -> dict:
    """The (device kind, device count, jax version) of this process."""
    import jax

    return {
        "device_kind": jax.devices()[0].device_kind,
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
    }


def calibration_hash(cal: Calibration | None) -> str | None:
    """Short content hash of a calibration (None passes through).

    Stamped onto every `bench_throughput` record so tuned and untuned runs
    are distinguishable in the committed trajectory.
    """
    if cal is None:
        return None
    blob = json.dumps(cal.to_json(), sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


def default_cache_path() -> str:
    """Where `--calibrate run` persists and `--calibrate load` looks by
    default: ``$REPRO_CALIBRATION_DIR`` (else ``~/.cache/repro``) /
    ``calibration-{device_kind}-{device_count}.json``."""
    key = live_key()
    base = os.environ.get(
        "REPRO_CALIBRATION_DIR",
        os.path.join(os.path.expanduser("~"), ".cache", "repro"),
    )
    kind = str(key["device_kind"]).replace(" ", "_").replace("/", "_")
    return os.path.join(
        base, f"calibration-{kind}-{key['device_count']}.json"
    )


def save_calibration(cal: Calibration, path: str) -> str:
    """Write the cache JSON (creating parent dirs); returns `path`."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(cal.to_json(), f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def load_calibration(
    path: str, *, check_staleness: bool = True
) -> Calibration | None:
    """Load a calibration cache, or None (with a warning) when it is unfit.

    "Unfit" covers a missing/unreadable file, corrupt or schema-mismatched
    JSON, and — with `check_staleness` — a key that no longer matches the
    live backend (different device kind/count or jax version: the measured
    rates and tuned blocks describe a different machine).  Returning None
    makes every consumer fall back to the presets + static default blocks,
    so a bad cache can never change behaviour, only forgo the tuning.
    """
    try:
        with open(path) as f:
            cal = Calibration.from_json(json.load(f))
    except (OSError, ValueError, KeyError, TypeError) as e:
        warnings.warn(
            f"calibration cache {path!r} is unreadable ({e!r}); "
            "falling back to the hardware presets and default blocks",
            RuntimeWarning,
            stacklevel=2,
        )
        return None
    if check_staleness:
        key = live_key()
        stored = {
            "device_kind": cal.device_kind,
            "device_count": cal.device_count,
            "jax_version": cal.jax_version,
        }
        if stored != key:
            warnings.warn(
                f"calibration cache {path!r} is stale: measured on {stored}, "
                f"running on {key}; falling back to the hardware presets and "
                "default blocks (re-run `python -m repro.tune` to refresh)",
                RuntimeWarning,
                stacklevel=2,
            )
            return None
    return cal


@functools.lru_cache(maxsize=64)
def load_calibration_cached(path: str) -> Calibration | None:
    """`load_calibration` memoized per path — the `GemmPolicy(calibration=)`
    resolution path, called on every trace.  The stale/corrupt warning fires
    once per path per process instead of once per matmul."""
    return load_calibration(path)


# ------------------------------------------- active-calibration scoping

_STATE = threading.local()
_GLOBAL: list[Calibration | None] = [None]


def current_calibration() -> Calibration | None:
    """The innermost `use_calibration` calibration, else the process-global
    `set_calibration` default, else None (presets + static blocks)."""
    stack = getattr(_STATE, "stack", None)
    if stack:
        return stack[-1]
    return _GLOBAL[0]


def set_calibration(cal: Calibration | None) -> Calibration | None:
    """Install `cal` as the process-global default calibration (the
    `--calibrate load/run` CLI entry); returns the previous default."""
    if cal is not None and not isinstance(cal, Calibration):
        raise TypeError(
            f"set_calibration expects a Calibration or None; got "
            f"{type(cal).__name__}"
        )
    prev = _GLOBAL[0]
    _GLOBAL[0] = cal
    return prev


@contextlib.contextmanager
def use_calibration(cal: Calibration | str):
    """Scope the thread-local active calibration (innermost wins).

    Accepts a `Calibration` or a cache-file path (loaded via
    `load_calibration`; an unfit file warns and the scope is a no-op, so the
    body runs on presets + defaults rather than failing).  Also reachable as
    ``repro.use_calibration`` and via ``repro.use_policy(policy,
    calibration=...)``.
    """
    if isinstance(cal, (str, os.PathLike)):
        cal = load_calibration(os.fspath(cal))
    if cal is not None and not isinstance(cal, Calibration):
        raise TypeError(
            f"use_calibration expects a Calibration or a cache path; got "
            f"{type(cal).__name__}"
        )
    if cal is None:
        yield None
        return
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    stack.append(cal)
    try:
        yield cal
    finally:
        stack.pop()
