"""Shared ``--calibrate`` argparse surface of the launch CLIs.

Every CLI that runs emulated GEMMs (`launch/train`, `launch/serve`,
`launch/dryrun`, `benchmarks/bench_throughput`) exposes the same two flags:

    --calibrate {off,load,run}   off (default): presets + static blocks —
                                 bitwise identical to the pre-calibration
                                 behaviour.  load: read the calibration
                                 cache (warn + presets when missing/stale).
                                 run: run the microbench + autotuner now,
                                 persist the cache, then use it.
    --calibration-file PATH      cache location (default: the per-backend
                                 `default_cache_path()`)

`apply_calibration_args` resolves the flags into a process-global
`set_calibration` default and returns the active `Calibration` (or None),
so everything the CLI subsequently traces prices and tiles against it.
"""
from __future__ import annotations

from .cache import (
    Calibration,
    default_cache_path,
    load_calibration,
    save_calibration,
    set_calibration,
)


def add_calibration_args(ap) -> None:
    """Install the shared --calibrate / --calibration-file flags on `ap`."""
    ap.add_argument(
        "--calibrate", choices=["off", "load", "run"], default="off",
        help="on-device calibration: 'load' reads the calibration cache "
             "(measured HW + tuned Pallas blocks; warns and falls back to "
             "the presets when missing/stale), 'run' measures now and "
             "persists the cache, 'off' (default) keeps the hardware "
             "presets and static default blocks",
    )
    ap.add_argument(
        "--calibration-file", default=None, metavar="PATH",
        help="calibration cache location (default: "
             "$REPRO_CALIBRATION_DIR/calibration-<kind>-<count>.json)",
    )


def apply_calibration_args(args, *, smoke: bool = False) -> Calibration | None:
    """Resolve the flags: load/run as requested, install the result as the
    process-global calibration, and return it (None = presets)."""
    mode = getattr(args, "calibrate", "off")
    if mode == "off":
        return None
    path = getattr(args, "calibration_file", None) or default_cache_path()
    if mode == "run":
        from .calibrate import calibrate

        cal = calibrate(smoke=smoke)
        save_calibration(cal, path)
        print(f"calibration: measured + tuned -> {path}")
    else:
        cal = load_calibration(path)
        if cal is None:
            print(
                f"calibration: no usable cache at {path} — running on "
                "hardware presets and default blocks"
            )
        else:
            print(f"calibration: loaded {path} ({cal.hw.name})")
    set_calibration(cal)
    return cal
