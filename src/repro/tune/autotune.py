"""Block-size autotuner for the batched/fused Pallas GEMM kernels.

Times the *public kernel entry points* — the exact functions the residue
backends call — over a small aligned candidate grid of (bm, bn, bk) per
(kernel family, dtype class, shape bucket), and returns the winners in the
`Calibration.blocks` format (`cache.block_key` -> (bm, bn, bk)).

Three facts make this safe and cheap:

* every kernel pads-and-slices (`kernels/common.block_and_padded`), so the
  block shape can never change numerics — the autotuner only ever trades
  speed, which is why the winners need no accuracy re-validation;
* the static default ``(256, 256, 512)`` is always in the candidate set, so
  a tuned configuration is never *measured* slower than the default at tune
  time — throughput can only hold or improve;
* candidates are MXU-aligned multiples (bm/bn of 128, bk of at least 128),
  and they flow through the same `block_and_padded` selection the defaults
  do, so a tuned block larger than a dim still shrinks exactly like the
  default would.

Smoke mode (CI) shrinks the shapes and the candidate grid so the whole
sweep stays in interpret-mode-on-CPU budget; a full run on real hardware
sweeps a wider grid per bucket.
"""
from __future__ import annotations

import time

from .cache import block_key

#: (bm, bn, bk) grids; the static kernel default leads both lists
DEFAULT_BLOCKS = (256, 256, 512)
_CANDIDATES_FULL = (
    DEFAULT_BLOCKS,
    (128, 128, 512),
    (128, 256, 512),
    (256, 128, 512),
    (256, 256, 256),
    (512, 512, 512),
    (256, 256, 1024),
)
_CANDIDATES_SMOKE = (
    DEFAULT_BLOCKS,
    (128, 128, 256),
)

#: tuned GEMM shapes: one bucket-representative per mode.  Smoke covers the
#: floor bucket (m128n128k128 — where the CI bench's tiny shapes land) plus
#: one multi-tile bucket so the sweep exercises a real grid.
_SHAPES_FULL = ((512, 512, 1024), (2048, 2048, 2048))
_SHAPES_SMOKE = ((128, 128, 128), (256, 128, 256))

_N_MODULI_SMOKE = 4
_N_MODULI_FULL = 8


def _median_time_s(fn, iters: int) -> float:
    import jax
    import numpy as np

    jax.block_until_ready(fn())  # compile + warm
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _make_entry(family: str, dclass: str, m: int, n: int, k: int,
                n_moduli: int):
    """A closure (bm, bn, bk) -> jitted-call thunk for one kernel slot."""
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..core.moduli import make_crt_context
    from ..core.plan import n_limbs_for_ctx
    from ..kernels.fp8_mod_gemm import (
        fp8_karatsuba_mod_gemm_batched,
        fp8_mod_gemm_batched,
    )
    from ..kernels.int8_mod_gemm import fused_mod_gemm, int8_mod_gemm_batched
    from ..kernels.karatsuba_fused import (
        fused_karatsuba_mod_gemm,
        karatsuba_mod_gemm_batched,
    )

    ctx = make_crt_context(n_moduli)
    rng = np.random.default_rng(0)

    def _planes(shape):
        return jnp.asarray(rng.integers(-60, 61, shape, dtype=np.int8))

    if family in ("kernel", "fp8"):
        if dclass == "real":
            kern = (fp8_mod_gemm_batched if family == "fp8"
                    else int8_mod_gemm_batched)
            a, b = _planes((n_moduli, m, k)), _planes((n_moduli, k, n))

            def entry(bm, bn, bk):
                f = functools.partial(
                    kern, a, b, moduli=ctx.moduli, bm=bm, bn=bn, bk=bk
                )
                return lambda: f()
        else:
            kern = (fp8_karatsuba_mod_gemm_batched if family == "fp8"
                    else karatsuba_mod_gemm_batched)
            ops = (_planes((n_moduli, m, k)), _planes((n_moduli, m, k)),
                   _planes((n_moduli, k, n)), _planes((n_moduli, k, n)))

            def entry(bm, bn, bk):
                f = functools.partial(
                    kern, *ops, moduli=ctx.moduli, bm=bm, bn=bn, bk=bk
                )
                return lambda: f()
        return entry

    if family != "fused":
        raise ValueError(f"unknown kernel family {family!r}")
    n_limbs = n_limbs_for_ctx(ctx)
    e_mu = jnp.zeros((m,), jnp.int32)
    e_nu = jnp.zeros((n,), jnp.int32)

    def _mant(shape):
        return jnp.asarray(rng.integers(-500, 501, shape), jnp.float32)

    if dclass == "real":
        a, b = _mant((m, k)), _mant((k, n))

        def entry(bm, bn, bk):
            def call():
                return fused_mod_gemm(
                    a, b, e_mu, e_nu, ctx, n_limbs=n_limbs,
                    bm=bm, bn=bn, bk=bk,
                )
            return call
    else:
        ar, ai = _mant((m, k)), _mant((m, k))
        br, bi = _mant((k, n)), _mant((k, n))

        def entry(bm, bn, bk):
            def call():
                return fused_karatsuba_mod_gemm(
                    ar, ai, br, bi, e_mu, e_nu, ctx, n_limbs=n_limbs,
                    bm=bm, bn=bn, bk=bk,
                )
            return call
    return entry


def autotune_blocks(
    smoke: bool = False,
    *,
    families: tuple[str, ...] = ("kernel", "fused", "fp8"),
    dclasses: tuple[str, ...] = ("real", "complex"),
    shapes: tuple[tuple[int, int, int], ...] | None = None,
    candidates: tuple[tuple[int, int, int], ...] | None = None,
    iters: int = 2,
    verbose: bool = False,
) -> dict:
    """Sweep the candidate grid; returns {block_key: (bm, bn, bk)} winners.

    The static default triple is force-included in `candidates`, so the
    recorded winner for every slot is measured at least as fast as the
    default at tune time.
    """
    shapes = shapes or (_SHAPES_SMOKE if smoke else _SHAPES_FULL)
    candidates = tuple(candidates or
                       (_CANDIDATES_SMOKE if smoke else _CANDIDATES_FULL))
    if DEFAULT_BLOCKS not in candidates:
        candidates = (DEFAULT_BLOCKS,) + candidates
    n_moduli = _N_MODULI_SMOKE if smoke else _N_MODULI_FULL
    winners: dict = {}
    for family in families:
        for dclass in dclasses:
            for m, n, k in shapes:
                entry = _make_entry(family, dclass, m, n, k, n_moduli)
                best, best_t = None, float("inf")
                for bm, bn, bk in candidates:
                    t = _median_time_s(entry(bm, bn, bk), iters)
                    if verbose:
                        print(
                            f"  tune {family}/{dclass} {m}x{n}x{k} "
                            f"({bm},{bn},{bk}): {t * 1e6:.0f} us"
                        )
                    if t < best_t:
                        best, best_t = (bm, bn, bk), t
                winners[block_key(family, dclass, m, n, k)] = best
    return winners
