"""`repro.tune` — on-device calibration + Pallas block autotuning.

The measured counterpart of `core/perfmodel`'s hardware presets: a one-shot
microbenchmark (`calibrate`) measures the live backend's int8/fp8 dot
rates, memory and psum bandwidth and per-launch overheads into an
`HW.from_calibration` instance, an autotuner (`autotune_blocks`) times the
batched/fused Pallas kernels over a (bm, bn, bk) candidate grid, and both
persist to one JSON calibration cache (`cache`) keyed by (device kind,
device count, jax version).

Activating a calibration (`use_calibration` scope, `set_calibration`
process default, or a `GemmPolicy(calibration=path)` pin) makes every
``"auto"`` decision — formulation, n_block, engine, the sharded comm term —
price against the *measured* `HW` (`perfmodel.default_hw`), and makes the
`kernel`/`fused`/`fp8` executions launch the tuned tile shapes
(`kernels.common.resolve_blocks`).  With no calibration active, behaviour
is bitwise identical to the presets + static default blocks.

CLI::

    PYTHONPATH=src python -m repro.tune [--smoke] [--out PATH] [--no-blocks]

See docs/calibration.md for the cache schema and the `--calibrate`
workflow of the launch CLIs.
"""
from .cache import (  # noqa: F401
    Calibration,
    block_key,
    calibration_hash,
    current_calibration,
    default_cache_path,
    load_calibration,
    load_calibration_cached,
    save_calibration,
    set_calibration,
    shape_bucket,
    use_calibration,
)

__all__ = [
    "Calibration",
    "add_calibration_args",
    "apply_calibration_args",
    "autotune_blocks",
    "block_key",
    "calibrate",
    "calibration_hash",
    "current_calibration",
    "default_cache_path",
    "load_calibration",
    "load_calibration_cached",
    "save_calibration",
    "set_calibration",
    "shape_bucket",
    "use_calibration",
]


def __getattr__(name):
    # calibrate/autotune pull in jax + the kernel stack; load them lazily so
    # `import repro` (which re-exports use_calibration) stays light
    if name == "calibrate":
        from .calibrate import calibrate

        return calibrate
    if name == "autotune_blocks":
        from .autotune import autotune_blocks

        return autotune_blocks
    if name in ("add_calibration_args", "apply_calibration_args"):
        from . import cli

        return getattr(cli, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
