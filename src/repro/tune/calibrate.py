"""One-shot on-device calibration microbenchmark -> measured `HW`.

Measures, on whatever backend this process actually runs on:

* sustained int8-dot MAC rate (`HW.int8_ops` — ops counted as mul+add, the
  SIII-C model's `p`), via a jitted int8 x int8 -> int32 `dot_general`;
* sustained fp8 (e4m3) dot rate (`HW.fp8_ops`), when the backend supports
  e4m3 matmuls — 0.0 otherwise, which the model reads as "no native fp8";
* memory bandwidth (`HW.mem_bw`), via a streaming read+write over an array
  far larger than cache;
* per-`pallas_call` launch overhead (`HW.gemm_launch_s`), via a tiny Pallas
  copy kernel whose compute is negligible — wall time IS the dispatch cost
  (in interpret mode off-TPU this is large, and that is the truth the model
  should price launches at on this host);
* native complex GEMM rates (`HW.native_c64` / `native_c128`) for the
  speedup-over-native comparisons (0.0 where the dtype is unsupported);
* per-device psum bandwidth + collective launch overhead (`HW.ici_bw` /
  `HW.collective_launch_s`) when >1 device is visible — single-device hosts
  keep the presets (there is nothing to measure).

`calibrate()` bundles the measurements with the `repro.tune.autotune` block
winners into a `Calibration` ready for `save_calibration`.  Smoke mode
shrinks every probe so the whole calibration finishes in seconds on a CPU
CI host; the measured numbers are then noisy but structurally valid — and
by design calibration can only ever change *speed*, never numerics.
"""
from __future__ import annotations

import time

from .cache import Calibration, live_key

# probe sizes: (smoke, full)
_MEM_ELEMS = (1 << 20, 1 << 24)       # f32 elements of the bandwidth probe
_DOT_DIM = (256, 1024)                # square dim of the engine-rate probes
_NATIVE_DIM = (128, 512)
_PSUM_ELEMS = (1 << 16, 1 << 22)      # per-device f32 elements


def _time_s(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-seconds per call of a jitted fn (blocks on the result)."""
    import jax
    import numpy as np

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _measure_mem_bw(smoke: bool) -> float:
    import jax
    import jax.numpy as jnp

    n = _MEM_ELEMS[0] if smoke else _MEM_ELEMS[1]
    x = jnp.arange(n, dtype=jnp.float32)
    f = jax.jit(lambda v: v * 1.000001 + 1.0)
    t = _time_s(f, x)
    return 2.0 * 4.0 * n / t  # one read + one write of 4-byte elements


def _measure_int8_ops(smoke: bool) -> float:
    import jax
    import jax.lax as lax
    import jax.numpy as jnp
    import numpy as np

    d = _DOT_DIM[0] if smoke else _DOT_DIM[1]
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-63, 64, (d, d), dtype=np.int8))
    b = jnp.asarray(rng.integers(-63, 64, (d, d), dtype=np.int8))
    f = jax.jit(
        lambda x, w: lax.dot_general(
            x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
    )
    t = _time_s(f, a, b)
    return 2.0 * d**3 / t


def _measure_fp8_ops(smoke: bool) -> float:
    """e4m3 dot rate, 0.0 when the backend cannot run one at all."""
    import jax
    import jax.lax as lax
    import jax.numpy as jnp
    import numpy as np

    d = _DOT_DIM[0] if smoke else _DOT_DIM[1]
    try:
        e4m3 = jnp.float8_e4m3fn
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(-7, 8, (d, d)), jnp.float32).astype(e4m3)
        b = jnp.asarray(rng.integers(-7, 8, (d, d)), jnp.float32).astype(e4m3)
        f = jax.jit(
            lambda x, w: lax.dot_general(
                x, w, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        )
        t = _time_s(f, a, b)
        return 2.0 * d**3 / t
    except Exception:
        return 0.0


def _measure_native_rate(dtype_name: str, smoke: bool) -> float:
    """Native complex GEMM flop rate (8 m n k flops), 0.0 if unsupported."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    d = _NATIVE_DIM[0] if smoke else _NATIVE_DIM[1]
    try:
        dt = jnp.dtype(dtype_name)
        rng = np.random.default_rng(0)
        a = jnp.asarray(
            (rng.standard_normal((d, d)) + 1j * rng.standard_normal((d, d)))
        ).astype(dt)
        f = jax.jit(jnp.matmul)
        t = _time_s(f, a, a)
        return 8.0 * d**3 / t
    except Exception:
        return 0.0


def _measure_gemm_launch_s() -> float:
    """Wall time of a compute-free Pallas launch (the dispatch overhead)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    from ..kernels.common import interpret_default

    def _copy(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    x = jnp.zeros((8, 128), jnp.float32)
    f = jax.jit(
        lambda v: pl.pallas_call(
            _copy,
            out_shape=jax.ShapeDtypeStruct(v.shape, v.dtype),
            interpret=interpret_default(),
        )(v)
    )
    return _time_s(f, x)


def _measure_psum(smoke: bool) -> tuple[float, float]:
    """(ici_bw B/s, collective_launch_s); (0, 0) on single-device hosts
    (meaning "not measured" — `HW.from_calibration` keeps the presets)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    d = jax.device_count()
    if d < 2:
        return 0.0, 0.0
    f = jax.pmap(lambda x: jax.lax.psum(x, "i"), axis_name="i")
    tiny = jnp.zeros((d, 8), jnp.float32)
    t_tiny = _time_s(f, tiny)
    n = _PSUM_ELEMS[0] if smoke else _PSUM_ELEMS[1]
    big = jnp.asarray(
        np.random.default_rng(0).standard_normal((d, n)), jnp.float32
    )
    t_big = _time_s(f, big)
    # ring all-reduce moves ~2(d-1)/d of the payload per device
    byts = 2.0 * (d - 1) / d * 4.0 * n
    bw = byts / max(t_big - t_tiny, 1e-9)
    return bw, t_tiny


def measure_hw(smoke: bool = False) -> dict:
    """Run every microbenchmark; returns the `HW.from_calibration` dict."""
    ici_bw, coll_s = _measure_psum(smoke)
    return {
        "mem_bw": _measure_mem_bw(smoke),
        "int8_ops": _measure_int8_ops(smoke),
        "fp8_ops": _measure_fp8_ops(smoke),
        "native_c64": _measure_native_rate("complex64", smoke),
        "native_c128": _measure_native_rate("complex128", smoke),
        "gemm_launch_s": _measure_gemm_launch_s(),
        "ici_bw": ici_bw,
        "collective_launch_s": coll_s,
    }


def calibrate(
    smoke: bool = False, *, blocks: bool = True, verbose: bool = False
) -> Calibration:
    """The one-shot calibration: microbench + (optionally) block autotune.

    Returns a `Calibration` for the live backend, ready to persist with
    `save_calibration` and activate with `set_calibration` /
    `use_calibration`.  `blocks=False` skips the autotuner (HW only).
    """
    from ..core.perfmodel import HW

    key = live_key()
    meas = measure_hw(smoke)
    if verbose:
        for k in sorted(meas):
            print(f"  measured {k:>20s} = {meas[k]:.3e}")
    hw = HW.from_calibration(meas, name=f"calibrated/{key['device_kind']}")
    cal = Calibration(hw=hw, **key)
    if blocks:
        from .autotune import autotune_blocks

        cal = cal.with_blocks(autotune_blocks(smoke=smoke, verbose=verbose))
    return cal
