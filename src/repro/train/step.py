"""The jitted training step: loss -> grads (with microbatch accumulation) ->
AdamW, fully sharded via in/out shardings derived from the logical rules.

`make_train_step(..., mesh=None)` also works on a single device (tests,
examples); with a mesh it returns the pjit'd step plus the sharding trees
used by the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import (
    DEFAULT_RULES,
    batch_sharding,
    optimizer_spec,
    tree_pspecs,
    tree_shardings,
)
from ..models.transformer import Model
from ..optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any

    def tree(self):
        return {"params": self.params, "opt": self.opt}


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    schedule: Callable | None = None,
    mesh: Mesh | None = None,
    rules=None,
    grad_accum: int = 1,
    donate: bool = True,
):
    """Returns (train_step, shardings) — shardings is None off-mesh."""
    rules = rules or DEFAULT_RULES

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def step_fn(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            # split the global batch into microbatches along the batch dim
            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, -1) + x.shape[1:]), batch
            )
            # accumulate in at least f32, widening to the param's own dtype
            # class (f64 / complex64 / complex128 grads must not be forced
            # through a narrower carry: lax.scan requires equal carry types)
            zero = jax.tree.map(
                lambda p: jnp.zeros(
                    p.shape, jnp.promote_types(jnp.float32, p.dtype)
                ),
                params,
            )
            (gsum, lsum), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            metrics = {}
        lr_scale = schedule(opt_state["step"]) if schedule else 1.0
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg, lr_scale
        )
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0, 1) if donate else ()), None

    abstract = model.abstract_params()
    pspecs = tree_pspecs(abstract, rules, mesh)
    param_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P)
    )
    from ..distributed.sharding import pspec_for_meta
    from ..models.params import _map_like

    opt_leaf_sh = _map_like(
        abstract,
        lambda _, m: NamedSharding(
            mesh, optimizer_spec(pspec_for_meta(m, rules, mesh), m.shape, mesh)
        ),
    )
    opt_sh = {
        "step": NamedSharding(mesh, P()),
        "m": opt_leaf_sh,
        "v": opt_leaf_sh,
    }
    if opt_cfg.use_master:
        opt_sh["master"] = opt_leaf_sh
    batch_sh = batch_sharding(mesh, rules)
    metrics_sh = NamedSharding(mesh, P())
    step = jax.jit(
        step_fn,
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, metrics_sh),
        donate_argnums=(0, 1) if donate else (),
    )
    return step, {"params": param_sh, "opt": opt_sh, "batch": batch_sh}


def init_state(model: Model, opt_cfg: AdamWConfig, key, shardings=None):
    params = model.init(key)
    opt = adamw_init(params, opt_cfg)
    if shardings is not None:
        params = jax.tree.map(jax.device_put, params, shardings["params"])
        opt = jax.tree.map(jax.device_put, opt, shardings["opt"])
    return params, opt
