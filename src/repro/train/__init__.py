from .step import TrainState, make_train_step
from .loop import TrainLoopConfig, train_loop

__all__ = ["TrainState", "TrainLoopConfig", "make_train_step", "train_loop"]
