"""Training driver: data -> step -> metrics/checkpoint, with auto-resume,
preemption guard, and straggler watch (DESIGN.md S4)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from ..checkpoint import Checkpointer, latest_step
from ..data import DataConfig, SyntheticLM
from ..distributed.fault import PreemptionGuard, StragglerWatch
from ..models.transformer import Model
from ..optim import AdamWConfig, cosine_warmup
from .step import init_state, make_train_step


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    warmup: int = 10
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    grad_accum: int = 1
    seed: int = 0
    async_ckpt: bool = True


def train_loop(
    model: Model,
    data_cfg: DataConfig,
    loop_cfg: TrainLoopConfig,
    opt_cfg: AdamWConfig | None = None,
    mesh=None,
    batch_hook: Callable | None = None,
    log: Callable = print,
):
    """Runs (or resumes) training; returns (params, history)."""
    opt_cfg = opt_cfg or AdamWConfig()
    schedule = cosine_warmup(loop_cfg.warmup, loop_cfg.steps)
    step_fn, shardings = make_train_step(
        model, opt_cfg, schedule, mesh=mesh, grad_accum=loop_cfg.grad_accum
    )
    params, opt = init_state(model, opt_cfg, jax.random.PRNGKey(loop_cfg.seed), shardings)

    start = 0
    ckpt = None
    if loop_cfg.ckpt_dir:
        ckpt = Checkpointer(loop_cfg.ckpt_dir)
        last = latest_step(loop_cfg.ckpt_dir)
        if last is not None:
            state = ckpt.restore(last, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            start = last
            log(f"[resume] restored step {last} from {loop_cfg.ckpt_dir}")

    data = SyntheticLM(data_cfg)
    watch = StragglerWatch()
    history = []
    with PreemptionGuard() as guard:
        for step in range(start, loop_cfg.steps):
            batch = data.batch(step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            if batch_hook:
                batch = batch_hook(batch)
            watch.step_begin()
            params, opt, metrics = step_fn(params, opt, batch)
            loss = float(metrics["loss"])
            watch.step_end(step)
            history.append(loss)
            if step % loop_cfg.log_every == 0 or step == loop_cfg.steps - 1:
                log(f"step {step:5d} loss {loss:.4f} gnorm "
                    f"{float(metrics.get('grad_norm', np.nan)):.3f}")
            if ckpt and ((step + 1) % loop_cfg.ckpt_every == 0 or guard.should_stop):
                ckpt.save(
                    step + 1,
                    {"params": params, "opt": opt},
                    blocking=not loop_cfg.async_ckpt,
                )
            if guard.should_stop:
                log(f"[preempt] stopping cleanly at step {step}")
                break
    if ckpt:
        ckpt.wait()
    return params, history
