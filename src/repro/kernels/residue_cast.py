"""Pallas kernel: fused scale -> trunc -> limb-split -> N int8 residue planes.

Alg. 1 steps IV + V-i/ii in one pass over the input: reads the source matrix
tile once from HBM and writes all N residue planes, instead of N separate
elementwise passes (the paper's step-1 memory term `(3N + ...)k(m+n)/b` is
dominated by exactly this traffic).

Grid: (S, m/bm, k/bk) with S an optional leading *stack* dimension: a
(S, m, k) input casts S same-shaped matrices sharing one scale vector in a
single launch — the complex pipeline stacks the real and imaginary parts of
an operand so one operand costs one `pallas_call` regardless of dtype
class.  2D inputs are treated as S=1 and squeezed on return.

Block shapes: input (1, bm, bk) f32; scale factors (bm,) broadcast along
rows (axis=0 operand) or (bk,) along columns (axis=1); output
(1, N, bm, bk) int8 — N is small and static, the whole stack of output
tiles lives in VMEM (N * bm * bk bytes; 13 * 256 * 512 = 1.7 MiB).
Non-block-divisible m/k are zero-padded to the block grid and sliced back
(zeros are residue-exact; the scale vectors pad with 1.0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (
    block_and_padded,
    interpret_default,
    pad_dims,
    residue_tiles_f32,
)


def _kernel(a_ref, s1_ref, s2_ref, out_ref, *, moduli, n_limbs, scale_axis):
    tiles = residue_tiles_f32(
        a_ref[0], s1_ref[...], s2_ref[...],
        moduli=moduli, n_limbs=n_limbs, scale_axis=scale_axis,
    )
    for l in range(len(moduli)):
        out_ref[0, l, :, :] = tiles[l].astype(jnp.int8)


@functools.partial(
    jax.jit,
    static_argnames=("moduli", "n_limbs", "scale_axis", "bm", "bk", "interpret"),
)
def _stacked_call(a, scale1, scale2, *, moduli, n_limbs, scale_axis, bm, bk,
                  interpret):
    s, m, k = a.shape
    n = len(moduli)

    def smap(si, i, j):
        return (i,) if scale_axis == 0 else (j,)

    grid = (s, m // bm, k // bk)
    return pl.pallas_call(
        functools.partial(
            _kernel, moduli=moduli, n_limbs=n_limbs, scale_axis=scale_axis
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda si, i, j: (si, i, j)),
            pl.BlockSpec((bm if scale_axis == 0 else bk,), smap),
            pl.BlockSpec((bm if scale_axis == 0 else bk,), smap),
        ],
        out_specs=pl.BlockSpec((1, n, bm, bk), lambda si, i, j: (si, 0, i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n, m, k), jnp.int8),
        interpret=interpret,
    )(a, scale1, scale2)


def residue_cast(
    a: jnp.ndarray,
    scale1: jnp.ndarray,
    scale2: jnp.ndarray,
    *,
    moduli: tuple[int, ...],
    n_limbs: int,
    scale_axis: int = 0,
    bm: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """a: (m, k) or stacked (S, m, k) f32; scale1*scale2: power-of-two
    factors along `scale_axis` (shared by all S stack entries).  Returns
    (N, m, k) — or (S, N, m, k) for stacked input — int8 symmetric residues
    of trunc(a * scale), in one `pallas_call` either way."""
    if interpret is None:
        interpret = interpret_default()
    stacked = a.ndim == 3
    if not stacked:
        a = a[None]
    _, m, k = a.shape
    bm, mp = block_and_padded(m, bm, align=8)
    bk, kp = block_and_padded(k, bk, align=128)
    a = pad_dims(a, {1: mp, 2: kp})
    spad = mp if scale_axis == 0 else kp
    scale1 = pad_dims(scale1, {0: spad}, value=1.0)
    scale2 = pad_dims(scale2, {0: spad}, value=1.0)
    out = _stacked_call(
        a, scale1, scale2, moduli=tuple(moduli), n_limbs=n_limbs,
        scale_axis=scale_axis, bm=bm, bk=bk, interpret=bool(interpret),
    )[:, :, :m, :k]
    return out if stacked else out[0]
