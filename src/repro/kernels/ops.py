"""jit'd public wrappers around the Pallas kernels + a full kernel-path GEMM.

`ozaki2_gemm_kernels` / `ozaki2_cgemm_kernels` run the complete emulation
pipeline exactly as it would run on a TPU chip: residue_cast -> N x
int8_mod_gemm (or fused Karatsuba) -> crt_garner.  The pipeline structure is
not duplicated here: both entry points build an `EmulationPlan` and run the
shared executor (`repro.core.executor`) with :class:`KernelBackend`, which
maps the executor's residue primitives onto the Pallas kernels.  The
block-embedding formulations (paper eqs. 7/8) compose in the executor from
`residue_matmul`, so the kernel path supports all three Fig. 1 strategies.

On CPU the kernels execute in interpret mode; tests compare the pipeline
against `repro.core` (which itself is validated against exact integers).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from ..core.executor import chunked_residue_matmul, execute_plan
from ..core.moduli import CRTContext
from ..core.plan import default_n_moduli, make_plan
from .common import split_scale_exponent
from .crt_garner import crt_garner
from .int8_mod_gemm import int8_mod_gemm
from .karatsuba_fused import karatsuba_mod_gemm
from .residue_cast import residue_cast


@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """Residue backend running the Pallas TPU kernels (interpret mode on CPU).

    CRT reconstruction is always the Garner mixed-radix kernel (the only
    TPU-native path — no f64 on the VPU); f64-grade output uses its
    double-single (~2^-48) mode.
    """

    interpret: bool | None = None

    def cast(self, x, e, axis, ctx: CRTContext, n_limbs: int):
        s1, s2 = split_scale_exponent(e)
        return residue_cast(
            x.astype(jnp.float32),
            s1,
            s2,
            moduli=ctx.moduli,
            n_limbs=n_limbs,
            scale_axis=axis,
            interpret=self.interpret,
        )

    def _mod_gemm_stack(self, ares, bres, ctx: CRTContext):
        """Un-chunked per-modulus kernel launches (k <= K_CHUNK_LIMIT)."""
        planes = [
            int8_mod_gemm(
                ares[l], bres[l], p=int(ctx.moduli[l]), interpret=self.interpret
            )
            for l in range(ctx.n)
        ]
        return jnp.stack(planes, axis=0)

    def residue_matmul(self, ares, bres, ctx: CRTContext):
        return chunked_residue_matmul(
            lambda a, b: self._mod_gemm_stack(a, b, ctx), ares, bres, ctx
        )

    def karatsuba(self, arr, ari, brr, bri, ctx: CRTContext):
        """Fused-Karatsuba modular kernel: one launch per modulus."""
        er_planes, ei_planes = [], []
        for l in range(ctx.n):
            cr, ci = karatsuba_mod_gemm(
                arr[l],
                ari[l],
                brr[l],
                bri[l],
                p=int(ctx.moduli[l]),
                interpret=self.interpret,
            )
            er_planes.append(cr)
            ei_planes.append(ci)
        return jnp.stack(er_planes, axis=0), jnp.stack(ei_planes, axis=0)

    def reconstruct(self, e_res, e_mu, e_nu, ctx: CRTContext, method, out_dtype):
        if method != "garner":
            raise ValueError(
                f"the kernel backend only reconstructs via 'garner' (no f64 "
                f"on the TPU VPU); plan requested method={method!r}"
            )
        out_dd = jnp.dtype(out_dtype) == jnp.float64
        out = crt_garner(
            e_res, e_mu, e_nu, ctx, out_dd=out_dd, interpret=self.interpret
        )
        if out_dd:
            return out[0].astype(jnp.float64) + out[1].astype(jnp.float64)
        return out


@functools.partial(
    jax.jit, static_argnames=("n_moduli", "mode", "n_block", "interpret")
)
def ozaki2_gemm_kernels(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    n_block: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Full kernel-path real GEMM emulation (f32 in / f32 out).

    This is the TPU execution plan; numerically it provides f32-grade output
    (the double-single 'dd' output path of crt_garner serves f64-grade).
    """
    if n_moduli is None:
        n_moduli = default_n_moduli(jnp.float32, mode)
    plan = make_plan(
        jnp.float32,
        n_moduli=n_moduli,
        mode=mode,
        method="garner",
        n_block=n_block,
        out_dtype=jnp.float32,
        shape=(a.shape[-2], a.shape[-1], b.shape[-1]),
    )
    return execute_plan(plan, a, b, KernelBackend(interpret))


@functools.partial(
    jax.jit,
    static_argnames=("n_moduli", "mode", "formulation", "n_block", "interpret"),
)
def ozaki2_cgemm_kernels(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    formulation: str = "karatsuba",
    n_block: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Full kernel-path complex GEMM emulation (complex64 in/out).

    formulation 'karatsuba' uses the fused-Karatsuba modular kernel (one
    launch per modulus); 'block_a'/'block_b'/'auto' use the block embeddings
    composed over `int8_mod_gemm`.
    """
    if n_moduli is None:
        n_moduli = default_n_moduli(jnp.complex64, mode)
    plan = make_plan(
        jnp.complex64,
        n_moduli=n_moduli,
        mode=mode,
        method="garner",
        formulation=formulation,
        n_block=n_block,
        out_dtype=jnp.complex64,
        shape=(a.shape[-2], a.shape[-1], b.shape[-1]),
        fused_karatsuba=True,
    )
    return execute_plan(plan, a, b, KernelBackend(interpret))
