"""jit'd public wrappers around the Pallas kernels + a full kernel-path GEMM.

`ozaki2_gemm_kernels` / `ozaki2_cgemm_kernels` chain the three kernels into
the complete emulation pipeline exactly as it would run on a TPU chip:
residue_cast -> N x int8_mod_gemm (or fused Karatsuba) -> crt_garner.
On CPU the kernels execute in interpret mode; tests compare the pipeline
against `repro.core` (which itself is validated against exact integers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core import scaling
from ..core.gemm import default_n_moduli
from ..core.moduli import make_crt_context
from ..core.residues import num_limbs_for_bits
from .common import split_scale_exponent
from .crt_garner import crt_garner
from .int8_mod_gemm import int8_mod_gemm
from .karatsuba_fused import karatsuba_mod_gemm
from .residue_cast import residue_cast


def _prep(a, b, n_moduli, mode, complex_input):
    ctx = make_crt_context(n_moduli)
    if complex_input:
        ar, ai = jnp.real(a), jnp.imag(a)
        br, bi = jnp.real(b), jnp.imag(b)
        if mode == "fast":
            e_mu, e_nu = scaling.scale_fast_complex(ar, ai, br, bi, ctx)
        else:
            e_mu, e_nu = scaling.scale_accurate_complex(ar, ai, br, bi, ctx)
        parts = (ar, ai, br, bi)
    else:
        if mode == "fast":
            e_mu, e_nu = scaling.scale_fast_real(a, b, ctx)
        else:
            e_mu, e_nu = scaling.scale_accurate_real(a, b, ctx)
        parts = (a, b)
    n_limbs = num_limbs_for_bits(ctx.log2_P / 2.0 + 8.0)
    return ctx, e_mu, e_nu, n_limbs, parts


def _cast(x, e, axis, ctx, n_limbs, interpret):
    s1, s2 = split_scale_exponent(e)
    return residue_cast(
        x.astype(jnp.float32),
        s1,
        s2,
        moduli=ctx.moduli,
        n_limbs=n_limbs,
        scale_axis=axis,
        interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("n_moduli", "mode", "interpret")
)
def ozaki2_gemm_kernels(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Full kernel-path real GEMM emulation (f32 in / f32 out).

    This is the TPU execution plan; numerically it provides f32-grade output
    (the double-single 'dd' output path of crt_garner serves f64-grade).
    """
    if n_moduli is None:
        n_moduli = default_n_moduli(jnp.float32, mode)
    ctx, e_mu, e_nu, n_limbs, (ax, bx) = _prep(a, b, n_moduli, mode, False)
    ares = _cast(ax, e_mu, 0, ctx, n_limbs, interpret)
    bres = _cast(bx, e_nu, 1, ctx, n_limbs, interpret)
    e_planes = [
        int8_mod_gemm(ares[l], bres[l], p=int(ctx.moduli[l]), interpret=interpret)
        for l in range(ctx.n)
    ]
    e_res = jnp.stack(e_planes, axis=0)
    return crt_garner(e_res, e_mu, e_nu, ctx, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("n_moduli", "mode", "interpret")
)
def ozaki2_cgemm_kernels(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Full kernel-path complex GEMM emulation (complex64 in/out) using the
    fused-Karatsuba modular kernel (one launch per modulus)."""
    if n_moduli is None:
        n_moduli = default_n_moduli(jnp.complex64, mode)
    ctx, e_mu, e_nu, n_limbs, (ar, ai, br, bi) = _prep(a, b, n_moduli, mode, True)
    arr = _cast(ar, e_mu, 0, ctx, n_limbs, interpret)
    ari = _cast(ai, e_mu, 0, ctx, n_limbs, interpret)
    brr = _cast(br, e_nu, 1, ctx, n_limbs, interpret)
    bri = _cast(bi, e_nu, 1, ctx, n_limbs, interpret)
    er_planes, ei_planes = [], []
    for l in range(ctx.n):
        cr, ci = karatsuba_mod_gemm(
            arr[l], ari[l], brr[l], bri[l], p=int(ctx.moduli[l]), interpret=interpret
        )
        er_planes.append(cr)
        ei_planes.append(ci)
    er = jnp.stack(er_planes, axis=0)
    ei = jnp.stack(ei_planes, axis=0)
    cr = crt_garner(er, e_mu, e_nu, ctx, interpret=interpret)
    ci = crt_garner(ei, e_mu, e_nu, ctx, interpret=interpret)
    return jax.lax.complex(cr, ci)
