"""The Pallas residue backends behind `GemmPolicy(execution=...)`.

:class:`KernelBackend` (execution="kernel") and
:class:`PerModulusKernelBackend` (execution="per_modulus_kernel") map the
executor's residue primitives onto the Pallas kernels; the pipeline
structure itself lives once in `repro.core.executor`, so the kernel path
supports all three Fig. 1 strategies (the block embeddings compose from
`residue_matmul`).  Select them through the policy layer:

    with repro.use_policy(GemmPolicy(backend="ozaki2_c64",
                                     execution="kernel")):
        y = repro.linalg.matmul(a, b)      # 4 pallas_calls at any N

The legacy `ozaki2_gemm_kernels` / `ozaki2_cgemm_kernels` entry points are
retained as deprecation shims over that policy route (bitwise-identical,
still jitted per shape × policy).

Launch economics (paper SIII-C, Fig. 1 small-shape regime): every residue
primitive is ONE `pallas_call` regardless of the modulus count N — the
batched GEMM kernels fold the N planes into their leading grid dimension,
`residue_cast` writes all N planes per operand in one pass (stacking the
real/imag parts of a complex operand), and `crt_garner` reconstructs the
whole (stacked) output in one pass.  A fast-mode GEMM is therefore
cast + cast + product-per-K-chunk + reconstruct = 4 launches at any N; the
pre-batching behaviour (one launch per modulus) is retained in
:class:`PerModulusKernelBackend` as the parity reference.

`interpret` is resolved (`interpret_default()`) *before* the jitted inner
functions, so passing `interpret=None` vs. an explicit bool can no longer
cause an avoidable retrace.

On CPU the kernels execute in interpret mode; tests compare the pipeline
against `repro.core` (which itself is validated against exact integers).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..core.executor import chunked_residue_matmul
from ..core.moduli import CRTContext
from .common import interpret_default, split_scale_exponent
from .crt_garner import crt_garner
from .int8_mod_gemm import int8_mod_gemm, int8_mod_gemm_batched
from .karatsuba_fused import karatsuba_mod_gemm, karatsuba_mod_gemm_batched
from .residue_cast import residue_cast


@dataclasses.dataclass(frozen=True)
class _KernelBackendBase:
    """Shared cast/reconstruct for the Pallas residue backends.

    CRT reconstruction is always the Garner mixed-radix kernel (the only
    TPU-native path — no f64 on the VPU); f64-grade output uses its
    double-single (~2^-48) mode.  All kernels pad-and-slice internally, so
    non-block-divisible shapes (odd m/n/k, `n_block` tails) are accepted.
    """

    interpret: bool | None = None

    # both kernel paths fuse the Karatsuba D/E/F triple into one kernel;
    # only the batched subclass folds the N planes into one grid (consulted
    # by the perfmodel-driven 'auto' plan selections)
    fused_karatsuba = True
    modulus_batched = False
    uses_pallas = True

    def analyze(self, plan, shape=None):
        """Static-analysis suite certifying this kernel backend (see
        repro.analysis.passes_for_backend): overflow/exactness, collective
        safety, scan index width, and — given ``shape=(m, k, n)`` — a
        launch-count certificate pinned to the perfmodel prediction for
        this backend's capabilities (modulus_batched / fused_karatsuba /
        megakernel)."""
        from ..analysis import passes_for_backend

        return passes_for_backend(self, plan, shape)

    def cast(self, x, e, axis, ctx: CRTContext, n_limbs: int):
        s1, s2 = split_scale_exponent(e)
        return residue_cast(
            x.astype(jnp.float32),
            s1,
            s2,
            moduli=ctx.moduli,
            n_limbs=n_limbs,
            scale_axis=axis,
            interpret=self.interpret,
        )

    @staticmethod
    def _check_method(method):
        if method != "garner":
            raise ValueError(
                f"the kernel backend only reconstructs via 'garner' (no f64 "
                f"on the TPU VPU); plan requested method={method!r}"
            )

    def reconstruct(self, e_res, e_mu, e_nu, ctx: CRTContext, method, out_dtype):
        self._check_method(method)
        out_dd = jnp.dtype(out_dtype) == jnp.float64
        out = crt_garner(
            e_res, e_mu, e_nu, ctx, out_dd=out_dd, interpret=self.interpret
        )
        if out_dd:
            return out[0].astype(jnp.float64) + out[1].astype(jnp.float64)
        return out


@dataclasses.dataclass(frozen=True)
class KernelBackend(_KernelBackendBase):
    """Residue backend running the modulus-batched Pallas kernels: every
    primitive is a single `pallas_call` (interpret mode on CPU).

    Beyond the base cast/reconstruct it stacks the real/imag parts of
    complex operands (`cast_stack` / `reconstruct_stack`, used by the
    executor's complex pipeline) so one complex operand or output also
    costs one launch.
    """

    modulus_batched = True

    def cast_stack(self, xs, e, axis, ctx: CRTContext, n_limbs: int):
        """(S, m, k) stack sharing one scale vector -> (S, N, m, k), 1 launch."""
        s1, s2 = split_scale_exponent(e)
        return residue_cast(
            xs.astype(jnp.float32),
            s1,
            s2,
            moduli=ctx.moduli,
            n_limbs=n_limbs,
            scale_axis=axis,
            interpret=self.interpret,
        )

    def residue_matmul(self, ares, bres, ctx: CRTContext):
        """One batched launch per K-chunk; the inter-chunk sym_mod runs in
        the kernel epilogue via the carry input (no host per-modulus loop)."""
        return chunked_residue_matmul(
            lambda a, b, carry: int8_mod_gemm_batched(
                a, b, moduli=ctx.moduli, carry=carry, interpret=self.interpret
            ),
            ares,
            bres,
            ctx,
            carry_epilogue=True,
        )

    def karatsuba(self, arr, ari, brr, bri, ctx: CRTContext):
        """Fused-Karatsuba modular kernel: ONE launch per K-chunk for all N
        planes, CR/CI chunk-carries folded into the kernel epilogue.  The
        chunk loop is the executor's shared `chunked_residue_matmul` (the
        operand pytrees are the (R, I) plane pairs), so there is a single
        K_CHUNK_LIMIT knob."""
        return chunked_residue_matmul(
            lambda a, b, carry: karatsuba_mod_gemm_batched(
                a[0], a[1], b[0], b[1],
                moduli=ctx.moduli, carry=carry, interpret=self.interpret,
            ),
            (arr, ari),
            (brr, bri),
            ctx,
            carry_epilogue=True,
        )

    def reconstruct_stack(
        self, e_res, e_mu, e_nu, ctx: CRTContext, method, out_dtype
    ):
        """(S, N, m, n) residue stacks sharing scale exponents -> (S, m, n)
        outputs in one launch (the executor stacks CR/CI)."""
        self._check_method(method)
        out_dd = jnp.dtype(out_dtype) == jnp.float64
        out = crt_garner(
            e_res, e_mu, e_nu, ctx, out_dd=out_dd, interpret=self.interpret
        )
        if out_dd:
            return out[:, 0].astype(jnp.float64) + out[:, 1].astype(jnp.float64)
        return out


@dataclasses.dataclass(frozen=True)
class FusedBackend(KernelBackend):
    """Residue backend running the one-launch megakernels
    (execution="fused"): the residue casts run as the GEMM kernel's
    prologue, the N int8 plane products accumulate per K grid block
    (auto-pipelined, i.e. double-buffered, with in-kernel chunk reduction
    replacing the host carry loop), and the Garner reconstruction runs as
    the epilogue — a fast-mode emulated GEMM is ONE `pallas_call` per
    output-column block (accu mode too: the scaling pass is pallas-free).

    The executor dispatches on ``megakernel = True`` (`_fused_pipeline_*`);
    everything the megakernel cannot serve — left-prepared operands, the
    sharded worker's r>1 dynamic plane chunks — falls back to the composed
    single-launch primitives inherited from :class:`KernelBackend`, so the
    fused execution is never less capable, only fewer launches.  Bitwise
    identical to ``execution="kernel"`` by construction: the prologue and
    epilogue run literally the shared `common.residue_tiles_f32` /
    `crt_garner.garner_tile` op sequences.
    """

    megakernel = True

    @staticmethod
    def _chunk_limit() -> int:
        # resolved at call time from the executor module so the tests'
        # monkeypatch of executor.K_CHUNK_LIMIT governs the fused path too
        from ..core import executor as _executor

        return _executor.K_CHUNK_LIMIT

    def fused_gemm(
        self, a, b, e_mu, e_nu, ctx, n_limbs, out_dtype, b_res=None
    ):
        from .int8_mod_gemm import fused_mod_gemm

        out_dd = jnp.dtype(out_dtype) == jnp.float64
        out = fused_mod_gemm(
            a, b, e_mu, e_nu, ctx, n_limbs=n_limbs, out_dd=out_dd,
            b_res=b_res, chunk_limit=self._chunk_limit(),
            interpret=self.interpret,
        )
        if out_dd:
            return out[0].astype(jnp.float64) + out[1].astype(jnp.float64)
        return out

    def fused_karatsuba_gemm(
        self, ar, ai, br, bi, e_mu, e_nu, ctx, n_limbs, out_dtype, b_res=None
    ):
        from .karatsuba_fused import fused_karatsuba_mod_gemm

        out_dd = jnp.dtype(out_dtype) == jnp.float64
        cr, ci = fused_karatsuba_mod_gemm(
            ar, ai, br, bi, e_mu, e_nu, ctx, n_limbs=n_limbs, out_dd=out_dd,
            b_res=b_res, chunk_limit=self._chunk_limit(),
            interpret=self.interpret,
        )
        if out_dd:
            return (
                cr[0].astype(jnp.float64) + cr[1].astype(jnp.float64),
                ci[0].astype(jnp.float64) + ci[1].astype(jnp.float64),
            )
        return cr, ci


@dataclasses.dataclass(frozen=True)
class PerModulusKernelBackend(_KernelBackendBase):
    """Pre-batching reference: one `pallas_call` per modulus (3N-launch
    complex products via per-modulus fused Karatsuba), kept as the bitwise
    parity target for :class:`KernelBackend` and as the launch-count
    contrast in the perfmodel tests.
    """

    def _mod_gemm_stack(self, ares, bres, ctx: CRTContext):
        """Un-chunked per-modulus kernel launches (k <= K_CHUNK_LIMIT)."""
        planes = [
            int8_mod_gemm(
                ares[l], bres[l], p=int(ctx.moduli[l]), interpret=self.interpret
            )
            for l in range(ctx.n)
        ]
        return jnp.stack(planes, axis=0)

    def residue_matmul(self, ares, bres, ctx: CRTContext):
        return chunked_residue_matmul(
            lambda a, b: self._mod_gemm_stack(a, b, ctx), ares, bres, ctx
        )

    def karatsuba(self, arr, ari, brr, bri, ctx: CRTContext):
        er_planes, ei_planes = [], []
        for l in range(ctx.n):
            cr, ci = karatsuba_mod_gemm(
                arr[l],
                ari[l],
                brr[l],
                bri[l],
                p=int(ctx.moduli[l]),
                interpret=self.interpret,
            )
            er_planes.append(cr)
            ei_planes.append(ci)
        return jnp.stack(er_planes, axis=0), jnp.stack(ei_planes, axis=0)


def _kernels_shim_policy(name, backend, **kw):
    from ..core.gemm import _deprecated
    from ..core.policy import GemmPolicy

    policy = GemmPolicy(backend=backend, execution="kernel", **kw)
    # stacklevel 4: user -> ozaki2_*_kernels -> here -> _deprecated
    _deprecated(name, policy, stacklevel=4)
    return policy


def ozaki2_gemm_kernels(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    n_block: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Full kernel-path real GEMM emulation (f32 in / f32 out).

    .. deprecated:: use ``repro.linalg.matmul`` with a
       ``GemmPolicy(backend="ozaki2_f32", execution="kernel")`` instead.

    Numerically this provides f32-grade output (the double-single 'dd'
    output path of crt_garner serves f64-grade).  Defaults (`n_moduli`,
    `interpret`) resolve inside the policy *before* the jitted inner
    function, so `interpret=None` never causes an extra retrace.
    """
    policy = _kernels_shim_policy(
        "ozaki2_gemm_kernels",
        "ozaki2_f32",
        n_moduli=None if n_moduli is None else int(n_moduli),
        mode=mode,
        n_block=n_block,
        interpret=bool(interpret_default() if interpret is None else interpret),
        out_dtype="float32",
    )
    from .. import linalg

    return linalg.matmul_jit(a, b, policy=policy)


def ozaki2_cgemm_kernels(
    a: jnp.ndarray,
    b: jnp.ndarray,
    n_moduli: int | None = None,
    mode: str = "fast",
    formulation: str = "karatsuba",
    n_block: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Full kernel-path complex GEMM emulation (complex64 in/out).

    .. deprecated:: use ``repro.linalg.matmul`` with a
       ``GemmPolicy(backend="ozaki2_c64", execution="kernel",
       formulation=...)`` instead.

    formulation 'karatsuba' uses the fused-Karatsuba modular kernel (one
    batched launch for all moduli); 'block_a'/'block_b'/'auto' use the block
    embeddings composed over the batched `int8_mod_gemm_batched`.
    """
    policy = _kernels_shim_policy(
        "ozaki2_cgemm_kernels",
        "ozaki2_c64",
        n_moduli=None if n_moduli is None else int(n_moduli),
        mode=mode,
        formulation=formulation,
        n_block=n_block,
        interpret=bool(interpret_default() if interpret is None else interpret),
        out_dtype="complex64",
    )
    from .. import linalg

    return linalg.matmul_jit(a, b, policy=policy)
