"""Pure-jnp oracles for every Pallas kernel (the allclose targets in tests).

These reuse the validated `repro.core` reference pipeline so the kernels are
checked against the same code that reproduces the paper's accuracy tables.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import crt
from ..core.intmul import int8_matmul
from ..core.moduli import CRTContext, make_crt_context
from ..core.residues import (
    residues_from_quantized,
    sym_mod_int32,
)


def residue_cast_ref(
    a: jnp.ndarray,
    scale1: jnp.ndarray,
    scale2: jnp.ndarray,
    *,
    moduli: tuple[int, ...],
    n_limbs: int,
    scale_axis: int = 0,
) -> jnp.ndarray:
    ctx = make_crt_context(len(moduli), moduli)
    scale = (scale1 * scale2).astype(jnp.float64)
    shape = [1, 1]
    shape[scale_axis] = -1
    aq = jnp.trunc(a.astype(jnp.float64) * scale.reshape(shape))
    return residues_from_quantized(aq, ctx, n_limbs)


def int8_mod_gemm_ref(a: jnp.ndarray, b: jnp.ndarray, *, p: int) -> jnp.ndarray:
    d = int8_matmul(a, b)
    return sym_mod_int32(d, p).astype(jnp.int8)


def karatsuba_mod_gemm_ref(ar, ai, br, bi, *, p: int):
    asum = sym_mod_int32(ar.astype(jnp.int32) + ai.astype(jnp.int32), p).astype(jnp.int8)
    bsum = sym_mod_int32(br.astype(jnp.int32) + bi.astype(jnp.int32), p).astype(jnp.int8)
    d = sym_mod_int32(int8_matmul(ar, br), p)
    e = sym_mod_int32(int8_matmul(ai, bi), p)
    f = sym_mod_int32(int8_matmul(asum, bsum), p)
    cr = sym_mod_int32(d - e, p).astype(jnp.int8)
    ci = sym_mod_int32(f - d - e, p).astype(jnp.int8)
    return cr, ci


def crt_garner_ref(
    e_res: jnp.ndarray, e_mu: jnp.ndarray, e_nu: jnp.ndarray, ctx: CRTContext
) -> jnp.ndarray:
    """f64 reference of the Garner reconstruction + inverse scaling."""
    hi, lo = crt.reconstruct_garner(e_res, ctx)
    return crt.inverse_scale(hi, lo, e_mu, e_nu, jnp.float64)


def flash_attention_ref(q, k, v, causal: bool = True) -> jnp.ndarray:
    """Naive softmax attention oracle. q: (B,S,H,D); k,v: (B,S,KV,D)."""
    import math

    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, d).astype(jnp.float32) / math.sqrt(d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
