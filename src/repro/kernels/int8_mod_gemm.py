"""Pallas kernel: tiled int8 MXU GEMM with symmetric-mod epilogue.

Alg. 1 steps V-iii/iv for one modulus: D = A_l B_l (int8 x int8 -> int32 on
the MXU, exact for k <= 2^17) and E = sym_mod(D, p) (int8), fused so the
int32 product tile never round-trips to HBM — the paper's step-2 memory term
(14N + c) mn / b is dominated by exactly those int32 stores+loads; the fused
epilogue removes 8 of the 14 bytes/elt (see EXPERIMENTS.md SPerf).

Grid: (m/bm, n/bn, k/bk), k innermost ('arbitrary'), int32 accumulator in a
VMEM scratch tile.  MXU alignment: bm/bn multiples of 128, bk multiple of 32
(int8 lane packing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import interpret_default, sym_mod_int32_via_f32


def _kernel(a_ref, b_ref, out_ref, acc_ref, *, p, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...],
        b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        out_ref[...] = sym_mod_int32_via_f32(acc_ref[...], p).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("p", "bm", "bn", "bk", "interpret")
)
def int8_mod_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    p: int,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """E = sym_mod(A @ B, p): (m,k) x (k,n) int8 -> (m,n) int8 residues."""
    if interpret is None:
        interpret = interpret_default()
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"({m},{n},{k}) not divisible by ({bm},{bn},{bk})")
    k_steps = k // bk
    return pl.pallas_call(
        functools.partial(_kernel, p=p, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a, b)
