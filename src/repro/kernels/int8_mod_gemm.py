"""Pallas kernel: modulus-batched tiled int8 MXU GEMM with sym-mod epilogue.

Alg. 1 steps V-iii/iv for ALL moduli in one `pallas_call`: the N residue
planes are folded into the leading grid dimension, so a full residue GEMM
D_l = A_l B_l, E_l = sym_mod(D_l, p_l) costs one kernel launch regardless of
N — the paper's SIII-C step-2 launch term drops from N to 1 (on small
shapes the launch-bound regime of Fig. 1).  The int8 x int8 -> int32 MXU
product is exact for k <= 2^17 and the fused epilogue keeps the int32 tile
in VMEM (never round-trips to HBM — 8 of the 14 bytes/elt of the paper's
(14N + c) mn / b step-2 memory term; see EXPERIMENTS.md SPerf).

Grid: (N, m/bm, n/bn, k/bk) — modulus plane outermost, k innermost
('arbitrary'), one int32 accumulator tile in VMEM scratch.  The per-plane
modulus is delivered via scalar prefetch (`PrefetchScalarGridSpec`): the
moduli are a small int32 array argument, not a static Python `p`, and the
epilogue derives (p, (p-1)/2, 2^16 mod p) from it in exact f32 arithmetic
(`common.dyn_mod_params`).  MXU alignment: bm/bn multiples of 128, bk a
multiple of 32 (int8 lane packing); non-block-divisible shapes are
zero-padded to the block grid and the output sliced back (zeros are
residue-exact, see `common.pad_dims`).

The optional `carry` input is an (N, m, n) int8 residue stack folded into
the epilogue reduction: `out = sym_mod(acc + carry, p)`.  K-chunked
products (k > 2^17) thread the previous chunk's residues through it, so the
inter-chunk combine happens inside the kernel instead of a host-side
per-modulus loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (
    block_and_padded,
    dyn_mod_params,
    interpret_default,
    pad_dims,
    sym_mod_int32_dyn,
)


def _kernel(moduli_ref, a_ref, b_ref, *rest, k_steps, has_carry):
    if has_carry:
        carry_ref, out_ref, acc_ref = rest
    else:
        out_ref, acc_ref = rest
    # program_id must be read outside pl.when bodies (the interpret-mode
    # evaluator does not substitute it inside cond sub-jaxprs)
    l = pl.program_id(0)

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0],
        b_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _epilogue():
        pf, half, m16 = dyn_mod_params(moduli_ref, l)
        acc = acc_ref[...]
        if has_carry:
            acc = acc + carry_ref[0].astype(jnp.int32)
        out_ref[0] = sym_mod_int32_dyn(acc, pf, half, m16).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def _batched_call(a, b, carry, mod_arr, *, bm, bn, bk, interpret):
    n_mod, m, k = a.shape
    n = b.shape[-1]
    k_steps = k // bk
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda l, i, j, kk, mods: (l, i, kk)),
        pl.BlockSpec((1, bk, bn), lambda l, i, j, kk, mods: (l, kk, j)),
    ]
    operands = [a, b]
    if carry is not None:
        in_specs.append(
            pl.BlockSpec((1, bm, bn), lambda l, i, j, kk, mods: (l, i, j))
        )
        operands.append(carry)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_mod, m // bm, n // bn, k_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda l, i, j, kk, mods: (l, i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, has_carry=carry is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_mod, m, n), jnp.int8),
        interpret=interpret,
    )(mod_arr, *operands)


def int8_mod_gemm_batched(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    moduli: tuple[int, ...] | jnp.ndarray,
    carry: jnp.ndarray | None = None,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """E_l = sym_mod(A_l @ B_l [+ carry_l], p_l) for all l in ONE launch.

    a: (N, m, k) int8, b: (N, k, n) int8, carry: optional (N, m, n) int8;
    returns (N, m, n) int8 residues.  Any m/n/k is accepted (pad-and-slice).

    `moduli` may be a static tuple or a *traced* (N,) int32 array: the
    kernel reads the modulus from the scalar-prefetched array either way
    (`dyn_mod_params`), so the compiled kernel is modulus-agnostic — the
    sharded execution passes each shard its dynamically-sliced plane chunk.
    """
    if interpret is None:
        interpret = interpret_default()
    n_mod, m, k = a.shape
    n_given = (
        moduli.shape[0] if isinstance(moduli, jnp.ndarray) else len(moduli)
    )
    if b.shape[0] != n_mod or b.shape[1] != k or n_given != n_mod:
        raise ValueError(f"shape mismatch: a {a.shape}, b {b.shape}, N={n_given}")
    n = b.shape[-1]
    bm, mp = block_and_padded(m, bm, align=128)
    bn, np_ = block_and_padded(n, bn, align=128)
    bk, kp = block_and_padded(k, bk, align=32)
    a = pad_dims(a, {1: mp, 2: kp})
    b = pad_dims(b, {1: kp, 2: np_})
    if carry is not None:
        carry = pad_dims(carry, {1: mp, 2: np_})
    out = _batched_call(
        a, b, carry, jnp.asarray(moduli, jnp.int32), bm=bm, bn=bn, bk=bk,
        interpret=bool(interpret),
    )
    return out[:, :m, :n]


def int8_mod_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    p: int,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """E = sym_mod(A @ B, p): (m,k) x (k,n) int8 -> (m,n) int8 residues.

    Per-modulus entry point, retained as a thin vmap-free wrapper over the
    batched kernel (an N=1 grid): launching it once per modulus is the
    reference the batched path is verified bitwise-identical against.
    """
    return int8_mod_gemm_batched(
        a[None], b[None], moduli=(int(p),), bm=bm, bn=bn, bk=bk,
        interpret=interpret,
    )[0]
