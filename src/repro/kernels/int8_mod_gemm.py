"""Pallas kernel: modulus-batched tiled int8 MXU GEMM with sym-mod epilogue.

Alg. 1 steps V-iii/iv for ALL moduli in one `pallas_call`: the N residue
planes are folded into the leading grid dimension, so a full residue GEMM
D_l = A_l B_l, E_l = sym_mod(D_l, p_l) costs one kernel launch regardless of
N — the paper's SIII-C step-2 launch term drops from N to 1 (on small
shapes the launch-bound regime of Fig. 1).  The int8 x int8 -> int32 MXU
product is exact for k <= 2^17 and the fused epilogue keeps the int32 tile
in VMEM (never round-trips to HBM — 8 of the 14 bytes/elt of the paper's
(14N + c) mn / b step-2 memory term; see EXPERIMENTS.md SPerf).

Grid: (N, m/bm, n/bn, k/bk) — modulus plane outermost, k innermost
('arbitrary'), one int32 accumulator tile in VMEM scratch.  The per-plane
modulus is delivered via scalar prefetch (`PrefetchScalarGridSpec`): the
moduli are a small int32 array argument, not a static Python `p`, and the
epilogue derives (p, (p-1)/2, 2^16 mod p) from it in exact f32 arithmetic
(`common.dyn_mod_params`).  MXU alignment: bm/bn multiples of 128, bk a
multiple of 32 (int8 lane packing); non-block-divisible shapes are
zero-padded to the block grid and the output sliced back (zeros are
residue-exact, see `common.pad_dims`).

The optional `carry` input is an (N, m, n) int8 residue stack folded into
the epilogue reduction: `out = sym_mod(acc + carry, p)`.  K-chunked
products (k > 2^17) thread the previous chunk's residues through it, so the
inter-chunk combine happens inside the kernel instead of a host-side
per-modulus loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (
    block_and_padded,
    dyn_mod_params,
    interpret_default,
    pad_dims,
    residue_tiles_f32,
    resolve_blocks,
    split_scale_exponent,
    static_mod_params,
    sym_mod_int32_dyn,
)
from .crt_garner import _prescale, garner_tile


def _kernel(moduli_ref, a_ref, b_ref, *rest, k_steps, has_carry):
    if has_carry:
        carry_ref, out_ref, acc_ref = rest
    else:
        out_ref, acc_ref = rest
    # program_id must be read outside pl.when bodies (the interpret-mode
    # evaluator does not substitute it inside cond sub-jaxprs)
    l = pl.program_id(0)

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0],
        b_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _epilogue():
        pf, half, m16 = dyn_mod_params(moduli_ref, l)
        acc = acc_ref[...]
        if has_carry:
            acc = acc + carry_ref[0].astype(jnp.int32)
        out_ref[0] = sym_mod_int32_dyn(acc, pf, half, m16).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def _batched_call(a, b, carry, mod_arr, *, bm, bn, bk, interpret):
    n_mod, m, k = a.shape
    n = b.shape[-1]
    k_steps = k // bk
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda l, i, j, kk, mods: (l, i, kk)),
        pl.BlockSpec((1, bk, bn), lambda l, i, j, kk, mods: (l, kk, j)),
    ]
    operands = [a, b]
    if carry is not None:
        in_specs.append(
            pl.BlockSpec((1, bm, bn), lambda l, i, j, kk, mods: (l, i, j))
        )
        operands.append(carry)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_mod, m // bm, n // bn, k_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda l, i, j, kk, mods: (l, i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, has_carry=carry is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_mod, m, n), jnp.int8),
        interpret=interpret,
    )(mod_arr, *operands)


def int8_mod_gemm_batched(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    moduli: tuple[int, ...] | jnp.ndarray,
    carry: jnp.ndarray | None = None,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """E_l = sym_mod(A_l @ B_l [+ carry_l], p_l) for all l in ONE launch.

    a: (N, m, k) int8, b: (N, k, n) int8, carry: optional (N, m, n) int8;
    returns (N, m, n) int8 residues.  Any m/n/k is accepted (pad-and-slice).
    Unset bm/bn/bk resolve via `common.resolve_blocks`: the active
    calibration's autotuned tile for this shape bucket, else (256, 256, 512).

    `moduli` may be a static tuple or a *traced* (N,) int32 array: the
    kernel reads the modulus from the scalar-prefetched array either way
    (`dyn_mod_params`), so the compiled kernel is modulus-agnostic — the
    sharded execution passes each shard its dynamically-sliced plane chunk.
    """
    if interpret is None:
        interpret = interpret_default()
    n_mod, m, k = a.shape
    n_given = (
        moduli.shape[0] if isinstance(moduli, jnp.ndarray) else len(moduli)
    )
    if b.shape[0] != n_mod or b.shape[1] != k or n_given != n_mod:
        raise ValueError(f"shape mismatch: a {a.shape}, b {b.shape}, N={n_given}")
    n = b.shape[-1]
    bm, bn, bk = resolve_blocks("kernel", "real", m, n, k, bm, bn, bk)
    bm, mp = block_and_padded(m, bm, align=128)
    bn, np_ = block_and_padded(n, bn, align=128)
    bk, kp = block_and_padded(k, bk, align=32)
    a = pad_dims(a, {1: mp, 2: kp})
    b = pad_dims(b, {1: kp, 2: np_})
    if carry is not None:
        carry = pad_dims(carry, {1: mp, 2: np_})
    out = _batched_call(
        a, b, carry, jnp.asarray(moduli, jnp.int32), bm=bm, bn=bn, bk=bk,
        interpret=bool(interpret),
    )
    return out[:, :m, :n]


# --------------------------------------------------------------- megakernel


def _fused_kernel(
    *refs, ctx, n_limbs, k_steps, chunk_steps, out_dd, prepared
):
    """cast A tile + cast/load B tile + N int8 products + Garner, one grid.

    The prologue runs `common.residue_tiles_f32` (the residue-cast kernel's
    exact op sequence) on the raw f32 tiles; the epilogue runs
    `crt_garner.garner_tile` (the Garner kernel's exact op sequence) on the
    canonical residues — so the fused output is bitwise identical to the
    4-launch cast/cast/product/reconstruct composition by construction.
    The K grid dimension is innermost: Pallas auto-pipelines the next K
    block's fetches against the current products (the double-buffering the
    host-side chunk loop could never give across launches).
    """
    if prepared:
        (a_ref, sa1_ref, sa2_ref, b_ref,
         r1_ref, r2_ref, c1_ref, c2_ref, out_ref, acc_ref) = refs
    else:
        (a_ref, sa1_ref, sa2_ref, b_ref, sb1_ref, sb2_ref,
         r1_ref, r2_ref, c1_ref, c2_ref, out_ref, acc_ref) = refs
    n = ctx.n
    # program_id must be read outside pl.when bodies (the interpret-mode
    # evaluator does not substitute it inside cond sub-jaxprs)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- prologue: in-kernel residue cast of the operand tiles ---
    a_tiles = residue_tiles_f32(
        a_ref[...], sa1_ref[...], sa2_ref[...],
        moduli=ctx.moduli, n_limbs=n_limbs, scale_axis=0,
    )
    if prepared:
        b_tiles = [b_ref[l] for l in range(n)]  # pre-cast int8 planes
    else:
        b_tiles = [
            t.astype(jnp.int8)
            for t in residue_tiles_f32(
                b_ref[...], sb1_ref[...], sb2_ref[...],
                moduli=ctx.moduli, n_limbs=n_limbs, scale_axis=1,
            )
        ]

    # --- N int8 MXU products into the plane-stacked int32 accumulator ---
    for l in range(n):
        acc_ref[l] += jax.lax.dot_general(
            a_tiles[l].astype(jnp.int8),
            b_tiles[l],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    # --- in-kernel K-chunk reduction (replaces the host carry loop) ---
    if k_steps > chunk_steps:

        @pl.when(((kk + 1) % chunk_steps == 0) & (kk < k_steps - 1))
        def _chunk_reduce():
            for l, p in enumerate(ctx.moduli):
                pf, half, m16 = static_mod_params(p)
                acc_ref[l] = sym_mod_int32_dyn(
                    acc_ref[l], pf, half, m16
                ).astype(jnp.int32)

    # --- epilogue: Garner reconstruction of the output tile ---
    @pl.when(kk == k_steps - 1)
    def _epilogue():
        planes = []
        for l, p in enumerate(ctx.moduli):
            pf, half, m16 = static_mod_params(p)
            planes.append(sym_mod_int32_dyn(acc_ref[l], pf, half, m16))
        rr = (r1_ref[...] * r2_ref[...])[:, None]
        cc = (c1_ref[...] * c2_ref[...])[None, :]
        if out_dd:
            hi, lo = garner_tile(planes, rr, cc, ctx=ctx, out_dd=True)
            out_ref[0] = hi
            out_ref[1] = lo
        else:
            out_ref[...] = garner_tile(planes, rr, cc, ctx=ctx, out_dd=False)


# not jitted: CRTContext holds numpy tables and is unhashable; the public
# pipeline wrappers jit the whole plan execution anyway.
def _fused_call(
    a, sa1, sa2, b, sb, r1, r2, c1, c2, *, ctx, n_limbs, k_steps,
    chunk_steps, out_dd, bm, bn, bk, interpret
):
    prepared = sb is None
    m = a.shape[0]
    n = (b.shape[-1])
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
        pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
    ]
    operands = [a, sa1, sa2]
    if prepared:
        in_specs.append(
            pl.BlockSpec((ctx.n, bk, bn), lambda i, j, kk: (0, kk, j))
        )
        operands.append(b)
    else:
        in_specs.append(pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)))
        operands.append(b)
        in_specs += [
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ]
        operands += list(sb)
    in_specs += [
        pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
        pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
        pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
    ]
    operands += [r1, r2, c1, c2]
    out_shape = (
        jax.ShapeDtypeStruct((2, m, n), jnp.float32)
        if out_dd
        else jax.ShapeDtypeStruct((m, n), jnp.float32)
    )
    out_spec = (
        pl.BlockSpec((2, bm, bn), lambda i, j, kk: (0, i, j))
        if out_dd
        else pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    )
    return pl.pallas_call(
        functools.partial(
            _fused_kernel, ctx=ctx, n_limbs=n_limbs, k_steps=k_steps,
            chunk_steps=chunk_steps, out_dd=out_dd, prepared=prepared,
        ),
        grid=(m // bm, n // bn, k_steps),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((ctx.n, bm, bn), jnp.int32)],
        interpret=interpret,
    )(*operands)


def fused_mod_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    e_mu: jnp.ndarray,
    e_nu: jnp.ndarray,
    ctx,
    *,
    n_limbs: int,
    out_dd: bool = False,
    b_res: jnp.ndarray | None = None,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    chunk_limit: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """The one-launch real megakernel: C = A @ B emulated end to end.

    a: (m, k) f32 (pre-scaled mantissas, as produced by the scaling pass);
    b: (k, n) f32, or None with `b_res` the pre-cast (N, k, n) int8 planes
    (prepared serving); e_mu/e_nu: the integer scale exponents.  Returns the
    reconstructed (m, n) f32 output — or the (2, m, n) double-single pair
    with `out_dd` — in ONE `pallas_call`: the residue casts run as the
    kernel prologue, the N int8 products accumulate per K block (with
    in-kernel chunk reduction replacing the host carry loop past
    `chunk_limit` columns), and the Garner reconstruction runs as the
    epilogue on the final K block.  Bitwise identical to the composed
    cast/product/reconstruct kernel path.
    """
    if interpret is None:
        interpret = interpret_default()
    if chunk_limit is None:
        chunk_limit = 1 << 17
    a = a.astype(jnp.float32)
    if b is not None:
        b = b.astype(jnp.float32)
    m, k = a.shape
    n = b_res.shape[-1] if b_res is not None else b.shape[-1]
    bm, bn, bk = resolve_blocks("fused", "real", m, n, k, bm, bn, bk)
    bm, mp = block_and_padded(m, bm, align=128)
    bn, np_ = block_and_padded(n, bn, align=128)
    bk, kp = block_and_padded(k, bk, align=32)
    a = pad_dims(a, {0: mp, 1: kp})
    e_mu = pad_dims(e_mu, {0: mp})
    e_nu = pad_dims(e_nu, {0: np_})
    sa1, sa2 = split_scale_exponent(e_mu)
    s = _prescale(ctx)
    s_r = s // 2
    r1, r2 = split_scale_exponent(-e_mu, bias=s_r)
    c1, c2 = split_scale_exponent(-e_nu, bias=s - s_r)
    if b_res is not None:
        bp = pad_dims(b_res, {1: kp, 2: np_})
        sb = None
    else:
        bp = pad_dims(b, {0: kp, 1: np_})
        sb = split_scale_exponent(e_nu)
    k_steps = kp // bk
    chunk_steps = max(1, chunk_limit // bk)
    out = _fused_call(
        a, sa1, sa2, bp, sb, r1, r2, c1, c2, ctx=ctx, n_limbs=n_limbs,
        k_steps=k_steps, chunk_steps=chunk_steps, out_dd=out_dd,
        bm=bm, bn=bn, bk=bk, interpret=bool(interpret),
    )
    return out[..., :m, :n]


def int8_mod_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    p: int,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """E = sym_mod(A @ B, p): (m,k) x (k,n) int8 -> (m,n) int8 residues.

    Per-modulus entry point, retained as a thin vmap-free wrapper over the
    batched kernel (an N=1 grid): launching it once per modulus is the
    reference the batched path is verified bitwise-identical against.
    """
    return int8_mod_gemm_batched(
        a[None], b[None], moduli=(int(p),), bm=bm, bn=bn, bk=bk,
        interpret=interpret,
    )[0]
