"""Pallas kernel: Garner mixed-radix CRT reconstruction + inverse scaling.

TPU-native replacement for the paper's fp64 eq.(5) reconstruction (DESIGN.md
S2): the digit recursion is exact small-integer arithmetic (done in f32 where
every value is < 2^17, hence error-free), and the digit->value conversion
accumulates in a double-single (two-f32, ~48-bit) pair against prescaled
mixed-radix weights W_t * 2^-S, followed by the exact power-of-two inverse
scaling  C = C' / (mu_i nu_j).

Output: 'f32' (CGEMM/SGEMM-grade) or a (2, m, n) double-single pair
('dd', ZGEMM-grade on TPU; ~2^-48 relative — see DESIGN.md S6).

Grid: (S, m/bm, n/bn) with S an optional leading *stack* dimension: a
(S, N, m, n) residue stack reconstructs S outputs sharing the same scale
exponents in one launch — the complex pipeline stacks the CR/CI residue
planes so reconstruction costs one `pallas_call` for the whole complex
output.  (N, m, n) inputs are treated as S=1 and squeezed on return.  The
full N-deep residue stack for a tile sits in VMEM (N * bm * bn int8;
13 * 256 * 256 = 0.8 MiB).  Non-block-divisible m/n are zero-padded to the
block grid and sliced back (zero residues reconstruct to zero).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.moduli import CRTContext
from .common import (
    block_and_padded,
    interpret_default,
    pad_dims,
    split_scale_exponent,
    sym_mod_f32,
)
from ..core import expansion as ex


def _prescale(ctx: CRTContext) -> int:
    """Weight prescale S keeping W_t * 2^-S * 127 within f32 range."""
    return max(0, math.ceil(ctx.log2_P) - 100)


def _weight_table(ctx: CRTContext) -> np.ndarray:
    """(N, 2) f32 double-single of W_t * 2^-S (exact power-of-two scaling)."""
    s = _prescale(ctx)
    tab = np.zeros((ctx.n, 2), dtype=np.float32)
    W = 1
    for t in range(ctx.n):
        hi = np.float32(np.ldexp(float(W), -s))
        lo = np.float32(np.ldexp(W - int(math.ldexp(float(np.float64(hi)), s)), -s))
        tab[t, 0], tab[t, 1] = hi, lo
        W *= ctx.moduli[t]
    return tab


def garner_tile(planes, rr, cc, *, ctx, out_dd):
    """Garner digits -> double-single value -> inverse scaling, one tile.

    The single implementation of the reconstruction math shared by the
    standalone Garner kernel and the fused megakernel epilogues: both run
    literally these ops, so their outputs are bitwise identical.

    `planes` is a list of N (bm, bn) f32 canonical residue tiles of C';
    `rr`/`cc` the broadcast-ready inverse-scale factor products (already
    shaped (bm, 1) / (1, bn)).  Returns the (bm, bn) f32 tile, or the
    (hi, lo) double-single pair when `out_dd`.
    """
    moduli = ctx.moduli
    n = ctx.n
    # --- Garner digits (exact f32 integer arithmetic, all values < 2^17) ---
    digits = []
    for t in range(n):
        pf, half = float(moduli[t]), float((moduli[t] - 1) // 2)
        r = planes[t]
        for s in range(t):
            r = sym_mod_f32((r - digits[s]) * float(ctx.garner_inv[s, t]), pf, half)
        digits.append(r)
    # --- digits -> value, double-single accumulation, MS digit first ---
    wt = _weight_table(ctx)
    hi = jnp.zeros_like(digits[0])
    lo = jnp.zeros_like(digits[0])
    for t in range(n - 1, -1, -1):
        ph, pe = ex.two_prod(jnp.float32(wt[t, 0]), digits[t])
        pe = pe + jnp.float32(wt[t, 1]) * digits[t]
        hi, lo = ex.dd_add(hi, lo, ph, pe)
    # --- exact inverse power-of-two scaling (folds in 2^S) ---
    if out_dd:
        return hi * rr * cc, lo * rr * cc
    return ((hi + lo) * rr) * cc


def _kernel(e_ref, r1_ref, r2_ref, c1_ref, c2_ref, out_ref, *, ctx, out_dd):
    planes = [e_ref[0, t, :, :].astype(jnp.float32) for t in range(ctx.n)]
    rr = (r1_ref[...] * r2_ref[...])[:, None]
    cc = (c1_ref[...] * c2_ref[...])[None, :]
    if out_dd:
        hi, lo = garner_tile(planes, rr, cc, ctx=ctx, out_dd=True)
        out_ref[0, 0, :, :] = hi
        out_ref[0, 1, :, :] = lo
    else:
        out_ref[0] = garner_tile(planes, rr, cc, ctx=ctx, out_dd=False)


# not jitted: CRTContext holds numpy tables and is unhashable; the public
# pipeline wrappers jit the whole plan execution anyway.
def _stacked_call(e_res, r1, r2, c1, c2, *, ctx, out_dd, bm, bn, interpret):
    s, n_mod, m, n = e_res.shape
    out_shape = (
        jax.ShapeDtypeStruct((s, 2, m, n), jnp.float32)
        if out_dd
        else jax.ShapeDtypeStruct((s, m, n), jnp.float32)
    )
    out_spec = (
        pl.BlockSpec((1, 2, bm, bn), lambda si, i, j: (si, 0, i, j))
        if out_dd
        else pl.BlockSpec((1, bm, bn), lambda si, i, j: (si, i, j))
    )
    return pl.pallas_call(
        functools.partial(_kernel, ctx=ctx, out_dd=out_dd),
        grid=(s, m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((1, ctx.n, bm, bn), lambda si, i, j: (si, 0, i, j)),
            pl.BlockSpec((bm,), lambda si, i, j: (i,)),
            pl.BlockSpec((bm,), lambda si, i, j: (i,)),
            pl.BlockSpec((bn,), lambda si, i, j: (j,)),
            pl.BlockSpec((bn,), lambda si, i, j: (j,)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(e_res, r1, r2, c1, c2)


def crt_garner(
    e_res: jnp.ndarray,
    e_mu: jnp.ndarray,
    e_nu: jnp.ndarray,
    ctx: CRTContext,
    *,
    out_dd: bool = False,
    bm: int = 256,
    bn: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """e_res: (N, m, n) or stacked (S, N, m, n) int8 residues of C'; e_mu /
    e_nu: integer scale exponents (shared across the stack).  Returns
    C = C'/(mu nu) as (m,n) f32 or (2,m,n) double-single — with a leading
    (S, ...) dim for stacked input — in one `pallas_call` either way.
    """
    if interpret is None:
        interpret = interpret_default()
    stacked = e_res.ndim == 4
    if not stacked:
        e_res = e_res[None]
    _, n_mod, m, n = e_res.shape
    assert n_mod == ctx.n
    bm, mp = block_and_padded(m, bm, align=8)
    bn, np_ = block_and_padded(n, bn, align=128)
    e_res = pad_dims(e_res, {2: mp, 3: np_})
    e_mu = pad_dims(e_mu, {0: mp})
    e_nu = pad_dims(e_nu, {0: np_})
    s = _prescale(ctx)
    s_r = s // 2
    r1, r2 = split_scale_exponent(-e_mu, bias=s_r)
    c1, c2 = split_scale_exponent(-e_nu, bias=s - s_r)
    out = _stacked_call(
        e_res, r1, r2, c1, c2, ctx=ctx, out_dd=out_dd, bm=bm, bn=bn,
        interpret=bool(interpret),
    )
    out = out[..., :m, :n]
    return out if stacked else out[0]
