"""Pallas kernel: fused Karatsuba modular complex GEMM for one modulus.

Beyond-paper optimization (EXPERIMENTS.md SPerf): the paper runs the three
Karatsuba products D = AR.BR, E = AI.BI, F = (AR+AI)(BR+BI) as separate
int8 GEMM kernel launches with int32 intermediates in HBM.  On TPU we fuse
all three into one kernel that

  * reads only the 4 residue planes (AR, AI, BR, BI) — the (AR+AI) mod p and
    (BR+BI) mod p operands are formed in VMEM per tile (exact f32 mod of
    values <= 254), never materialized in HBM;
  * keeps the three int32 accumulators in VMEM scratch;
  * emits the final CR/CI int8 residues directly:
        CR = D - E,  CI = F - D - E   (mod p).

HBM traffic per modulus drops from 6 int8 plane reads + 3 int32 (m,n)
writes + 3 int32 reads + 2 int8 writes to 4 int8 reads + 2 int8 writes.

Grid: (m/bm, n/bn, k/bk), k innermost, 3 int32 VMEM accumulators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import interpret_default, sym_mod_f32, sym_mod_int32_via_f32


def _dot_i8(a, b):
    return jax.lax.dot_general(
        a, b, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _kernel(ar_ref, ai_ref, br_ref, bi_ref, cr_ref, ci_ref,
            d_acc, e_acc, f_acc, *, p, k_steps):
    pf, half = float(p), float((p - 1) // 2)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        d_acc[...] = jnp.zeros_like(d_acc)
        e_acc[...] = jnp.zeros_like(e_acc)
        f_acc[...] = jnp.zeros_like(f_acc)

    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    # (AR + AI) mod p formed in VMEM: |sum| <= 254 -> exact f32 mod -> int8
    asum = sym_mod_f32(ar.astype(jnp.float32) + ai.astype(jnp.float32), pf, half
                       ).astype(jnp.int8)
    bsum = sym_mod_f32(br.astype(jnp.float32) + bi.astype(jnp.float32), pf, half
                       ).astype(jnp.int8)
    d_acc[...] += _dot_i8(ar, br)
    e_acc[...] += _dot_i8(ai, bi)
    f_acc[...] += _dot_i8(asum, bsum)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        dr = sym_mod_int32_via_f32(d_acc[...], p)
        de = sym_mod_int32_via_f32(e_acc[...], p)
        df = sym_mod_int32_via_f32(f_acc[...], p)
        cr_ref[...] = sym_mod_f32(dr - de, pf, half).astype(jnp.int8)
        ci_ref[...] = sym_mod_f32(df - dr - de, pf, half).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("p", "bm", "bn", "bk", "interpret")
)
def karatsuba_mod_gemm(
    ar: jnp.ndarray,
    ai: jnp.ndarray,
    br: jnp.ndarray,
    bi: jnp.ndarray,
    *,
    p: int,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
):
    """Residues of (CR', CI') = (AR'+iAI')(BR'+iBI') mod p. All int8 (m,k)/(k,n)."""
    if interpret is None:
        interpret = interpret_default()
    m, k = ar.shape
    _, n = br.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"({m},{n},{k}) not divisible by ({bm},{bn},{bk})")
    k_steps = k // bk
    return pl.pallas_call(
        functools.partial(_kernel, p=p, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=(
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m, n), jnp.int8),
        ),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, bn), jnp.int32),
        ],
        interpret=interpret,
    )(ar, ai, br, bi)
