"""Pallas kernel: modulus-batched fused-Karatsuba modular complex GEMM.

Beyond-paper optimization (EXPERIMENTS.md SPerf), two fusions deep:

 1. *Karatsuba fusion* — the paper runs the three Karatsuba products
    D = AR.BR, E = AI.BI, F = (AR+AI)(BR+BI) as separate int8 GEMM kernel
    launches with int32 intermediates in HBM.  We fuse all three into one
    kernel that reads only the 4 residue planes (the (AR+AI) mod p and
    (BR+BI) mod p operands are formed in VMEM per tile — exact f32 mod of
    values <= 254 — never materialized in HBM), keeps the three int32
    accumulators in VMEM scratch, and emits the final CR/CI int8 residues
    directly: CR = D - E, CI = F - D - E (mod p).  HBM traffic per modulus
    drops from 6 int8 plane reads + 3 int32 (m,n) writes + 3 int32 reads +
    2 int8 writes to 4 int8 reads + 2 int8 writes.
 2. *Modulus batching* — all N planes run in one `pallas_call` with the
    modulus plane as the leading grid dimension, so a full fast-mode
    complex residue product is ONE launch (vs 3N for the paper's schedule).

Grid: (N, m/bm, n/bn, k/bk) — modulus outermost, k innermost, 3 int32 VMEM
accumulators.  The per-plane modulus arrives via scalar prefetch as an
int32 array (`PrefetchScalarGridSpec`); (p, (p-1)/2, 2^16 mod p) are
derived in-kernel in exact f32 (`common.dyn_mod_params`).  Alignment: bm/bn
multiples of 128, bk a multiple of 32; non-block-divisible shapes are
zero-padded to the block grid and sliced back (zero padding is
residue-exact).  The optional `carry` pair (CR, CI residues of previous
K-chunks) is folded into the epilogue mod, keeping chunked-K combines
inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (
    block_and_padded,
    dyn_mod_params,
    interpret_default,
    pad_dims,
    residue_tiles_f32,
    resolve_blocks,
    split_scale_exponent,
    static_mod_params,
    sym_mod_f32,
    sym_mod_int32_dyn,
)
from .crt_garner import _prescale, garner_tile


def _dot_i8(a, b):
    return jax.lax.dot_general(
        a, b, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _kernel(moduli_ref, ar_ref, ai_ref, br_ref, bi_ref, *rest,
            k_steps, has_carry):
    if has_carry:
        cr_in_ref, ci_in_ref, cr_ref, ci_ref, d_acc, e_acc, f_acc = rest
    else:
        cr_ref, ci_ref, d_acc, e_acc, f_acc = rest
    # program_id read once at kernel top level (outside pl.when bodies —
    # the interpret-mode evaluator does not substitute it inside conds)
    pf, half, m16 = dyn_mod_params(moduli_ref, pl.program_id(0))

    @pl.when(pl.program_id(3) == 0)
    def _init():
        d_acc[...] = jnp.zeros_like(d_acc)
        e_acc[...] = jnp.zeros_like(e_acc)
        f_acc[...] = jnp.zeros_like(f_acc)

    ar, ai = ar_ref[0], ai_ref[0]
    br, bi = br_ref[0], bi_ref[0]
    # (AR + AI) mod p formed in VMEM: |sum| <= 254 -> exact f32 mod -> int8
    asum = sym_mod_f32(ar.astype(jnp.float32) + ai.astype(jnp.float32), pf, half
                       ).astype(jnp.int8)
    bsum = sym_mod_f32(br.astype(jnp.float32) + bi.astype(jnp.float32), pf, half
                       ).astype(jnp.int8)
    d_acc[...] += _dot_i8(ar, br)
    e_acc[...] += _dot_i8(ai, bi)
    f_acc[...] += _dot_i8(asum, bsum)

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _epilogue():
        dr = sym_mod_int32_dyn(d_acc[...], pf, half, m16)
        de = sym_mod_int32_dyn(e_acc[...], pf, half, m16)
        df = sym_mod_int32_dyn(f_acc[...], pf, half, m16)
        cr = dr - de
        ci = df - dr - de
        if has_carry:
            cr = cr + cr_in_ref[0].astype(jnp.float32)
            ci = ci + ci_in_ref[0].astype(jnp.float32)
        cr_ref[0] = sym_mod_f32(cr, pf, half).astype(jnp.int8)
        ci_ref[0] = sym_mod_f32(ci, pf, half).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def _batched_call(ar, ai, br, bi, carry, mod_arr, *, bm, bn, bk, interpret):
    n_mod, m, k = ar.shape
    n = br.shape[-1]
    k_steps = k // bk
    a_spec = pl.BlockSpec((1, bm, bk), lambda l, i, j, kk, mods: (l, i, kk))
    b_spec = pl.BlockSpec((1, bk, bn), lambda l, i, j, kk, mods: (l, kk, j))
    o_spec = pl.BlockSpec((1, bm, bn), lambda l, i, j, kk, mods: (l, i, j))
    in_specs = [a_spec, a_spec, b_spec, b_spec]
    operands = [ar, ai, br, bi]
    if carry is not None:
        in_specs += [o_spec, o_spec]
        operands += list(carry)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_mod, m // bm, n // bn, k_steps),
        in_specs=in_specs,
        out_specs=(o_spec, o_spec),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, bn), jnp.int32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, has_carry=carry is not None),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((n_mod, m, n), jnp.int8),
            jax.ShapeDtypeStruct((n_mod, m, n), jnp.int8),
        ),
        interpret=interpret,
    )(mod_arr, *operands)


def karatsuba_mod_gemm_batched(
    ar: jnp.ndarray,
    ai: jnp.ndarray,
    br: jnp.ndarray,
    bi: jnp.ndarray,
    *,
    moduli: tuple[int, ...] | jnp.ndarray,
    carry: tuple[jnp.ndarray, jnp.ndarray] | None = None,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
):
    """Residues of (CR', CI') = (AR'+iAI')(BR'+iBI') mod p_l, all planes in
    ONE launch.  Inputs (N, m, k) / (N, k, n) int8 stacks; `carry` is an
    optional (CR, CI) pair of (N, m, n) int8 residues folded into the
    epilogue (K-chunk combine).  Any m/n/k is accepted (pad-and-slice).
    `moduli` may be a static tuple or a traced (N,) int32 array (the sharded
    execution's per-shard plane chunk) — the kernel is modulus-agnostic."""
    if interpret is None:
        interpret = interpret_default()
    n_mod, m, k = ar.shape
    n_given = (
        moduli.shape[0] if isinstance(moduli, jnp.ndarray) else len(moduli)
    )
    if (
        ai.shape != ar.shape
        or br.shape != bi.shape
        or br.shape[:2] != (n_mod, k)
        or n_given != n_mod
    ):
        raise ValueError(
            f"shape mismatch: ar {ar.shape}, ai {ai.shape}, br {br.shape}, "
            f"bi {bi.shape}, N={n_given}"
        )
    n = br.shape[-1]
    bm, bn, bk = resolve_blocks("kernel", "complex", m, n, k, bm, bn, bk)
    bm, mp = block_and_padded(m, bm, align=128)
    bn, np_ = block_and_padded(n, bn, align=128)
    bk, kp = block_and_padded(k, bk, align=32)
    ar = pad_dims(ar, {1: mp, 2: kp})
    ai = pad_dims(ai, {1: mp, 2: kp})
    br = pad_dims(br, {1: kp, 2: np_})
    bi = pad_dims(bi, {1: kp, 2: np_})
    if carry is not None:
        carry = tuple(pad_dims(c, {1: mp, 2: np_}) for c in carry)
    cr, ci = _batched_call(
        ar, ai, br, bi, carry, jnp.asarray(moduli, jnp.int32),
        bm=bm, bn=bn, bk=bk, interpret=bool(interpret),
    )
    return cr[:, :m, :n], ci[:, :m, :n]


# --------------------------------------------------------------- megakernel


def _fused_kernel(
    *refs, ctx, n_limbs, k_steps, chunk_steps, out_dd, prepared
):
    """cast AR/AI (+BR/BI) + 3 Karatsuba products x N planes + two Garner
    reconstructions, one grid — the complex twin of
    `int8_mod_gemm._fused_kernel` (same shared prologue/epilogue helpers,
    same bitwise-parity-by-construction argument)."""
    if prepared:
        (ar_ref, ai_ref, sa1_ref, sa2_ref, brr_ref, bri_ref,
         r1_ref, r2_ref, c1_ref, c2_ref,
         cr_ref, ci_ref, d_acc, e_acc, f_acc) = refs
    else:
        (ar_ref, ai_ref, sa1_ref, sa2_ref, br_ref, bi_ref,
         sb1_ref, sb2_ref, r1_ref, r2_ref, c1_ref, c2_ref,
         cr_ref, ci_ref, d_acc, e_acc, f_acc) = refs
    n = ctx.n
    # program_id read once at kernel top level (outside pl.when bodies)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        d_acc[...] = jnp.zeros_like(d_acc)
        e_acc[...] = jnp.zeros_like(e_acc)
        f_acc[...] = jnp.zeros_like(f_acc)

    # --- prologue: in-kernel residue casts (f32 canonical residue tiles) ---
    sa1, sa2 = sa1_ref[...], sa2_ref[...]
    art = residue_tiles_f32(
        ar_ref[...], sa1, sa2, moduli=ctx.moduli, n_limbs=n_limbs, scale_axis=0
    )
    ait = residue_tiles_f32(
        ai_ref[...], sa1, sa2, moduli=ctx.moduli, n_limbs=n_limbs, scale_axis=0
    )
    if prepared:
        brt = [brr_ref[l].astype(jnp.float32) for l in range(n)]
        bit = [bri_ref[l].astype(jnp.float32) for l in range(n)]
    else:
        sb1, sb2 = sb1_ref[...], sb2_ref[...]
        brt = residue_tiles_f32(
            br_ref[...], sb1, sb2, moduli=ctx.moduli, n_limbs=n_limbs,
            scale_axis=1,
        )
        bit = residue_tiles_f32(
            bi_ref[...], sb1, sb2, moduli=ctx.moduli, n_limbs=n_limbs,
            scale_axis=1,
        )

    # --- the D/E/F Karatsuba triple per plane (sum operands in VMEM) ---
    for l, p in enumerate(ctx.moduli):
        pf, half = float(p), float((p - 1) // 2)
        asum = sym_mod_f32(art[l] + ait[l], pf, half).astype(jnp.int8)
        bsum = sym_mod_f32(brt[l] + bit[l], pf, half).astype(jnp.int8)
        d_acc[l] += _dot_i8(art[l].astype(jnp.int8), brt[l].astype(jnp.int8))
        e_acc[l] += _dot_i8(ait[l].astype(jnp.int8), bit[l].astype(jnp.int8))
        f_acc[l] += _dot_i8(asum, bsum)

    # --- in-kernel K-chunk reduction (replaces the host carry loop) ---
    if k_steps > chunk_steps:

        @pl.when(((kk + 1) % chunk_steps == 0) & (kk < k_steps - 1))
        def _chunk_reduce():
            for l, p in enumerate(ctx.moduli):
                pf, half, m16 = static_mod_params(p)
                for acc in (d_acc, e_acc, f_acc):
                    acc[l] = sym_mod_int32_dyn(
                        acc[l], pf, half, m16
                    ).astype(jnp.int32)

    # --- epilogue: CR/CI combine + two Garner reconstructions ---
    @pl.when(kk == k_steps - 1)
    def _epilogue():
        cr_planes, ci_planes = [], []
        for l, p in enumerate(ctx.moduli):
            pf, half, m16 = static_mod_params(p)
            dr = sym_mod_int32_dyn(d_acc[l], pf, half, m16)
            de = sym_mod_int32_dyn(e_acc[l], pf, half, m16)
            df = sym_mod_int32_dyn(f_acc[l], pf, half, m16)
            cr_planes.append(sym_mod_f32(dr - de, pf, half))
            ci_planes.append(sym_mod_f32(df - dr - de, pf, half))
        rr = (r1_ref[...] * r2_ref[...])[:, None]
        cc = (c1_ref[...] * c2_ref[...])[None, :]
        if out_dd:
            hi, lo = garner_tile(cr_planes, rr, cc, ctx=ctx, out_dd=True)
            cr_ref[0], cr_ref[1] = hi, lo
            hi, lo = garner_tile(ci_planes, rr, cc, ctx=ctx, out_dd=True)
            ci_ref[0], ci_ref[1] = hi, lo
        else:
            cr_ref[...] = garner_tile(cr_planes, rr, cc, ctx=ctx, out_dd=False)
            ci_ref[...] = garner_tile(ci_planes, rr, cc, ctx=ctx, out_dd=False)


# not jitted: CRTContext holds numpy tables and is unhashable; the public
# pipeline wrappers jit the whole plan execution anyway.
def _fused_call(
    ar, ai, sa1, sa2, b_pair, sb, r1, r2, c1, c2, *, ctx, n_limbs, k_steps,
    chunk_steps, out_dd, bm, bn, bk, interpret
):
    prepared = sb is None
    m = ar.shape[0]
    n = b_pair[0].shape[-1]
    row_spec = pl.BlockSpec((bm,), lambda i, j, kk: (i,))
    col_spec = pl.BlockSpec((bn,), lambda i, j, kk: (j,))
    a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    in_specs = [a_spec, a_spec, row_spec, row_spec]
    operands = [ar, ai, sa1, sa2]
    if prepared:
        bp_spec = pl.BlockSpec((ctx.n, bk, bn), lambda i, j, kk: (0, kk, j))
        in_specs += [bp_spec, bp_spec]
        operands += list(b_pair)
    else:
        b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
        in_specs += [b_spec, b_spec, col_spec, col_spec]
        operands += list(b_pair) + list(sb)
    in_specs += [row_spec, row_spec, col_spec, col_spec]
    operands += [r1, r2, c1, c2]
    one_shape = (
        jax.ShapeDtypeStruct((2, m, n), jnp.float32)
        if out_dd
        else jax.ShapeDtypeStruct((m, n), jnp.float32)
    )
    one_spec = (
        pl.BlockSpec((2, bm, bn), lambda i, j, kk: (0, i, j))
        if out_dd
        else pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    )
    return pl.pallas_call(
        functools.partial(
            _fused_kernel, ctx=ctx, n_limbs=n_limbs, k_steps=k_steps,
            chunk_steps=chunk_steps, out_dd=out_dd, prepared=prepared,
        ),
        grid=(m // bm, n // bn, k_steps),
        in_specs=in_specs,
        out_specs=(one_spec, one_spec),
        out_shape=(one_shape, one_shape),
        scratch_shapes=[
            pltpu.VMEM((ctx.n, bm, bn), jnp.int32),
            pltpu.VMEM((ctx.n, bm, bn), jnp.int32),
            pltpu.VMEM((ctx.n, bm, bn), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)


def fused_karatsuba_mod_gemm(
    ar: jnp.ndarray,
    ai: jnp.ndarray,
    br: jnp.ndarray,
    bi: jnp.ndarray,
    e_mu: jnp.ndarray,
    e_nu: jnp.ndarray,
    ctx,
    *,
    n_limbs: int,
    out_dd: bool = False,
    b_res: tuple[jnp.ndarray, jnp.ndarray] | None = None,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    chunk_limit: int | None = None,
    interpret: bool | None = None,
):
    """The one-launch complex megakernel: C = (AR+iAI)(BR+iBI) emulated.

    ar/ai: (m, k) f32 pre-scaled mantissas; br/bi: (k, n) f32, or None with
    `b_res` the pre-cast ((N, k, n), (N, k, n)) int8 plane pair (prepared
    serving).  Returns the reconstructed (cr, ci) pair, each (m, n) f32 —
    or (2, m, n) double-single with `out_dd` — in ONE `pallas_call`:
    residue casts in the prologue, the fused Karatsuba D/E/F triple per K
    block (in-kernel chunk reduction past `chunk_limit` columns), CR/CI
    combine + both Garner reconstructions in the epilogue.  Bitwise
    identical to the composed cast/karatsuba/reconstruct kernel path.
    """
    if interpret is None:
        interpret = interpret_default()
    if chunk_limit is None:
        chunk_limit = 1 << 17
    ar = ar.astype(jnp.float32)
    ai = ai.astype(jnp.float32)
    m, k = ar.shape
    n = b_res[0].shape[-1] if b_res is not None else br.shape[-1]
    bm, bn, bk = resolve_blocks("fused", "complex", m, n, k, bm, bn, bk)
    bm, mp = block_and_padded(m, bm, align=128)
    bn, np_ = block_and_padded(n, bn, align=128)
    bk, kp = block_and_padded(k, bk, align=32)
    ar = pad_dims(ar, {0: mp, 1: kp})
    ai = pad_dims(ai, {0: mp, 1: kp})
    e_mu = pad_dims(e_mu, {0: mp})
    e_nu = pad_dims(e_nu, {0: np_})
    sa1, sa2 = split_scale_exponent(e_mu)
    s = _prescale(ctx)
    s_r = s // 2
    r1, r2 = split_scale_exponent(-e_mu, bias=s_r)
    c1, c2 = split_scale_exponent(-e_nu, bias=s - s_r)
    if b_res is not None:
        b_pair = tuple(pad_dims(x, {1: kp, 2: np_}) for x in b_res)
        sb = None
    else:
        b_pair = tuple(
            pad_dims(x.astype(jnp.float32), {0: kp, 1: np_}) for x in (br, bi)
        )
        sb = split_scale_exponent(e_nu)
    k_steps = kp // bk
    chunk_steps = max(1, chunk_limit // bk)
    cr, ci = _fused_call(
        ar, ai, sa1, sa2, b_pair, sb, r1, r2, c1, c2, ctx=ctx,
        n_limbs=n_limbs, k_steps=k_steps, chunk_steps=chunk_steps,
        out_dd=out_dd, bm=bm, bn=bn, bk=bk, interpret=bool(interpret),
    )
    return cr[..., :m, :n], ci[..., :m, :n]


def karatsuba_mod_gemm(
    ar: jnp.ndarray,
    ai: jnp.ndarray,
    br: jnp.ndarray,
    bi: jnp.ndarray,
    *,
    p: int,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
):
    """Residues of (CR', CI') = (AR'+iAI')(BR'+iBI') mod p. All int8 (m,k)/(k,n).

    Per-modulus entry point, retained as a thin vmap-free wrapper over the
    batched kernel (an N=1 grid) for the reference/parity tests."""
    cr, ci = karatsuba_mod_gemm_batched(
        ar[None], ai[None], br[None], bi[None], moduli=(int(p),),
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
    return cr[0], ci[0]
