"""Pallas kernel: modulus-batched fused-Karatsuba modular complex GEMM.

Beyond-paper optimization (EXPERIMENTS.md SPerf), two fusions deep:

 1. *Karatsuba fusion* — the paper runs the three Karatsuba products
    D = AR.BR, E = AI.BI, F = (AR+AI)(BR+BI) as separate int8 GEMM kernel
    launches with int32 intermediates in HBM.  We fuse all three into one
    kernel that reads only the 4 residue planes (the (AR+AI) mod p and
    (BR+BI) mod p operands are formed in VMEM per tile — exact f32 mod of
    values <= 254 — never materialized in HBM), keeps the three int32
    accumulators in VMEM scratch, and emits the final CR/CI int8 residues
    directly: CR = D - E, CI = F - D - E (mod p).  HBM traffic per modulus
    drops from 6 int8 plane reads + 3 int32 (m,n) writes + 3 int32 reads +
    2 int8 writes to 4 int8 reads + 2 int8 writes.
 2. *Modulus batching* — all N planes run in one `pallas_call` with the
    modulus plane as the leading grid dimension, so a full fast-mode
    complex residue product is ONE launch (vs 3N for the paper's schedule).

Grid: (N, m/bm, n/bn, k/bk) — modulus outermost, k innermost, 3 int32 VMEM
accumulators.  The per-plane modulus arrives via scalar prefetch as an
int32 array (`PrefetchScalarGridSpec`); (p, (p-1)/2, 2^16 mod p) are
derived in-kernel in exact f32 (`common.dyn_mod_params`).  Alignment: bm/bn
multiples of 128, bk a multiple of 32; non-block-divisible shapes are
zero-padded to the block grid and sliced back (zero padding is
residue-exact).  The optional `carry` pair (CR, CI residues of previous
K-chunks) is folded into the epilogue mod, keeping chunked-K combines
inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (
    block_and_padded,
    dyn_mod_params,
    interpret_default,
    pad_dims,
    sym_mod_f32,
    sym_mod_int32_dyn,
)


def _dot_i8(a, b):
    return jax.lax.dot_general(
        a, b, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _kernel(moduli_ref, ar_ref, ai_ref, br_ref, bi_ref, *rest,
            k_steps, has_carry):
    if has_carry:
        cr_in_ref, ci_in_ref, cr_ref, ci_ref, d_acc, e_acc, f_acc = rest
    else:
        cr_ref, ci_ref, d_acc, e_acc, f_acc = rest
    # program_id read once at kernel top level (outside pl.when bodies —
    # the interpret-mode evaluator does not substitute it inside conds)
    pf, half, m16 = dyn_mod_params(moduli_ref, pl.program_id(0))

    @pl.when(pl.program_id(3) == 0)
    def _init():
        d_acc[...] = jnp.zeros_like(d_acc)
        e_acc[...] = jnp.zeros_like(e_acc)
        f_acc[...] = jnp.zeros_like(f_acc)

    ar, ai = ar_ref[0], ai_ref[0]
    br, bi = br_ref[0], bi_ref[0]
    # (AR + AI) mod p formed in VMEM: |sum| <= 254 -> exact f32 mod -> int8
    asum = sym_mod_f32(ar.astype(jnp.float32) + ai.astype(jnp.float32), pf, half
                       ).astype(jnp.int8)
    bsum = sym_mod_f32(br.astype(jnp.float32) + bi.astype(jnp.float32), pf, half
                       ).astype(jnp.int8)
    d_acc[...] += _dot_i8(ar, br)
    e_acc[...] += _dot_i8(ai, bi)
    f_acc[...] += _dot_i8(asum, bsum)

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _epilogue():
        dr = sym_mod_int32_dyn(d_acc[...], pf, half, m16)
        de = sym_mod_int32_dyn(e_acc[...], pf, half, m16)
        df = sym_mod_int32_dyn(f_acc[...], pf, half, m16)
        cr = dr - de
        ci = df - dr - de
        if has_carry:
            cr = cr + cr_in_ref[0].astype(jnp.float32)
            ci = ci + ci_in_ref[0].astype(jnp.float32)
        cr_ref[0] = sym_mod_f32(cr, pf, half).astype(jnp.int8)
        ci_ref[0] = sym_mod_f32(ci, pf, half).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def _batched_call(ar, ai, br, bi, carry, mod_arr, *, bm, bn, bk, interpret):
    n_mod, m, k = ar.shape
    n = br.shape[-1]
    k_steps = k // bk
    a_spec = pl.BlockSpec((1, bm, bk), lambda l, i, j, kk, mods: (l, i, kk))
    b_spec = pl.BlockSpec((1, bk, bn), lambda l, i, j, kk, mods: (l, kk, j))
    o_spec = pl.BlockSpec((1, bm, bn), lambda l, i, j, kk, mods: (l, i, j))
    in_specs = [a_spec, a_spec, b_spec, b_spec]
    operands = [ar, ai, br, bi]
    if carry is not None:
        in_specs += [o_spec, o_spec]
        operands += list(carry)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_mod, m // bm, n // bn, k_steps),
        in_specs=in_specs,
        out_specs=(o_spec, o_spec),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, bn), jnp.int32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, has_carry=carry is not None),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((n_mod, m, n), jnp.int8),
            jax.ShapeDtypeStruct((n_mod, m, n), jnp.int8),
        ),
        interpret=interpret,
    )(mod_arr, *operands)


def karatsuba_mod_gemm_batched(
    ar: jnp.ndarray,
    ai: jnp.ndarray,
    br: jnp.ndarray,
    bi: jnp.ndarray,
    *,
    moduli: tuple[int, ...] | jnp.ndarray,
    carry: tuple[jnp.ndarray, jnp.ndarray] | None = None,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
):
    """Residues of (CR', CI') = (AR'+iAI')(BR'+iBI') mod p_l, all planes in
    ONE launch.  Inputs (N, m, k) / (N, k, n) int8 stacks; `carry` is an
    optional (CR, CI) pair of (N, m, n) int8 residues folded into the
    epilogue (K-chunk combine).  Any m/n/k is accepted (pad-and-slice).
    `moduli` may be a static tuple or a traced (N,) int32 array (the sharded
    execution's per-shard plane chunk) — the kernel is modulus-agnostic."""
    if interpret is None:
        interpret = interpret_default()
    n_mod, m, k = ar.shape
    n_given = (
        moduli.shape[0] if isinstance(moduli, jnp.ndarray) else len(moduli)
    )
    if (
        ai.shape != ar.shape
        or br.shape != bi.shape
        or br.shape[:2] != (n_mod, k)
        or n_given != n_mod
    ):
        raise ValueError(
            f"shape mismatch: ar {ar.shape}, ai {ai.shape}, br {br.shape}, "
            f"bi {bi.shape}, N={n_given}"
        )
    n = br.shape[-1]
    bm, mp = block_and_padded(m, bm, align=128)
    bn, np_ = block_and_padded(n, bn, align=128)
    bk, kp = block_and_padded(k, bk, align=32)
    ar = pad_dims(ar, {1: mp, 2: kp})
    ai = pad_dims(ai, {1: mp, 2: kp})
    br = pad_dims(br, {1: kp, 2: np_})
    bi = pad_dims(bi, {1: kp, 2: np_})
    if carry is not None:
        carry = tuple(pad_dims(c, {1: mp, 2: np_}) for c in carry)
    cr, ci = _batched_call(
        ar, ai, br, bi, carry, jnp.asarray(moduli, jnp.int32),
        bm=bm, bn=bn, bk=bk, interpret=bool(interpret),
    )
    return cr[:, :m, :n], ci[:, :m, :n]


def karatsuba_mod_gemm(
    ar: jnp.ndarray,
    ai: jnp.ndarray,
    br: jnp.ndarray,
    bi: jnp.ndarray,
    *,
    p: int,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    interpret: bool | None = None,
):
    """Residues of (CR', CI') = (AR'+iAI')(BR'+iBI') mod p. All int8 (m,k)/(k,n).

    Per-modulus entry point, retained as a thin vmap-free wrapper over the
    batched kernel (an N=1 grid) for the reference/parity tests."""
    cr, ci = karatsuba_mod_gemm_batched(
        ar[None], ai[None], br[None], bi[None], moduli=(int(p),),
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
    return cr[0], ci[0]
