r"""Pallas kernel: modulus-batched residue GEMM on the **FP8 (e4m3) engine**.

The int8 kernel (`int8_mod_gemm.py`) feeds the MXU int8 residue planes
directly; this kernel targets the FP8 variant of the Ozaki-II scheme
(arXiv:2603.10634): the multiply engine is e4m3, whose significand holds
only 4 bits, so a symmetric residue (|r| <= 127, 7 bits) is NOT exactly
representable.  The scheme therefore splits every residue into two balanced
base-16 digits

    r = 16 * hi + lo,   hi = round(r / 16),   lo = r - 16 * hi,

with |hi| <= 8 and |lo| <= 8 — every digit is a small integer with <= 4
significant bits, hence *exact* in e4m3.  One residue product becomes three
e4m3 GEMMs per plane (the cross terms share one GEMM of doubled K):

    r_a r_b = 256 (hi_a hi_b) + 16 (hi_a lo_b + lo_a hi_b) + (lo_a lo_b)
              \__ HH GEMM __/      \____ X GEMM (2k) ____/    \_ LL GEMM _/

each accumulated in f32.  Digit products are <= 64, so an f32 accumulator
stays an exact integer for k * 128 < 2^24 — the per-launch K bound
`FP8_K_CHUNK_LIMIT` (2^16), tighter than the int8 engine's 2^17 int32 bound.
The epilogue applies the **per-plane rescale**: the digit radix weights
reduced into each plane's residue ring, m4_l = sym_mod(16, p_l) and
m8_l = sym_mod(256, p_l) (derived in-kernel from the scalar-prefetched
modulus), combine the three digit sums as

    E_l = sym_mod(m8_l * sym_mod(HH) + m4_l * sym_mod(X) + sym_mod(LL), p_l)

— every step exact small-integer f32 arithmetic, so the output is the exact
canonical symmetric residue of A_l B_l and the FP8 path is **bitwise
identical** to the int8 engine (asserted in tests/test_fp8.py).  Emulation
accuracy is set by the CRT pipeline, not the engine; what the engine changes
is throughput (priced by `perfmodel` as 4 digit-MAC volumes at the e4m3
rate vs 1 at the int8 rate).

Grid and conventions mirror `int8_mod_gemm_batched`: (N, m/bm, n/bn, k/bk)
with the modulus plane outermost, moduli scalar-prefetched (static tuple or
traced int32 array — the kernel is modulus-agnostic), an optional int8
`carry` folded into the epilogue for K-chunked products, and pad-and-slice
for non-block-divisible shapes (zeros are residue-exact).

Hosts without native e4m3 matmul support run the same code in interpreted
Pallas (`interpret=None` resolves via `common.interpret_default`): the
digits are exactly representable, so XLA's upcast-and-multiply fallback is
bit-identical to a hardware fp8 MAC with f32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (
    block_and_padded,
    resolve_blocks,
    dyn_mod_params,
    interpret_default,
    pad_dims,
    sym_mod_f32,
    sym_mod_int32_dyn,
)

# Per-launch K bound of the f32 digit accumulators: worst-case per-element
# digit-product mass is 2 * 8 * 8 = 128 (the X GEMM sums two digit products
# per k), and f32 integer arithmetic is exact below 2^24, so k <= 2^24 / 128
# = 2^17; we keep a 2x margin.  `Fp8Backend` threads this through
# `chunked_residue_matmul` in place of the int8 engine's int32 bound.
FP8_K_CHUNK_LIMIT = 1 << 16

_F8 = jnp.float8_e4m3fn


def _digits(r32: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Balanced base-16 digit split of f32 integer residues (|r| <= 127):
    hi = round(r/16) in [-8, 8], lo = r - 16*hi in [-8, 8] — both exact in
    e4m3 (<= 4 significant bits)."""
    hi = jnp.round(r32 * (1.0 / 16.0))
    lo = r32 - 16.0 * hi
    return hi, lo


def _kernel(moduli_ref, a_ref, b_ref, *rest, k_steps, has_carry):
    if has_carry:
        carry_ref, out_ref, hh_ref, xx_ref, ll_ref = rest
    else:
        out_ref, hh_ref, xx_ref, ll_ref = rest
    # program_id must be read outside pl.when bodies (the interpret-mode
    # evaluator does not substitute it inside cond sub-jaxprs)
    l = pl.program_id(0)

    @pl.when(pl.program_id(3) == 0)
    def _init():
        hh_ref[...] = jnp.zeros_like(hh_ref)
        xx_ref[...] = jnp.zeros_like(xx_ref)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    ah, al = _digits(a_ref[0].astype(jnp.float32))
    bh, bl = _digits(b_ref[0].astype(jnp.float32))
    # round through e4m3: exact (digits have <= 4 significant bits), and the
    # dot then runs on genuine fp8 operands — the MXU fp8 path on hardware
    # that has one, XLA's upcast fallback (bit-identical) elsewhere
    dot = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    hh_ref[...] += dot(ah.astype(_F8), bh.astype(_F8))
    ll_ref[...] += dot(al.astype(_F8), bl.astype(_F8))
    # cross terms as ONE fp8 GEMM of doubled K: [ah | al] @ [bl ; bh]
    xx_ref[...] += dot(
        jnp.concatenate([ah, al], axis=1).astype(_F8),
        jnp.concatenate([bl, bh], axis=0).astype(_F8),
    )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _epilogue():
        pf, half, m16 = dyn_mod_params(moduli_ref, l)
        # per-plane rescale constants: the digit radix in the residue ring
        m4 = sym_mod_f32(jnp.float32(16.0), pf, half)
        m8 = sym_mod_f32(m4 * m4, pf, half)  # 256 mod p == (16 mod p)^2 mod p
        # f32 digit sums are exact integers < 2^24: int32 conversion is exact
        # and the 16-bit-split reduction gives the exact symmetric residue
        eh = sym_mod_int32_dyn(hh_ref[...].astype(jnp.int32), pf, half, m16)
        ex = sym_mod_int32_dyn(xx_ref[...].astype(jnp.int32), pf, half, m16)
        el = sym_mod_int32_dyn(ll_ref[...].astype(jnp.int32), pf, half, m16)
        acc = m8 * eh + m4 * ex + el  # |.| <= 2*127^2 + 127 < 2^16: exact
        if has_carry:
            acc = acc + carry_ref[0].astype(jnp.float32)
        out_ref[0] = sym_mod_f32(acc, pf, half).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def _batched_call(a, b, carry, mod_arr, *, bm, bn, bk, interpret):
    n_mod, m, k = a.shape
    n = b.shape[-1]
    k_steps = k // bk
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda l, i, j, kk, mods: (l, i, kk)),
        pl.BlockSpec((1, bk, bn), lambda l, i, j, kk, mods: (l, kk, j)),
    ]
    operands = [a, b]
    if carry is not None:
        in_specs.append(
            pl.BlockSpec((1, bm, bn), lambda l, i, j, kk, mods: (l, i, j))
        )
        operands.append(carry)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_mod, m // bm, n // bn, k_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda l, i, j, kk, mods: (l, i, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, has_carry=carry is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_mod, m, n), jnp.int8),
        interpret=interpret,
    )(mod_arr, *operands)


def _karatsuba_kernel(moduli_ref, ar_ref, ai_ref, br_ref, bi_ref, *rest,
                      k_steps, has_carry):
    if has_carry:
        (cr_in_ref, ci_in_ref, cr_ref, ci_ref, *accs) = rest
    else:
        (cr_ref, ci_ref, *accs) = rest
    d_hh, d_xx, d_ll, e_hh, e_xx, e_ll, f_hh, f_xx, f_ll = accs
    # program_id read once at kernel top level (outside pl.when bodies)
    pf, half, m16 = dyn_mod_params(moduli_ref, pl.program_id(0))

    @pl.when(pl.program_id(3) == 0)
    def _init():
        for acc in accs:
            acc[...] = jnp.zeros_like(acc)

    ar = ar_ref[0].astype(jnp.float32)
    ai = ai_ref[0].astype(jnp.float32)
    br = br_ref[0].astype(jnp.float32)
    bi = bi_ref[0].astype(jnp.float32)
    # (AR + AI) mod p formed in VMEM: |sum| <= 254 -> exact f32 mod
    asum = sym_mod_f32(ar + ai, pf, half)
    bsum = sym_mod_f32(br + bi, pf, half)
    dot = functools.partial(
        jax.lax.dot_general,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    def accumulate(a32, b32, hh, xx, ll):
        ah, al = _digits(a32)
        bh, bl = _digits(b32)
        hh[...] += dot(ah.astype(_F8), bh.astype(_F8))
        ll[...] += dot(al.astype(_F8), bl.astype(_F8))
        xx[...] += dot(
            jnp.concatenate([ah, al], axis=1).astype(_F8),
            jnp.concatenate([bl, bh], axis=0).astype(_F8),
        )

    accumulate(ar, br, d_hh, d_xx, d_ll)
    accumulate(ai, bi, e_hh, e_xx, e_ll)
    accumulate(asum, bsum, f_hh, f_xx, f_ll)

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _epilogue():
        m4 = sym_mod_f32(jnp.float32(16.0), pf, half)
        m8 = sym_mod_f32(m4 * m4, pf, half)

        def combine(hh, xx, ll):
            eh = sym_mod_int32_dyn(hh[...].astype(jnp.int32), pf, half, m16)
            exx = sym_mod_int32_dyn(xx[...].astype(jnp.int32), pf, half, m16)
            el = sym_mod_int32_dyn(ll[...].astype(jnp.int32), pf, half, m16)
            return sym_mod_f32(m8 * eh + m4 * exx + el, pf, half)

        dr = combine(d_hh, d_xx, d_ll)
        de = combine(e_hh, e_xx, e_ll)
        df = combine(f_hh, f_xx, f_ll)
        cr = dr - de
        ci = df - dr - de
        if has_carry:
            cr = cr + cr_in_ref[0].astype(jnp.float32)
            ci = ci + ci_in_ref[0].astype(jnp.float32)
        cr_ref[0] = sym_mod_f32(cr, pf, half).astype(jnp.int8)
        ci_ref[0] = sym_mod_f32(ci, pf, half).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def _karatsuba_call(ar, ai, br, bi, carry, mod_arr, *, bm, bn, bk, interpret):
    n_mod, m, k = ar.shape
    n = br.shape[-1]
    k_steps = k // bk
    a_spec = pl.BlockSpec((1, bm, bk), lambda l, i, j, kk, mods: (l, i, kk))
    b_spec = pl.BlockSpec((1, bk, bn), lambda l, i, j, kk, mods: (l, kk, j))
    o_spec = pl.BlockSpec((1, bm, bn), lambda l, i, j, kk, mods: (l, i, j))
    in_specs = [a_spec, a_spec, b_spec, b_spec]
    operands = [ar, ai, br, bi]
    if carry is not None:
        in_specs += [o_spec, o_spec]
        operands += list(carry)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_mod, m // bm, n // bn, k_steps),
        in_specs=in_specs,
        out_specs=(o_spec, o_spec),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)] * 9,
    )
    return pl.pallas_call(
        functools.partial(
            _karatsuba_kernel, k_steps=k_steps, has_carry=carry is not None
        ),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((n_mod, m, n), jnp.int8),
            jax.ShapeDtypeStruct((n_mod, m, n), jnp.int8),
        ),
        interpret=interpret,
    )(mod_arr, *operands)


def fp8_karatsuba_mod_gemm_batched(
    ar: jnp.ndarray,
    ai: jnp.ndarray,
    br: jnp.ndarray,
    bi: jnp.ndarray,
    *,
    moduli: tuple[int, ...] | jnp.ndarray,
    carry: tuple[jnp.ndarray, jnp.ndarray] | None = None,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
):
    """Residues of (CR', CI') = (AR'+iAI')(BR'+iBI') mod p_l on the e4m3
    engine, all planes and all three Karatsuba products in ONE launch.

    The fp8 twin of `karatsuba_mod_gemm_batched`: the D/E/F products each
    run as the exact balanced-digit HH/X/LL triple (9 f32 accumulators in
    VMEM), the (AR+AI)/(BR+BI) sum operands are formed per tile in VMEM, and
    the epilogue combines digits and the Karatsuba recombination in exact
    f32 — bitwise identical to composing three `fp8_mod_gemm_batched` calls
    with host combines, in 1 launch instead of 3.  Inputs (N, m, k) /
    (N, k, n) int8 stacks, optional (CR, CI) carry pair, k <=
    `FP8_K_CHUNK_LIMIT` per launch.
    """
    if interpret is None:
        interpret = interpret_default()
    n_mod, m, k = ar.shape
    if k > FP8_K_CHUNK_LIMIT:
        raise ValueError(
            f"fp8 digit accumulation is exact only for k <= "
            f"{FP8_K_CHUNK_LIMIT} per launch (got k={k}); chunk via "
            f"chunked_residue_matmul(chunk_limit=FP8_K_CHUNK_LIMIT)"
        )
    n_given = (
        moduli.shape[0] if isinstance(moduli, jnp.ndarray) else len(moduli)
    )
    if (
        ai.shape != ar.shape
        or br.shape != bi.shape
        or br.shape[:2] != (n_mod, k)
        or n_given != n_mod
    ):
        raise ValueError(
            f"shape mismatch: ar {ar.shape}, ai {ai.shape}, br {br.shape}, "
            f"bi {bi.shape}, N={n_given}"
        )
    n = br.shape[-1]
    bm, bn, bk = resolve_blocks("fp8", "complex", m, n, k, bm, bn, bk)
    bm, mp = block_and_padded(m, bm, align=128)
    bn, np_ = block_and_padded(n, bn, align=128)
    bk, kp = block_and_padded(k, bk, align=32)
    ar = pad_dims(ar, {1: mp, 2: kp})
    ai = pad_dims(ai, {1: mp, 2: kp})
    br = pad_dims(br, {1: kp, 2: np_})
    bi = pad_dims(bi, {1: kp, 2: np_})
    if carry is not None:
        carry = tuple(pad_dims(c, {1: mp, 2: np_}) for c in carry)
    cr, ci = _karatsuba_call(
        ar, ai, br, bi, carry, jnp.asarray(moduli, jnp.int32),
        bm=bm, bn=bn, bk=bk, interpret=bool(interpret),
    )
    return cr[:, :m, :n], ci[:, :m, :n]


def fp8_mod_gemm_batched(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    moduli: tuple[int, ...] | jnp.ndarray,
    carry: jnp.ndarray | None = None,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """E_l = sym_mod(A_l @ B_l [+ carry_l], p_l) on the e4m3 engine, all N
    planes in ONE launch.

    a: (N, m, k) int8, b: (N, k, n) int8, carry: optional (N, m, n) int8;
    returns (N, m, n) int8 residues, bitwise identical to
    `int8_mod_gemm_batched` (the digit split and per-plane rescale are
    exact — see module docstring).  Any m/n/k up to `FP8_K_CHUNK_LIMIT` per
    launch is accepted (pad-and-slice); `moduli` may be a static tuple or a
    traced (N,) int32 array.
    """
    if interpret is None:
        interpret = interpret_default()
    n_mod, m, k = a.shape
    if k > FP8_K_CHUNK_LIMIT:
        raise ValueError(
            f"fp8 digit accumulation is exact only for k <= "
            f"{FP8_K_CHUNK_LIMIT} per launch (got k={k}); chunk via "
            f"chunked_residue_matmul(chunk_limit=FP8_K_CHUNK_LIMIT)"
        )
    n_given = (
        moduli.shape[0] if isinstance(moduli, jnp.ndarray) else len(moduli)
    )
    if b.shape[0] != n_mod or b.shape[1] != k or n_given != n_mod:
        raise ValueError(f"shape mismatch: a {a.shape}, b {b.shape}, N={n_given}")
    n = b.shape[-1]
    bm, bn, bk = resolve_blocks("fp8", "real", m, n, k, bm, bn, bk)
    bm, mp = block_and_padded(m, bm, align=128)
    bn, np_ = block_and_padded(n, bn, align=128)
    bk, kp = block_and_padded(k, bk, align=32)
    a = pad_dims(a, {1: mp, 2: kp})
    b = pad_dims(b, {1: kp, 2: np_})
    if carry is not None:
        carry = pad_dims(carry, {1: mp, 2: np_})
    out = _batched_call(
        a, b, carry, jnp.asarray(moduli, jnp.int32), bm=bm, bn=bn, bk=bk,
        interpret=bool(interpret),
    )
    return out[:, :m, :n]
