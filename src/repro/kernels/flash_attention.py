"""Pallas kernel: causal GQA flash attention (serving/prefill hot path).

Not part of the paper's contribution (the models default to the pure-JAX
online-softmax attention in models/layers.py, which is what the dry-run
lowers); this kernel is the TPU-performance path for 32k-prefill serving:
HBM traffic O(S*D) instead of O(S^2) logits.

Grid: (B*H, S/bq, S/bk), kv innermost; running (m, l, acc) in VMEM scratch.
GQA: query head h reads kv head h // group_size via the BlockSpec index map.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import interpret_default


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale, causal, k_steps, bq, bk):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # (bq, bk)
    if causal:
        qi = pl.program_id(1)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        logits = jnp.where(q_pos >= k_pos, logits, -1e30)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _done():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """q: (B, S, H, D); k, v: (B, S, KV, D); H = KV * G.  Returns (B,S,H,D)."""
    if interpret is None:
        interpret = interpret_default()
    b, s, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    bq = min(bq, s)
    bk = min(bk, sk)
    if s % bq or sk % bk:
        raise ValueError(f"seq ({s},{sk}) not divisible by blocks ({bq},{bk})")
    scale = 1.0 / math.sqrt(d)
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    k_steps = sk // bk

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, k_steps=k_steps, bq=bq, bk=bk
        ),
        grid=(b * h, s // bq, k_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
