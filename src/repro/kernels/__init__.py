"""Pallas TPU kernels for the Ozaki-II hot spots (validated in interpret
mode on CPU; see tests/test_kernels.py for the per-kernel allclose sweeps).
"""
from .common import count_pallas_launches
from .crt_garner import crt_garner
from .flash_attention import flash_attention
from .fp8_mod_gemm import (
    FP8_K_CHUNK_LIMIT,
    fp8_karatsuba_mod_gemm_batched,
    fp8_mod_gemm_batched,
)
from .int8_mod_gemm import fused_mod_gemm, int8_mod_gemm, int8_mod_gemm_batched
from .karatsuba_fused import (
    fused_karatsuba_mod_gemm,
    karatsuba_mod_gemm,
    karatsuba_mod_gemm_batched,
)
from .ops import (
    FusedBackend,
    KernelBackend,
    PerModulusKernelBackend,
    ozaki2_cgemm_kernels,
    ozaki2_gemm_kernels,
)
from .residue_cast import residue_cast

__all__ = [
    "FP8_K_CHUNK_LIMIT",
    "FusedBackend",
    "KernelBackend",
    "PerModulusKernelBackend",
    "count_pallas_launches",
    "crt_garner",
    "flash_attention",
    "fp8_karatsuba_mod_gemm_batched",
    "fp8_mod_gemm_batched",
    "fused_karatsuba_mod_gemm",
    "fused_mod_gemm",
    "int8_mod_gemm",
    "int8_mod_gemm_batched",
    "karatsuba_mod_gemm",
    "karatsuba_mod_gemm_batched",
    "ozaki2_cgemm_kernels",
    "ozaki2_gemm_kernels",
    "residue_cast",
]
