"""Pallas TPU kernels for the Ozaki-II hot spots (validated in interpret
mode on CPU; see tests/test_kernels.py for the per-kernel allclose sweeps).
"""
from .crt_garner import crt_garner
from .flash_attention import flash_attention
from .int8_mod_gemm import int8_mod_gemm
from .karatsuba_fused import karatsuba_mod_gemm
from .ops import KernelBackend, ozaki2_cgemm_kernels, ozaki2_gemm_kernels
from .residue_cast import residue_cast

__all__ = [
    "KernelBackend",
    "crt_garner",
    "flash_attention",
    "int8_mod_gemm",
    "karatsuba_mod_gemm",
    "ozaki2_cgemm_kernels",
    "ozaki2_gemm_kernels",
    "residue_cast",
]
