"""Shared helpers for the Ozaki-II Pallas TPU kernels.

Everything here is exact f32/int32 arithmetic: the kernels never touch f64
(TPU has none).  Values stay below 2^24 after the limb peel, where f32
arithmetic on integers is error-free.

Two flavours of the symmetric modular reduction coexist:

  * static-p (`sym_mod_f32` with Python floats) — used where the modulus is
    a compile-time constant (residue_cast / crt_garner, whose host tables
    are per-modulus anyway);
  * dynamic-p (`dyn_mod_params` + the same `sym_mod_f32` on traced scalars)
    — used by the modulus-batched GEMM kernels, where the modulus arrives as
    a scalar-prefetched int32 array indexed by the leading grid dimension.

Both produce the exact canonical symmetric residue (the +/-1 correction
steps absorb the reciprocal rounding), so batched and per-modulus kernels
are bitwise identical.
"""
from __future__ import annotations

import jax

import jax.numpy as jnp
import numpy as np

LIMB_BITS = 24
LIMB = float(1 << LIMB_BITS)


def interpret_default() -> bool:
    """Run kernels in interpret mode off-TPU (this container is CPU-only)."""
    return jax.default_backend() != "tpu"


def sym_mod_f32(v, p, half):
    """Symmetric mod for f32 integer values |v| <~ 2^24 (exact, see core).

    `p`/`half` may be Python floats (static modulus) or traced f32 scalars
    (dynamic modulus from scalar prefetch): the initial guess n = round(v/p)
    is within +/-1 of the true quotient either way, and the two correction
    steps make the result the exact canonical symmetric residue.
    """
    n = jnp.round(v * (1.0 / p))
    r = v - n * p
    r = jnp.where(r > half, r - p, r)
    r = jnp.where(r < -half, r + p, r)
    return r


def dyn_mod_params(moduli_ref, l):
    """(pf, half, m16) for plane `l` from a scalar-prefetched int32 moduli ref.

    pf = p as f32; half = (p-1)/2 (exact: p odd, so floor(p/2) == (p-1)/2);
    m16 = symmetric residue of 2^16 mod p (|m16| <= half), used by the exact
    16-bit-split int32 reduction.  All three are exact small f32 integers.
    """
    pf = moduli_ref[l].astype(jnp.float32)
    half = jnp.floor(pf * 0.5)
    m16 = sym_mod_f32(jnp.float32(float(1 << 16)), pf, half)
    return pf, half, m16


def sym_mod_int32_dyn(d, pf, half, m16):
    """Exact symmetric mod of int32 (|d| < 2^31) with a dynamic modulus.

    d = dh*2^16 + dl with dh = d >> 16 (floor), dl = d & 0xffff in [0, 2^16);
    both below 2^24 so the f32 modular arithmetic is exact.  `pf`/`half`/
    `m16` come from :func:`dyn_mod_params` (traced) or host floats (static —
    the two agree bit-for-bit because the result is the exact residue).
    """
    dh = jnp.right_shift(d, 16).astype(jnp.float32)  # arithmetic shift: floor
    dl = jnp.bitwise_and(d, (1 << 16) - 1).astype(jnp.float32)
    rh = sym_mod_f32(dh, pf, half)
    rl = sym_mod_f32(dl, pf, half)
    return sym_mod_f32(rh * m16 + rl, pf, half)


def static_mod_params(p: int) -> tuple[float, float, float]:
    """(pf, half, m16) as Python floats for a compile-time modulus.

    The static twin of :func:`dyn_mod_params`: host-computed m16 is the same
    exact symmetric residue of 2^16 mod p, so `sym_mod_int32_dyn` fed with
    these constants is bitwise identical to the dynamic-modulus call.
    """
    half = (p - 1) // 2
    m16 = pow(1 << 16, 1, p)
    if m16 > half:
        m16 -= p
    return float(p), float(half), float(m16)


def residue_tiles_f32(x, s1, s2, *, moduli, n_limbs, scale_axis):
    """Scale -> trunc -> limb-peel -> per-modulus canonical residues, in f32.

    The single implementation of Alg. 1 steps IV + V-i/ii shared by the
    standalone residue-cast kernel and the fused megakernel prologues: both
    run literally these ops, so their int8 planes are bitwise identical.

    `x` is one (bm, bk) f32 tile; `s1*s2` the power-of-two scale factors
    broadcast along rows (scale_axis=0) or columns (scale_axis=1).  Returns
    a list of N (bm, bk) f32 tiles, each the exact canonical symmetric
    residue (|r| <= (p-1)/2) ready for `.astype(jnp.int8)`.
    """
    if scale_axis == 0:
        scale = (s1 * s2)[:, None]
    else:
        scale = (s1 * s2)[None, :]
    x = jnp.trunc(x * scale)  # exact: power-of-two scale, f32 trunc

    # exact base-2^24 limb peel (DESIGN.md S2)
    limbs = []
    rem = x
    for i in reversed(range(1, n_limbs)):
        base = LIMB**i
        hi = jnp.trunc(rem * (1.0 / base))  # 1/2^24k is a power of two: exact
        rem = rem - hi * base
        limbs.append(hi)
    limbs.append(rem)
    limbs = limbs[::-1]

    radix = limb_radix_f32(moduli, n_limbs)  # static host table
    out = []
    for l, p in enumerate(moduli):
        pf, half = float(p), float((p - 1) // 2)
        acc = jnp.zeros_like(x)
        for i in range(n_limbs):
            acc = acc + sym_mod_f32(limbs[i], pf, half) * float(radix[i, l])
        out.append(sym_mod_f32(acc, pf, half))
    return out


def limb_radix_f32(moduli, n_limbs: int) -> np.ndarray:
    """(n_limbs, N) f32 table of symmetric 2^(24 i) mod p_l."""
    tab = np.zeros((n_limbs, len(moduli)), dtype=np.float32)
    for i in range(n_limbs):
        for l, p in enumerate(moduli):
            r = pow(1 << LIMB_BITS, i, p)
            if r > (p - 1) // 2:
                r -= p
            tab[i, l] = float(r)
    return tab


def split_scale_exponent(e: np.ndarray | jnp.ndarray, bias: int = 0):
    """Split exponents e+bias into two f32-safe power-of-two factors.

    Returns (s1, s2) f32 with s1*s2 == 2^(e+bias) exactly, each factor's
    exponent within f32 normal range for |e+bias| <= 252.
    """
    et = e + bias
    e1 = et // 2
    e2 = et - e1
    one = jnp.float64(1.0)
    return (
        jnp.ldexp(one, e1).astype(jnp.float32),
        jnp.ldexp(one, e2).astype(jnp.float32),
    )


# ------------------------------------------------- ragged-shape pad/slice


def round_up(x: int, mult: int) -> int:
    """Smallest multiple of `mult` that is >= x."""
    return -(-x // mult) * mult


def pad_dims(x, targets: dict[int, int], value=0):
    """Zero-pad (or `value`-pad) `x` at the end of each axis up to `targets`.

    Zero padding is residue-exact: residues of 0 are 0 for every modulus,
    padded K contributes nothing to dot products, and padded M/N rows and
    columns are sliced off the output — so pad-and-slice keeps every kernel
    bit-identical on the retained region.
    """
    pads = [(0, 0)] * x.ndim
    needed = False
    for ax, tgt in targets.items():
        cur = x.shape[ax]
        if cur != tgt:
            pads[ax] = (0, tgt - cur)
            needed = True
    if not needed:
        return x
    return jnp.pad(x, pads, constant_values=value)


def block_and_padded(
    dim: int, block: int, align: int | None = None
) -> tuple[int, int]:
    """(block', padded_dim) for one axis: shrink the block to the axis when
    the axis is smaller, otherwise pick the padding-minimizing aligned block
    (perfmodel.select_block — the shared, perfmodel-visible rule) and round
    the axis up to a multiple of it.  With `align=None` (or the
    `perfmodel.BLOCK_SHRINK` knob off) this is the legacy round-up to the
    default block: just-over-a-multiple dims like m=257 then pad ~2x, which
    the aligned shrink avoids (257 @ bm=256/align=128 -> block 128, pad 384).
    """
    from ..core.perfmodel import select_block

    b = select_block(dim, block, align)
    return b, round_up(dim, b)


#: static default (bm, bn, bk) of every batched/fused GEMM kernel — what
#: runs when no calibration is active and the caller passes no blocks
DEFAULT_GEMM_BLOCKS = (256, 256, 512)


def resolve_blocks(
    family: str,
    dclass: str,
    m: int,
    n: int,
    k: int,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> tuple[int, int, int]:
    """The (bm, bn, bk) a GEMM kernel launches for one (family, dclass,
    shape) slot.

    Explicit caller-passed values always win per axis.  Unset axes resolve
    from the active calibration's autotuned winner for this slot
    (`repro.tune` — `current_calibration().block_for(block_key(...))`),
    else the static `DEFAULT_GEMM_BLOCKS`.  The result then flows through
    the exact same `block_and_padded` pad-and-slice path as the defaults,
    so tuned blocks can never change numerics — only which tiles the
    `pallas_call` grid steps over.
    """
    tuned = None
    if bm is None or bn is None or bk is None:
        # lazy import: tune.cache must stay importable without the kernels
        from ..tune.cache import block_key, current_calibration

        cal = current_calibration()
        if cal is not None:
            tuned = cal.block_for(block_key(family, dclass, m, n, k))
    base = tuned or DEFAULT_GEMM_BLOCKS
    return (
        bm if bm is not None else base[0],
        bn if bn is not None else base[1],
        bk if bk is not None else base[2],
    )


# ------------------------------------------------- launch-count diagnostics
# The jaxpr walker grew into the repro.analysis pass framework (PR 7);
# re-exported here because older callers import it from kernels.common.

from ..analysis.jaxprs import (  # noqa: E402,F401
    count_pallas_calls,
    count_pallas_launches,
    iter_subjaxprs as _iter_subjaxprs,
)


def _count_in_jaxpr(jaxpr) -> int:
    """Compat shim: pallas_call count of one (open) jaxpr, nested included."""
    from ..analysis.jaxprs import count_primitive

    return count_primitive(jaxpr, "pallas_call")
