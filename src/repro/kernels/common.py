"""Shared helpers for the Ozaki-II Pallas TPU kernels.

Everything here is exact f32/int32 arithmetic: the kernels never touch f64
(TPU has none).  Values stay below 2^24 after the limb peel, where f32
arithmetic on integers is error-free.
"""
from __future__ import annotations

import jax

import jax.numpy as jnp
import numpy as np

LIMB_BITS = 24
LIMB = float(1 << LIMB_BITS)


def interpret_default() -> bool:
    """Run kernels in interpret mode off-TPU (this container is CPU-only)."""
    return jax.default_backend() != "tpu"


def sym_mod_f32(v, p: float, half: float):
    """Symmetric mod for f32 integer values |v| <~ 2^20 (exact, see core)."""
    n = jnp.round(v * (1.0 / p))
    r = v - n * p
    r = jnp.where(r > half, r - p, r)
    r = jnp.where(r < -half, r + p, r)
    return r


def sym_mod_int32_via_f32(d, p: int):
    """Exact symmetric mod of int32 (|d| < 2^31) using an exact 16-bit split.

    d = dh*2^16 + dl with dh = d >> 16 (floor), dl = d & 0xffff in [0, 2^16);
    both below 2^24 so the f32 modular arithmetic is exact.
    """
    half = float((p - 1) // 2)
    pf = float(p)
    m16 = float(pow(1 << 16, 1, p))  # 2^16 mod p (representative in [0,p))
    dh = jnp.right_shift(d, 16).astype(jnp.float32)  # arithmetic shift: floor
    dl = jnp.bitwise_and(d, (1 << 16) - 1).astype(jnp.float32)
    rh = sym_mod_f32(dh, pf, half)
    rl = sym_mod_f32(dl, pf, half)
    return sym_mod_f32(rh * m16 + rl, pf, half)


def limb_radix_f32(moduli, n_limbs: int) -> np.ndarray:
    """(n_limbs, N) f32 table of symmetric 2^(24 i) mod p_l."""
    tab = np.zeros((n_limbs, len(moduli)), dtype=np.float32)
    for i in range(n_limbs):
        for l, p in enumerate(moduli):
            r = pow(1 << LIMB_BITS, i, p)
            if r > (p - 1) // 2:
                r -= p
            tab[i, l] = float(r)
    return tab


def split_scale_exponent(e: np.ndarray | jnp.ndarray, bias: int = 0):
    """Split exponents e+bias into two f32-safe power-of-two factors.

    Returns (s1, s2) f32 with s1*s2 == 2^(e+bias) exactly, each factor's
    exponent within f32 normal range for |e+bias| <= 252.
    """
    et = e + bias
    e1 = et // 2
    e2 = et - e1
    one = jnp.float64(1.0)
    return (
        jnp.ldexp(one, e1).astype(jnp.float32),
        jnp.ldexp(one, e2).astype(jnp.float32),
    )
