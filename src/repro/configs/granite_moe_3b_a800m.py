"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155; 40 experts top-8 [hf:ibm-granite family]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    n_layers=32,
    d_model=1536,
    vocab=49155,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    mlp="moe",
    moe_experts=40,
    moe_topk=8,
    norm="rmsnorm",
    pos="rope",
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="granite-moe-3b-a800m-reduced",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=64,
    mlp="moe",
    moe_experts=8,
    moe_topk=2,
    norm="rmsnorm",
    pos="rope",
    tie_embeddings=True,
)
