"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152; GQA, RoPE, 4096 sliding window [arXiv:2402.19173; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    n_layers=30,
    d_model=3072,
    vocab=49152,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    qkv_bias=True,
    d_ff=12288,
    mlp="gelu",
    norm="layernorm",
    pos="rope",
    window=4096,
)

REDUCED = ModelConfig(
    name="starcoder2-3b-reduced",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    qkv_bias=True,
    d_ff=256,
    mlp="gelu",
    norm="layernorm",
    pos="rope",
    window=64,
)
