"""mamba2-130m [ssm] — 24L d_model=768 attn-free, ssm_state=128,
vocab=50280; SSD state-space duality [arXiv:2405.21060].

Pure Mamba2 blocks (no attention, no MLP: d_ff=0); d_inner = 2*768 = 1536,
headdim=64 -> 24 SSD heads.  Sub-quadratic: runs the long_500k shape."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    n_layers=24,
    d_model=768,
    vocab=50280,
    d_ff=0,
    block_pattern=("ssd",),
    norm="rmsnorm",
    pos="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    conv_width=4,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="mamba2-130m-reduced",
    n_layers=2,
    d_model=128,
    vocab=512,
    d_ff=0,
    block_pattern=("ssd",),
    norm="rmsnorm",
    pos="none",
    ssm_state=32,
    ssm_expand=2,
    ssm_headdim=32,
    conv_width=4,
    tie_embeddings=True,
)
