"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000; RG-LRU + local attention 1:2 [arXiv:2402.19427; hf].

Griffin pattern: (rglru, rglru, attn) repeating; local window 2048; GeGLU
MLP (7680 = 3x expansion).  Sub-quadratic: runs the long_500k shape
(windowed KV ring buffer + constant-size LRU state)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    n_layers=26,
    d_model=2560,
    vocab=256000,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    mlp="geglu",
    norm="rmsnorm",
    pos="rope",
    window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=2560,
    conv_width=4,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="recurrentgemma-2b-reduced",
    n_layers=3,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=384,
    mlp="geglu",
    norm="rmsnorm",
    pos="rope",
    window=32,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=128,
    conv_width=4,
    tie_embeddings=True,
)
