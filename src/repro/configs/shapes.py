"""Assigned input shapes and per-(arch x shape) applicability.

  train_4k     seq_len=4096    global_batch=256   (train_step)
  prefill_32k  seq_len=32768   global_batch=32    (serve prefill)
  decode_32k   seq_len=32768   global_batch=128   (serve_step: 1 new token,
                                                   KV/state cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     (long-context decode)

long_500k requires sub-quadratic attention: it runs only for the SSM/hybrid
archs (mamba2-130m, recurrentgemma-2b); the 8 pure full-attention archs skip
it (DESIGN.md S5).  All assigned archs are decoder-style backbones, so every
arch runs the decode shapes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig

SUBQUADRATIC = {"mamba2-130m", "recurrentgemma-2b"}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and cfg.name.split("-reduced")[0] not in SUBQUADRATIC:
        return False, "full-attention arch: 512k dense decode skipped (DESIGN.md S5)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    For train/prefill: the (tokens [+ prefix_embeds]) batch.  The token count
    is reduced by n_prefix_embeds so total sequence == shape.seq_len.
    For decode: one new token; the cache specs come from Model.cache_abstract.
    """
    spec = SHAPES[shape]
    npre = cfg.n_prefix_embeds if cfg.frontend else 0
    if spec.kind in ("train", "prefill"):
        s_tok = spec.seq_len - npre
        out = {
            "tokens": jax.ShapeDtypeStruct(
                (spec.global_batch, s_tok), jnp.int32
            )
        }
        if npre:
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (spec.global_batch, npre, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        return out
    return {
        "tokens": jax.ShapeDtypeStruct((spec.global_batch, 1), jnp.int32)
    }
