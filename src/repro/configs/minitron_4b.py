"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000; pruned nemotron (squared-ReLU) [arXiv:2407.14679; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    n_layers=32,
    d_model=3072,
    vocab=256000,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    mlp="sq_relu",
    norm="layernorm",
    pos="rope",
    rope_pct=0.5,
)

REDUCED = ModelConfig(
    name="minitron-4b-reduced",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=192,
    mlp="sq_relu",
    norm="layernorm",
    pos="rope",
    rope_pct=0.5,
)
