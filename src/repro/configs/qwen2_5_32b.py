"""qwen2.5-32b [dense] — 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064; GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B family; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    n_layers=64,
    d_model=5120,
    vocab=152064,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    qkv_bias=True,
    d_ff=27648,
    mlp="swiglu",
    norm="rmsnorm",
    pos="rope",
    rope_theta=1e6,
)

REDUCED = ModelConfig(
    name="qwen2.5-32b-reduced",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    qkv_bias=True,
    d_ff=256,
    mlp="swiglu",
    norm="rmsnorm",
    pos="rope",
    rope_theta=1e6,
)
