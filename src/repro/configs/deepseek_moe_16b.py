"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (MHA kv=16) d_ff=1408
vocab=102400; 2 shared + 64 routed top-6, fine-grained; dense FFN
(ff=10944) in layer 0 [arXiv:2401.06066; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    vocab=102400,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    mlp="moe",
    moe_experts=64,
    moe_topk=6,
    moe_shared=2,
    first_dense_ff=10944,
    norm="rmsnorm",
    pos="rope",
)

REDUCED = ModelConfig(
    name="deepseek-moe-16b-reduced",
    n_layers=3,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=64,
    mlp="moe",
    moe_experts=8,
    moe_topk=2,
    moe_shared=1,
    first_dense_ff=256,
    norm="rmsnorm",
    pos="rope",
)
