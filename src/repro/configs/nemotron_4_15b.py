"""nemotron-4-15b [dense] — 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000; squared-ReLU MLP, partial RoPE [arXiv:2402.16819]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    n_layers=32,
    d_model=6144,
    vocab=256000,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    mlp="sq_relu",
    norm="layernorm",
    pos="rope",
    rope_pct=0.5,
)

REDUCED = ModelConfig(
    name="nemotron-4-15b-reduced",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    mlp="sq_relu",
    norm="layernorm",
    pos="rope",
    rope_pct=0.5,
)
