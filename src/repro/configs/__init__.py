"""Assigned-architecture registry: `get_config(arch)` / `get_reduced(arch)`.

Each module defines CONFIG (the exact published configuration) and REDUCED
(same family, small dims — used by the CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = (
    "mamba2-130m",
    "internvl2-26b",
    "qwen2.5-32b",
    "nemotron-4-15b",
    "starcoder2-3b",
    "minitron-4b",
    "recurrentgemma-2b",
    "granite-moe-3b-a800m",
    "deepseek-moe-16b",
    "musicgen-medium",
)


def _module(arch: str):
    from ..linalg import _no_ambient_policy

    name = arch.replace("-", "_").replace(".", "_")
    with _no_ambient_policy():
        # first import may run inside a use_policy scope; the module-level
        # CONFIG/REDUCED must stay scope-independent (re-pinned by _resolve)
        return importlib.import_module(f"repro.configs.{name}")


def _resolve(cfg, overrides):
    """Registry configs are built at import time (no ambient scope), so a
    `repro.use_policy` scope active at *lookup* re-pins their matmul policy
    — unless the arch module configured an emulated policy explicitly or the
    caller overrides `gemm_policy` themselves."""
    if "gemm_policy" not in overrides:
        from ..core.policy import NATIVE
        from ..linalg import current_policy

        ambient = current_policy()
        if ambient != NATIVE and cfg.gemm_policy == NATIVE:
            overrides = dict(overrides, gemm_policy=ambient)
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_config(arch: str, **overrides):
    return _resolve(_module(arch).CONFIG, overrides)


def get_reduced(arch: str, **overrides):
    return _resolve(_module(arch).REDUCED, overrides)
