"""Assigned-architecture registry: `get_config(arch)` / `get_reduced(arch)`.

Each module defines CONFIG (the exact published configuration) and REDUCED
(same family, small dims — used by the CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = (
    "mamba2-130m",
    "internvl2-26b",
    "qwen2.5-32b",
    "nemotron-4-15b",
    "starcoder2-3b",
    "minitron-4b",
    "recurrentgemma-2b",
    "granite-moe-3b-a800m",
    "deepseek-moe-16b",
    "musicgen-medium",
)


def _module(arch: str):
    name = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str, **overrides):
    cfg = _module(arch).CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_reduced(arch: str, **overrides):
    cfg = _module(arch).REDUCED
    return dataclasses.replace(cfg, **overrides) if overrides else cfg
