"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553; InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

The InternViT vision frontend is a STUB: `input_specs()` supplies
precomputed patch embeddings (B, 256, d_model) prepended to the token
sequence (DESIGN.md S5)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    n_layers=48,
    d_model=6144,
    vocab=92553,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    mlp="swiglu",
    norm="rmsnorm",
    pos="rope",
    frontend="vision",
    n_prefix_embeds=256,
)

REDUCED = ModelConfig(
    name="internvl2-26b-reduced",
    n_layers=2,
    d_model=128,
    vocab=512,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    mlp="swiglu",
    norm="rmsnorm",
    pos="rope",
    frontend="vision",
    n_prefix_embeds=8,
)
