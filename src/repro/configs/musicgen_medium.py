"""musicgen-medium [audio] — 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

The EnCodec/T5 frontends are STUBS: the backbone consumes discrete audio
tokens directly plus precomputed text-conditioning embeddings (B, 64, d)
as a prefix (prefix-LM approximation of MusicGen's cross-attention
conditioning; recorded in DESIGN.md S5)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    vocab=2048,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    mlp="gelu",
    norm="layernorm",
    pos="sinusoidal",
    frontend="audio",
    n_prefix_embeds=64,
)

REDUCED = ModelConfig(
    name="musicgen-medium-reduced",
    n_layers=2,
    d_model=128,
    vocab=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    mlp="gelu",
    norm="layernorm",
    pos="sinusoidal",
    frontend="audio",
    n_prefix_embeds=8,
)
