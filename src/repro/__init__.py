"""repro — Ozaki-II CRT-based GEMM emulation framework (JAX/Pallas, TPU target).

Reproduction + extension of "Emulation of Complex Matrix Multiplication based
on the Chinese Remainder Theorem" (Uchino, Ma, Imamura, Ozaki, Gutsche, 2025).
"""
import os

# The reference/validation paths of the Ozaki-II scheme need float64 on the
# CPU host (the TPU kernels themselves are int8/int32/f32 only).  All model
# code uses explicit dtypes, so enabling x64 is inert for them.
if os.environ.get("REPRO_NO_X64", "0") != "1":
    import jax

    jax.config.update("jax_enable_x64", True)

# The context-scoped execution-policy API (the LD_PRELOAD analog): scope a
# GemmPolicy with `repro.use_policy(...)` and every `repro.linalg.matmul` —
# including the model/serve/train layers, whose configs resolve the ambient
# policy at construction — routes through it.
from . import linalg  # noqa: E402
from .linalg import current_mesh, current_policy, use_mesh, use_policy  # noqa: E402

# On-device calibration scoping (repro.tune): `use_calibration` /
# `set_calibration` make the perfmodel 'auto' selections price against the
# measured HW and the Pallas kernels launch autotuned block shapes; with no
# calibration active, behaviour is identical to the hardware presets.
from .tune import (  # noqa: E402
    current_calibration,
    set_calibration,
    use_calibration,
)

__all__ = [
    "current_calibration",
    "current_mesh",
    "current_policy",
    "linalg",
    "set_calibration",
    "use_calibration",
    "use_mesh",
    "use_policy",
]
__version__ = "1.0.0"
