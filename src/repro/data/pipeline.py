"""Deterministic, shardable synthetic LM data pipeline.

Every batch is a pure function of (seed, step, shard_index) — the property
that makes restart-after-preemption and elastic re-sharding exactly
replayable (DESIGN.md S4): a host that picks up shard i at step s generates
the same tokens regardless of when/where it runs.

The token stream is a Zipf-ish mixture with a Markov backbone so small
models show a measurable, decreasing loss (used by the convergence tests and
examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLM:
    """Markov-chain synthetic corpus, deterministic per (step, shard)."""

    def __init__(self, cfg: DataConfig, num_shards: int = 1, shard: int = 0):
        if cfg.global_batch % num_shards:
            raise ValueError("global_batch must divide by num_shards")
        self.cfg = cfg
        self.num_shards = num_shards
        self.shard = shard
        self.local_batch = cfg.global_batch // num_shards
        # small deterministic transition structure shared by all shards
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        self._hot = rng.integers(0, v, size=(v, 4))  # 4 likely successors

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + self.shard
        )
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab
        toks = np.empty((b, s), dtype=np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        jump = rng.random((b, s)) < 0.15
        pick = rng.integers(0, 4, size=(b, s))
        rand_tok = rng.integers(0, v, size=(b, s))
        for t in range(1, s):
            nxt = self._hot[toks[:, t - 1], pick[:, t]]
            toks[:, t] = np.where(jump[:, t], rand_tok[:, t], nxt)
        return {"tokens": toks}


def make_batch_specs(cfg: DataConfig) -> dict:
    """ShapeDtypeStructs of a global batch (dry-run input stand-ins)."""
    import jax
    import jax.numpy as jnp

    return {
        "tokens": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len), jnp.int32)
    }
