import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
# init).  The dry-run — and only the dry-run — builds the production mesh
# with 512 placeholder host devices.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this prints/records:
  * compiled.memory_analysis()  — proves the per-device working set,
  * compiled.cost_analysis()    — per-device HLO FLOPs / bytes,
  * the collective schedule     — bytes per collective kind parsed from the
                                  partitioned HLO (all-gather / all-reduce /
                                  reduce-scatter / all-to-all / permute),
used by benchmarks/roofline.py to derive the three roofline terms
(EXPERIMENTS.md SRoofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
      --shape train_4k [--multi-pod] [--backend ozaki2_f32] [--seq-shard]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import re
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro  # noqa: F401  (enables x64 for the core library)
from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, applicable, input_specs
from repro.core.policy import GemmPolicy
from repro.distributed.sharding import (
    DEFAULT_RULES,
    pspec_for_axes,
    tree_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.models.params import abstract_arrays
from repro.optim import AdamWConfig
from repro.train.step import make_train_step
from repro.tune.cli import add_calibration_args, apply_calibration_args

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _line_result_bytes(line: str) -> int:
    """Bytes of the op result type(s) on an HLO text line (LHS of '= ... op(')."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    # result types appear between '=' and the op name
    mloc = None
    for c in _COLLECTIVES:
        i = lhs[1].find(c + "(")
        if i >= 0:
            mloc = i
            break
    if mloc is None:
        return 0
    typestr = lhs[1][:mloc]
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        for c in _COLLECTIVES:
            if f" {c}(" in line or f"{c}-start(" in line or f" {c}(" in line:
                b = _line_result_bytes(line)
                if b:
                    out[c] += b
                    out["count"] += 1
                break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def _mem_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {"error": "unavailable on this backend"}
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    )
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def _opt_abstract(params_abs, opt_cfg: AdamWConfig):
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    out = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(f32, params_abs),
        "v": jax.tree.map(f32, params_abs),
    }
    if opt_cfg.use_master:
        out["master"] = jax.tree.map(f32, params_abs)
    return out


def build_cell(cfg, shape_name: str, mesh, grad_accum: int = 1, rules=None):
    """Returns (jitted_fn, example_args) for one cell.

    With mesh=None the cell is built unsharded (the flop-accounting path)."""
    model = Model(cfg)
    spec = SHAPES[shape_name]
    rules = rules or DEFAULT_RULES
    if spec.kind == "train":
        opt_cfg = AdamWConfig()
        step, shardings = make_train_step(
            model, opt_cfg, mesh=mesh, grad_accum=grad_accum, donate=False,
            rules=rules,
        )
        params_abs = abstract_arrays(model.abstract_params())
        args = (params_abs, _opt_abstract(params_abs, opt_cfg), input_specs(cfg, shape_name))
        return step, args
    params_abs = abstract_arrays(model.abstract_params())
    cache_abs_meta = model.cache_abstract(spec.global_batch, spec.seq_len)
    cache_abs = abstract_arrays(cache_abs_meta)
    if mesh is not None:
        params_sh = tree_shardings(model.abstract_params(), rules, mesh)
        cache_sh = tree_shardings(cache_abs_meta, rules, mesh)

        def _batch_leaf(sds):
            axes = ("batch",) + (None,) * (len(sds.shape) - 1)
            spec = pspec_for_axes(axes, rules, mesh, sds.shape)
            return NamedSharding(mesh, spec)

        batch_sh = jax.tree.map(_batch_leaf, input_specs(cfg, shape_name))
    if spec.kind == "prefill":
        fn = (
            jax.jit(model.prefill, in_shardings=(params_sh, batch_sh, cache_sh))
            if mesh is not None
            else jax.jit(model.prefill)
        )
        return fn, (params_abs, input_specs(cfg, shape_name), cache_abs)
    # decode
    fn = (
        jax.jit(
            model.decode_step,
            in_shardings=(
                params_sh,
                batch_sh["tokens"],
                cache_sh,
                NamedSharding(mesh, P()),
            ),
        )
        if mesh is not None
        else jax.jit(model.decode_step)
    )
    tok = input_specs(cfg, shape_name)["tokens"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params_abs, tok, cache_abs, pos)


def _unrolled_cost(cfg, shape_name: str, n_devices: int) -> dict:
    """Lower (no compile, no mesh) a scan-unrolled variant of the cell and
    read its cost analysis: XLA counts while-loop bodies ONCE, so the scanned
    production module under-reports flops by ~n_layers. The unrolled module
    gives the true totals; per-device = total / n_devices."""
    # NOTE: moe_group_size keeps its production value — GShard dispatch cost
    # scales quadratically with group size, so a single giant group would
    # inflate the count.  The group scan is then counted once per layer,
    # i.e. ~1 group/device of MoE work (2 groups/device on the single pod) —
    # a conservative, documented approximation (EXPERIMENTS.md SDry-run).
    cost_cfg = dataclasses.replace(
        cfg,
        scan_unroll=True,
        remat=False,
        kv_chunk=2**30,
        # sharding constraints need a mesh; the cost lowering is unpartitioned
        act_pspec=None,
        embed_pspec=None,
        moe_dispatch_pspec=None,
    )
    try:
        fn, args = build_cell(cost_cfg, shape_name, mesh=None, grad_accum=1)
        lowered = fn.lower(*args)
        cost = lowered.cost_analysis() or {}
        return {
            "flops_total": float(cost.get("flops", 0.0)),
            "flops_per_device": float(cost.get("flops", 0.0)) / n_devices,
            "bytes_total_unopt": float(cost.get("bytes accessed", 0.0)),
        }
    except Exception as e:  # pragma: no cover
        return {"error": f"{type(e).__name__}: {e}"}


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    backend: str = "native",
    seq_shard: bool = False,
    grad_accum: int = 1,
    vocab_chunk: int | None = None,
    moe_shard_tokens: bool = False,
    zero3: bool = False,
    kv_chunk: int | None = None,
    moe_group: int | None = None,
    mode: str = "fast",
    formulation: str = "karatsuba",
    n_block=None,
    execution: str = "reference",
    residue: int = 1,
    rtol: float | None = None,
    out_dir: str | None = None,
    verbose: bool = True,
):
    cfg = get_config(arch)
    ok, why = applicable(cfg, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if residue > 1:
        mesh_name += f"r{residue}"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    if backend != "native":
        cell_id += f"__{backend}"
        if execution != "reference":
            cell_id += f"__{execution}"
        if mode != "fast":
            cell_id += f"__{mode}"
        if formulation != "karatsuba":
            cell_id += f"__{formulation}"
        if n_block:
            cell_id += f"__nb{n_block}"
        if rtol is not None:
            cell_id += f"__rtol{rtol:g}"
    if seq_shard:
        cell_id += "__sp"
    if grad_accum > 1:
        cell_id += f"__ga{grad_accum}"
    if vocab_chunk:
        cell_id += f"__vc{vocab_chunk}"
    if moe_shard_tokens:
        cell_id += "__moest"
    if zero3:
        cell_id += "__zero3"
    if kv_chunk:
        cell_id += f"__kv{kv_chunk}"
    if moe_group:
        cell_id += f"__mg{moe_group}"
    if not ok:
        rec = {"cell": cell_id, "status": "skipped", "reason": why}
        _emit(rec, out_dir, verbose)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod, residue=residue)
    overrides = {}
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    if backend != "native":
        overrides["gemm_policy"] = GemmPolicy(
            backend=backend,
            mode=mode,
            formulation=formulation,
            n_block=n_block,
            execution=execution,
            # the sharded execution shard_maps over the same mesh the cell
            # is partitioned on (pinned: the policy is a jit static)
            mesh=mesh if execution == "sharded" else None,
            rtol=rtol,
        )
        overrides["embed_pspec"] = (batch_axes, None, None)
    if seq_shard:
        overrides["act_pspec"] = (batch_axes, "model", None)
    if vocab_chunk:
        overrides["loss_vocab_chunk"] = vocab_chunk
    if moe_shard_tokens:
        overrides["moe_dispatch_pspec"] = (
            (("pod", "data"),) if multi_pod else (("data",),)
        )
    if kv_chunk:
        overrides["kv_chunk"] = kv_chunk
    if moe_group:
        overrides["moe_group_size"] = moe_group
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    rules = dict(DEFAULT_RULES)
    if zero3:
        # ZeRO-3-style parameter storage: the d_model ('embed') axis of every
        # weight additionally shards over 'data'; XLA gathers layer weights
        # on the fly inside the scan (SPerf hillclimb 1, iteration 4).
        rules["embed"] = "data"
    t0 = time.time()
    with mesh:
        fn, args = build_cell(cfg, shape_name, mesh, grad_accum, rules=rules)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        cost = compiled.cost_analysis() or {}
        mem = _mem_analysis_dict(compiled)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        coll = collective_bytes(hlo)
    n_dev = 512 if multi_pod else 256
    unrolled = _unrolled_cost(cfg, shape_name, n_dev)
    # loop-body correction: scale compiled per-device bytes & collective bytes
    # by the (unrolled / compiled) flops ratio (EXPERIMENTS.md SDry-run).
    scale = 1.0
    if unrolled.get("flops_per_device") and float(cost.get("flops", 0)) > 0:
        scale = max(1.0, unrolled["flops_per_device"] / float(cost["flops"]))
    rec = {
        "cell": cell_id,
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": [2, 16, 16] if multi_pod else [16, 16],
        "backend": backend,
        "seq_shard": seq_shard,
        "grad_accum": grad_accum,
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "unrolled": unrolled,
        "loop_scale": scale,
        "flops_per_device_corrected": unrolled.get(
            "flops_per_device", float(cost.get("flops", 0.0))
        ),
        "bytes_per_device_corrected": float(cost.get("bytes accessed", 0.0)) * scale,
        "cost_analysis": {
            k: float(v)
            for k, v in cost.items()
            if isinstance(v, (int, float)) and k in (
                "flops", "bytes accessed", "transcendentals",
                "utilization operand 0 {}", "optimal_seconds")
        },
        "memory_analysis": mem,
        "collectives": coll,
        "collective_bytes_corrected": coll["total"] * scale,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    _emit(rec, out_dir, verbose)
    return rec


def _emit(rec: dict, out_dir: str | None, verbose: bool):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, rec["cell"] + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        if rec["status"] == "skipped":
            print(f"[skip] {rec['cell']}: {rec['reason']}")
            return
        mem = rec["memory_analysis"]
        memstr = (
            f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
            f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
            f"out={mem.get('output_size_in_bytes', 0)/2**30:.2f}GiB"
            if "error" not in mem
            else f"mem: {mem['error']}"
        )
        c = rec["collectives"]
        print(
            f"[ok]   {rec['cell']}: flops/dev={rec['flops_per_device']:.3e} "
            f"bytes/dev={rec['bytes_per_device']:.3e} {memstr} "
            f"coll={c['total']/2**20:.1f}MiB({c['count']} ops: "
            f"ag={c['all-gather']/2**20:.0f} ar={c['all-reduce']/2**20:.0f} "
            f"rs={c['reduce-scatter']/2**20:.0f} a2a={c['all-to-all']/2**20:.0f} "
            f"cp={c['collective-permute']/2**20:.0f}) "
            f"compile={rec['compile_s']:.0f}s"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--backend", default="native",
                    choices=["native", "ozaki2_f32", "ozaki2_f64",
                             "ozaki2_c64", "ozaki2_c128"])
    ap.add_argument("--execution", default="reference",
                    choices=["reference", "kernel", "per_modulus_kernel",
                             "sharded", "fp8", "fused"],
                    help="residue backend running the emulation plan "
                         "(fp8: the e4m3 digit-GEMM engine; fused: the "
                         "one-launch megakernel)")
    ap.add_argument("--residue", type=int, default=1,
                    help="residue mesh-axis size (sharded execution): "
                         "carved out of the 16-way model axis")
    ap.add_argument("--mode", default="fast",
                    choices=["fast", "accu", "auto"])
    ap.add_argument("--rtol", type=float, default=None,
                    help="componentwise accuracy target (adaptive policy: "
                         "fewest moduli provably meeting it; required for "
                         "--mode auto)")
    ap.add_argument("--formulation", default="karatsuba",
                    choices=["karatsuba", "block_a", "block_b", "auto"])
    ap.add_argument("--n-block", default=None,
                    type=lambda s: "auto" if s == "auto" else int(s),
                    help="output-column blocking: an int or 'auto'")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--all", action="store_true", help="sweep every cell")
    ap.add_argument("--out", default="experiments/dryrun")
    add_calibration_args(ap)
    args = ap.parse_args()
    apply_calibration_args(args)
    if args.mode == "auto" and args.rtol is None:
        ap.error("--mode auto needs an accuracy target: pass --rtol")

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    if args.all:
        failures = []
        for arch in ARCHS:
            for shape in SHAPES:
                for mp in meshes:
                    try:
                        run_cell(arch, shape, mp, out_dir=args.out)
                    except Exception as e:  # keep sweeping; record the bug
                        failures.append((arch, shape, mp, f"{type(e).__name__}: {e}"))
                        print(f"[FAIL] {arch}/{shape}/mp={mp}: {type(e).__name__}: {e}")
        print(f"sweep done, {len(failures)} failures")
        for f in failures:
            print("  FAIL:", f)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required unless --all")
    for mp in meshes:
        run_cell(
            args.arch,
            args.shape,
            mp,
            backend=args.backend,
            seq_shard=args.seq_shard,
            grad_accum=args.grad_accum,
            mode=args.mode,
            formulation=args.formulation,
            n_block=args.n_block,
            execution=args.execution,
            residue=args.residue,
            rtol=args.rtol,
            out_dir=args.out,
        )


if __name__ == "__main__":
    main()
