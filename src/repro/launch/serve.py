"""Production serving launcher (batched prefill + decode).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b \
        --batch 8 --prompt-len 64 --new-tokens 64 [--temperature 0.8] \
        [--backend ozaki2_f32] [--execution kernel] \
        [--prepare] [--prepared-dir DIR]

An emulated --backend scopes the whole model onto that GemmPolicy via
`repro.use_policy` around config lookup (the context-scoped drop-in path);
--execution picks the residue backend (jnp reference or the batched Pallas
kernels).  --prepare residue-casts the weights once at startup with the
selected execution backend; --prepared-dir persists those planes so a
restarted server restores them instead of re-preparing.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

import contextlib

import repro
from repro.configs import ARCHS, get_reduced
from repro.core import GemmPolicy
from repro.models import Model
from repro.serve import ServeEngine
from repro.tune.cli import add_calibration_args, apply_calibration_args


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument(
        "--prepare", action="store_true",
        help="residue-cast weights once at startup (emulated backends: "
             "amortizes the scheme's step 1 across all requests)",
    )
    ap.add_argument("--prepared-dir", default=None,
                    help="persist/restore prepared residue planes here")
    ap.add_argument("--backend", default="native",
                    choices=["native", "ozaki2_f32", "ozaki2_f64",
                             "ozaki2_c64", "ozaki2_c128"])
    ap.add_argument("--execution", default="reference",
                    choices=["reference", "kernel", "per_modulus_kernel",
                             "sharded", "fp8", "fused"],
                    help="residue backend running the emulation plan "
                         "(fp8: the e4m3 digit-GEMM engine; fused: the "
                         "one-launch megakernel)")
    ap.add_argument("--residue", type=int, default=1,
                    help="residue mesh-axis size (sharded execution)")
    ap.add_argument("--mode", default="fast",
                    choices=["fast", "accu", "auto"],
                    help="paper scaling mode; 'auto' picks the cheapest "
                         "mode meeting --rtol per shape")
    ap.add_argument("--rtol", type=float, default=None,
                    help="componentwise accuracy target (adaptive policy: "
                         "fewest moduli provably meeting it; required for "
                         "--mode auto)")
    add_calibration_args(ap)
    args = ap.parse_args()
    apply_calibration_args(args)
    if args.mode == "auto" and args.rtol is None:
        ap.error("--mode auto needs an accuracy target: pass --rtol")

    scope = contextlib.nullcontext()
    if args.backend != "native":
        mesh = None
        if args.execution == "sharded":
            from repro.launch.mesh import make_host_mesh

            mesh = make_host_mesh(
                1, 1,
                residue=args.residue if args.residue > 1 else len(jax.devices()),
            )
        scope = repro.use_policy(
            GemmPolicy(backend=args.backend, execution=args.execution,
                       mesh=mesh, mode=args.mode, rtol=args.rtol)
        )
    with scope:
        cfg = get_reduced(args.arch, **(
            {} if args.backend == "native" else {"dtype": "float32"}
        ))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    npre = cfg.n_prefix_embeds if cfg.frontend else 0
    eng = ServeEngine(
        model, params,
        cache_len=args.prompt_len + npre + args.new_tokens,
        batch_size=args.batch,
        prepare=args.prepare,
        prepared_dir=args.prepared_dir,
    )
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.frontend:
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, npre, cfg.d_model)) * 0.02, jnp.float32)
    t0 = time.perf_counter()
    toks = eng.generate(batch, args.new_tokens, args.temperature,
                        jax.random.PRNGKey(1))
    dt = time.perf_counter() - t0
    print(f"[{args.arch}] {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
