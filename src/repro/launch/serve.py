"""Production serving launcher (batched prefill + decode).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b \
        --batch 8 --prompt-len 64 --new-tokens 64 [--temperature 0.8]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import ARCHS, get_reduced
from repro.models import Model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument(
        "--prepare", action="store_true",
        help="residue-cast weights once at startup (emulated backends: "
             "amortizes the scheme's step 1 across all requests)",
    )
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    npre = cfg.n_prefix_embeds if cfg.frontend else 0
    eng = ServeEngine(
        model, params,
        cache_len=args.prompt_len + npre + args.new_tokens,
        batch_size=args.batch,
        prepare=args.prepare,
    )
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.frontend:
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, npre, cfg.d_model)) * 0.02, jnp.float32)
    t0 = time.perf_counter()
    toks = eng.generate(batch, args.new_tokens, args.temperature,
                        jax.random.PRNGKey(1))
    dt = time.perf_counter() - t0
    print(f"[{args.arch}] {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
