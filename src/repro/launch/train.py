"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 100 --batch 8 --seq 256 [--mesh dxm] [--ckpt-dir DIR] \
        [--backend ozaki2_f32] [--execution kernel] [--mode accu] \
        [--formulation auto] [--n-block auto] [--rtol 1e-6] \
        [--seq-shard] [--vocab-chunk N] [--compress-dp]

The emulation flags mirror the `GemmPolicy` axes: `--backend` picks the
compute dtype class, `--execution` the residue backend (jnp reference,
modulus-batched Pallas kernels, or the per-modulus parity path), `--mode` /
`--formulation` / `--n-block` the paper's accuracy and Fig. 1 strategy knobs
('auto' consults the SIII-C perfmodel per shape).

On this CPU container the mesh defaults to 1x1; on a real pod pass
--mesh 16x16 (the dry-run proves those configs compile for every arch).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

import repro  # noqa: F401
from repro.configs import ARCHS, get_config, get_reduced
from repro.core.policy import GemmPolicy
from repro.data import DataConfig
from repro.models import Model
from repro.optim import AdamWConfig
from repro.train import TrainLoopConfig, train_loop
from repro.tune.cli import add_calibration_args, apply_calibration_args


def parse_n_block(s: str):
    """CLI n_block: an integer or the literal 'auto' (perfmodel-driven)."""
    return "auto" if s == "auto" else int(s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced config (full configs need a pod)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--mesh", default=None, help="DxM, e.g. 16x16")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--backend", default="native",
                    choices=["native", "ozaki2_f32", "ozaki2_f64",
                             "ozaki2_c64", "ozaki2_c128"])
    ap.add_argument("--execution", default="reference",
                    choices=["reference", "kernel", "per_modulus_kernel",
                             "sharded", "fp8", "fused"],
                    help="residue backend running the emulation plan "
                         "(fp8: the e4m3 digit-GEMM engine; fused: the "
                         "one-launch megakernel)")
    ap.add_argument("--residue", type=int, default=1,
                    help="residue mesh-axis size (sharded execution); "
                         "appended to the --mesh layout")
    ap.add_argument("--mode", default="fast", choices=["fast", "accu", "auto"],
                    help="paper scaling mode (accuracy band); 'auto' picks "
                         "the cheapest mode meeting --rtol per shape")
    ap.add_argument("--rtol", type=float, default=None,
                    help="componentwise accuracy target: the policy "
                         "resolves the fewest moduli whose core.accuracy "
                         "bound provably meets it (required for "
                         "--mode auto)")
    ap.add_argument("--formulation", default="karatsuba",
                    choices=["karatsuba", "block_a", "block_b", "auto"],
                    help="complex Fig. 1 strategy (complex backends only)")
    ap.add_argument("--n-block", default=None, type=parse_n_block,
                    help="output-column blocking: an int or 'auto'")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--vocab-chunk", type=int, default=None)
    add_calibration_args(ap)
    args = ap.parse_args()
    apply_calibration_args(args)
    if args.mode == "auto" and args.rtol is None:
        ap.error("--mode auto needs an accuracy target: pass --rtol")

    mesh = None
    if args.mesh:
        d, m = map(int, args.mesh.split("x"))
        if args.residue > 1:
            mesh = jax.make_mesh(
                (d, m, args.residue), ("data", "model", "residue")
            )
        else:
            mesh = jax.make_mesh((d, m), ("data", "model"))
    elif args.execution == "sharded":
        # sharded execution needs a mesh even on a single host: default to
        # every local device on the residue axis
        from .mesh import make_host_mesh

        mesh = make_host_mesh(
            1, 1,
            residue=args.residue if args.residue > 1 else len(jax.devices()),
        )

    cfg = (get_reduced if args.reduced else get_config)(args.arch)
    over = {}
    if args.backend != "native":
        over["gemm_policy"] = GemmPolicy(
            backend=args.backend,
            mode=args.mode,
            formulation=args.formulation,
            n_block=args.n_block,
            execution=args.execution,
            mesh=mesh if args.execution == "sharded" else None,
            rtol=args.rtol,
        )
        over["dtype"] = "float32"
    if args.seq_shard:
        over["act_pspec"] = (("data",), "model", None)
    if args.vocab_chunk:
        over["loss_vocab_chunk"] = args.vocab_chunk
    if over:
        cfg = dataclasses.replace(cfg, **over)

    model = Model(cfg)
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    loop = TrainLoopConfig(
        steps=args.steps,
        warmup=max(5, args.steps // 20),
        log_every=max(1, args.steps // 20),
        ckpt_every=max(10, args.steps // 4),
        ckpt_dir=args.ckpt_dir,
        grad_accum=args.grad_accum,
    )
    _, hist = train_loop(model, data, loop, AdamWConfig(lr=args.lr, grad_clip=5.0),
                         mesh=mesh)
    print(f"[{args.arch}] loss {hist[0]:.4f} -> {hist[-1]:.4f}")


if __name__ == "__main__":
    main()
