"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import
(see dryrun.py) so jax.make_mesh can build the full production topology on
the CPU container.

The optional `residue` axis carves residue-plane parallelism for
`GemmPolicy(execution="sharded")` out of the model axis (total chip count is
unchanged): the N int8 residue planes of every emulated GEMM shard over it,
m/n shard over data/model as usual, and only the reconstructed output is
psum-combined (see `distributed/sharded_gemm.py`).  With `residue=1` the
mesh shapes are exactly the pre-existing 2- and 3-axis layouts.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, residue: int = 1):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod.

    residue > 1 splits the 16-way model axis into (model // residue,
    residue) and appends a 'residue' mesh axis for sharded emulated GEMMs.
    """
    model = 16
    if residue > 1:
        if model % residue:
            raise ValueError(f"residue={residue} must divide the model axis ({model})")
        shape = (2, 16, model // residue, residue) if multi_pod else (
            16, model // residue, residue
        )
        axes = (
            ("pod", "data", "model", "residue")
            if multi_pod
            else ("data", "model", "residue")
        )
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, residue: int = 1):
    """Small mesh over whatever devices exist (tests/examples).

    residue > 1 appends a 'residue' axis (clamped like the others); with
    residue == 1 the mesh keeps the historical 2-axis ('data', 'model')
    layout.
    """
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    if residue > 1:
        residue = min(residue, max(1, n // (data * model)))
        return jax.make_mesh(
            (data, model, residue), ("data", "model", "residue")
        )
    return jax.make_mesh((data, model), ("data", "model"))
