"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import
(see dryrun.py) so jax.make_mesh can build the full production topology on
the CPU container.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
