"""AdamW with decoupled weight decay and global-norm clipping (hand-rolled;
the container has no optax).  Optimizer state (m, v, f32 master copy) is a
pytree mirroring the params, so pjit shards it with the ZeRO-1 rules in
`repro.distributed.sharding` (extra 'data'-axis sharding on the largest dim).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    use_master: bool = True  # keep f32 master weights for bf16 params


def adamw_init(params, cfg: AdamWConfig):
    import numpy as np

    # numpy-backed zeros: eager jnp constants of equal shape+dtype share a
    # buffer, which breaks donation ("donate same buffer twice" at Execute).
    zeros = lambda p: jnp.asarray(np.zeros(p.shape, np.float32))
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }
    if cfg.use_master:
        # jnp.array(copy=True): astype(f32) is a no-op alias for f32 params,
        # and donating both params and master then trips XLA.
        state["master"] = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
        )
    return state


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    masters = state.get("master", params)

    def upd(p, g, m, v, w):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        w32 = w.astype(jnp.float32)
        w32 = w32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * w32)
        return w32.astype(p.dtype), m, v, w32

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(masters)
    out = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "step": step,
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
    }
    if cfg.use_master:
        new_state["master"] = treedef.unflatten([o[3] for o in out])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
