"""Atomic, async checkpointing with auto-resume (no orbax in container).

Layout:  <dir>/step_<N>/arrays.npz + meta.json, written to a tmp dir and
renamed (atomic on POSIX), so a preemption mid-write never corrupts the
latest checkpoint.  `Checkpointer.save(..., blocking=False)` runs the
serialization on a background thread (compute/IO overlap); `wait()` joins.

Restore takes an optional sharding tree: arrays are `device_put` straight to
their shards, which is also the elastic-rescale path (same checkpoint, new
mesh — see distributed/elastic.py).

Param trees may contain `PreparedOperand` leaves (weights pre-residue-cast
for Ozaki-II serving): their scale exponents and int8 residue planes are
flattened into the same npz, and `restore` rebuilds the operands from the
static metadata carried by the `like` tree (obtained for free via
`jax.eval_shape(prepare_weights, ...)` — no residue cast runs).  This is
what lets `ServeEngine(prepare=True, prepared_dir=...)` restore residue
planes across restarts instead of re-preparing on construction.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

from ..core.executor import (
    PreparedOperand,
    _prepared_flatten,
    _prepared_unflatten,
)


def _prepared_encode(p: PreparedOperand) -> dict:
    """Array children of a PreparedOperand as a plain dict, via the same
    flatten the jax pytree registration uses (one source of truth for the
    children/aux split; the aux rides in the `like` tree on restore).
    The accu-only extras (bound matrices, raw operand) are keyed only when
    present, so fast-mode checkpoints keep the pre-accu on-disk format —
    older prepared_dir saves restore unchanged."""
    (e_scale, residues, bound, e_bound, raw), _ = _prepared_flatten(p)
    enc = {}
    if e_scale is not None:
        enc["e_scale"] = e_scale
    for i, r in enumerate(residues):
        enc[f"res{i}"] = r
    for i, b in enumerate(bound):
        enc[f"bound{i}"] = b
    if e_bound is not None:
        enc["e_bound"] = e_bound
    if raw is not None:
        enc["raw"] = raw
    return enc


def _prepared_decode(like: PreparedOperand, enc: dict) -> PreparedOperand:
    _, aux = _prepared_flatten(like)
    residues = tuple(enc[f"res{i}"] for i in range(len(like.residues)))
    bound = tuple(enc[f"bound{i}"] for i in range(len(like.bound)))
    e_scale = enc["e_scale"] if like.e_scale is not None else None
    e_bound = enc["e_bound"] if like.e_bound is not None else None
    raw = enc["raw"] if like.raw is not None else None
    return _prepared_unflatten(
        aux, (e_scale, residues, bound, e_bound, raw)
    )


def _flatten(tree, prefix=""):
    if isinstance(tree, PreparedOperand):
        yield from _flatten(_prepared_encode(tree), prefix)
    elif isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}{i}/")
    else:
        yield prefix[:-1], tree


def _unflatten_into(like, flat, prefix=""):
    if isinstance(like, PreparedOperand):
        return _prepared_decode(
            like, _unflatten_into(_prepared_encode(like), flat, prefix)
        )
    if isinstance(like, dict):
        return {k: _unflatten_into(like[k], flat, f"{prefix}{k}/") for k in like}
    if isinstance(like, (list, tuple)):
        return type(like)(
            _unflatten_into(v, flat, f"{prefix}{i}/") for i, v in enumerate(like)
        )
    return flat[prefix[:-1]]


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_", 1)[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and d.split("_", 1)[1].isdigit()
    ]
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree, blocking: bool = True, extra_meta=None):
        # pull to host *synchronously* (values must be a consistent snapshot)
        host = {}
        dtypes = {}
        for k, v in _flatten(tree):
            a = np.asarray(v)
            if a.dtype.kind == "V" or a.dtype.name in (
                "bfloat16",
                "float8_e4m3fn",
                "float8_e5m2",
            ):
                # npz has no native bf16/f8: store raw bits + dtype metadata
                dtypes[k] = a.dtype.name
                a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
            host[k] = a
        meta = {"step": int(step), "_dtypes": dtypes, **(extra_meta or {})}

        def _write():
            final = os.path.join(self.directory, f"step_{step}")
            tmp = final + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        self.wait()
        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(d.split("_", 1)[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and d.split("_", 1)[1].isdigit()
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), True)

    def restore(self, step: int, like, shardings=None):
        """Load step into the structure of `like` (pytree of arrays or
        ShapeDtypeStructs).  With `shardings`, device_put onto the mesh."""
        import ml_dtypes

        path = os.path.join(self.directory, f"step_{step}")
        dtypes = self.meta(step).get("_dtypes", {})
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {}
            for k in z.files:
                a = z[k]
                if k in dtypes:
                    a = a.view(np.dtype(getattr(ml_dtypes, dtypes[k])))
                flat[k] = a
        tree = _unflatten_into(like, flat)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree

    def meta(self, step: int) -> dict:
        with open(os.path.join(self.directory, f"step_{step}", "meta.json")) as f:
            return json.load(f)
