"""Assemble the EXPERIMENTS.md dry-run + roofline tables from the JSONs."""
import glob
import json
import os
import sys

sys.path.insert(0, "src")
import repro  # noqa: F401,E402
from benchmarks.roofline import analyze  # noqa: E402


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def main():
    recs = []
    for f in sorted(glob.glob("experiments/dryrun/*.json")):
        with open(f) as fh:
            recs.append(json.load(fh))

    print("## Dry-run summary (compile proof, per-device artifacts)\n")
    for mesh, tag in (([16, 16], "single pod 16x16 = 256 chips"),
                      ([2, 16, 16], "multi-pod 2x16x16 = 512 chips")):
        print(f"### {tag}\n")
        print("| cell | status | flops/dev (corr) | bytes/dev (corr) | "
              "temp GiB | coll MiB (corr) | ag/ar/a2a MiB | compile s |")
        print("|---|---|---|---|---|---|---|---|")
        for r in recs:
            if r.get("mesh") != mesh and r.get("status") == "ok":
                continue
            if r["status"] == "skipped":
                if mesh == [16, 16] and "pod16x16" in r["cell"]:
                    print(f"| {r['cell']} | SKIP: {r['reason'][:60]} | | | | | | |")
                continue
            c = r["collectives"]
            print(
                f"| {r['cell']} | ok | {r['flops_per_device_corrected']:.2e} | "
                f"{r['bytes_per_device_corrected']:.2e} | "
                f"{fmt_bytes(r['memory_analysis'].get('temp_size_in_bytes',0))} | "
                f"{r['collective_bytes_corrected']/2**20:.0f} | "
                f"{c['all-gather']/2**20:.0f}/{c['all-reduce']/2**20:.0f}/"
                f"{c['all-to-all']/2**20:.0f} | {r['compile_s']:.0f} |"
            )
        print()

    print("## Roofline terms (single pod, v5e constants)\n")
    print("| cell | compute s | memory s | collective s | dominant | "
          "MODEL/HLO | frac | temp GiB |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        row = analyze(r)
        if row and row["mesh"] == "16x16":
            print(
                f"| {row['cell']} | {row['t_compute_s']:.3e} | "
                f"{row['t_memory_s']:.3e} | {row['t_collective_s']:.3e} | "
                f"{row['dominant']} | {row['useful_ratio']:.2f} | "
                f"{row['roofline_fraction']:.3f} | {row['mem_gib']:.1f} |"
            )


if __name__ == "__main__":
    os.chdir(os.path.join(os.path.dirname(__file__), ".."))
    main()
