"""End-to-end behaviour: training converges, resume-after-preemption works,
serving generates, grad accumulation is exact, straggler watch flags."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.data import DataConfig
from repro.distributed.fault import PreemptionGuard, StragglerWatch
from repro.models import Model
from repro.optim import AdamWConfig
from repro.serve import ServeEngine
from repro.train import TrainLoopConfig, train_loop


@pytest.fixture(scope="module")
def trained():
    cfg = get_reduced("qwen2.5-32b")
    model = Model(cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0)
    lcfg = TrainLoopConfig(steps=120, warmup=10, log_every=1000, ckpt_every=10**6)
    params, hist = train_loop(
        model, dcfg, lcfg, AdamWConfig(lr=3e-3, grad_clip=5.0), log=lambda *_: None
    )
    return cfg, model, params, hist


def test_training_converges(trained):
    _, _, _, hist = trained
    first = np.mean(hist[:10])
    last = np.mean(hist[-10:])
    assert last < first - 0.3, (first, last)


def test_resume_from_checkpoint(tmp_path):
    cfg = get_reduced("mamba2-130m")
    model = Model(cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=1)
    l1 = TrainLoopConfig(steps=8, warmup=2, ckpt_every=4, ckpt_dir=str(tmp_path),
                         log_every=1000, async_ckpt=False)
    train_loop(model, dcfg, l1, AdamWConfig(), log=lambda *_: None)
    # resume: loop must start from step 8 and run only 4 more
    l2 = TrainLoopConfig(steps=12, warmup=2, ckpt_every=100, ckpt_dir=str(tmp_path),
                         log_every=1000)
    _, hist = train_loop(model, dcfg, l2, AdamWConfig(), log=lambda *_: None)
    assert len(hist) == 4


def test_grad_accum_matches_full_batch():
    cfg = dataclasses.replace(get_reduced("starcoder2-3b"), dtype="float32")
    model = Model(cfg)
    from repro.train.step import init_state, make_train_step

    opt = AdamWConfig(lr=1e-3)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)}
    s1, _ = make_train_step(model, opt, donate=False)
    s2, _ = make_train_step(model, opt, grad_accum=4, donate=False)
    p, o = init_state(model, opt, jax.random.PRNGKey(0))
    p1, _, m1 = s1(p, o, batch)
    p2, _, m2 = s2(p, o, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-3, atol=1e-5
        )


def test_serving_generates(trained):
    cfg, model, params, _ = trained
    eng = ServeEngine(model, params, cache_len=96, batch_size=4)
    batch = {
        "tokens": jnp.asarray(
            np.random.default_rng(3).integers(0, cfg.vocab, (4, 16)), jnp.int32
        )
    }
    toks = eng.generate(batch, 12)
    assert toks.shape == (4, 12)
    assert int(toks.min()) >= 0 and int(toks.max()) < cfg.vocab
    # temperature sampling path
    toks2 = eng.generate(batch, 4, temperature=1.0, key=jax.random.PRNGKey(0))
    assert toks2.shape == (4, 4)


def test_preemption_guard_flag():
    with PreemptionGuard() as g:
        assert not g.should_stop
        g._handler(None, None)
        assert g.should_stop


def test_straggler_watch():
    import time

    w = StragglerWatch(threshold=5.0)
    for s in range(3):
        w.step_begin()
        time.sleep(0.01)
        w.step_end(s)
    w.step_begin()
    time.sleep(0.2)
    assert w.step_end(3) is True
    assert w.flagged and w.flagged[0][0] == 3


def test_deterministic_data_sharding():
    from repro.data import SyntheticLM

    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=5)
    full = SyntheticLM(cfg).batch(7)["tokens"]
    shards = [SyntheticLM(cfg, num_shards=4, shard=i).batch(7)["tokens"] for i in range(4)]
    # each shard is deterministic and reproducible
    again = SyntheticLM(cfg, num_shards=4, shard=2).batch(7)["tokens"]
    np.testing.assert_array_equal(shards[2], again)
    assert full.shape == (8, 16) and shards[0].shape == (2, 16)
