"""Distribution: sharding-rule resolution (unit) + multi-device behaviours
(subprocess with xla_force_host_platform_device_count=8): compressed
gradient psum, elastic resharding, sharded train-step parity."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced
from repro.distributed.sharding import (
    DEFAULT_RULES,
    optimizer_spec,
    pspec_for_axes,
    tree_pspecs,
)
from repro.models import Model
from repro.models.params import _map_like


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_pspec_resolution_rules():
    mesh = _mesh11()
    assert pspec_for_axes(("vocab", "embed"), DEFAULT_RULES, mesh) == P("model", None)
    # size-aware: indivisible dims drop to replicated
    assert pspec_for_axes(("experts",), DEFAULT_RULES, mesh, (40,)) == P("model")
    mesh16 = jax.make_mesh((1,), ("model",))
    # left-to-right precedence: one mesh axis used once
    spec = pspec_for_axes(("experts", "embed", "ff"), DEFAULT_RULES, mesh16)
    assert spec == P("model", None, None)


def test_optimizer_spec_zero1():
    mesh = _mesh11()
    spec = optimizer_spec(P(None, "model"), (64, 128), mesh)
    assert spec == P("data", "model")
    # indivisible first dim falls through to the next free axis (abstract
    # 2-way data mesh: only .shape is consulted)
    from jax.sharding import AbstractMesh

    amesh = AbstractMesh((("data", 2), ("model", 1)))
    spec2 = optimizer_spec(P(None, None), (3, 64), amesh)
    assert spec2 == P(None, "data")


def test_tree_pspecs_cover_all_archs():
    mesh = _mesh11()
    for arch in ("qwen2.5-32b", "deepseek-moe-16b", "mamba2-130m", "recurrentgemma-2b"):
        model = Model(get_reduced(arch))
        specs = tree_pspecs(model.abstract_params(), DEFAULT_RULES, mesh)
        flat = jax.tree.leaves(
            _map_like(specs, lambda _, s: 1) if False else specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        assert len(flat) > 0


_SUBPROCESS_COMMON = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    # pin the platform: jax's backend discovery in the stripped subprocess
    # env takes minutes without it (this box is CPU-only anyway)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import repro
    """
)


def _run_sub(body: str, devices: int = 8):
    code = _SUBPROCESS_COMMON.format(devices=devices) + textwrap.dedent(body)
    res = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=420,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd="/root/repo",
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


import pytest

# The 8-host-device subprocess tests compile full (reduced) models under
# SPMD and need several minutes of CPU each — slow-profile only (pytest.ini
# deselects `slow` by default; CI's slow job runs them).


@pytest.mark.slow
def test_compressed_psum_subprocess():
    out = _run_sub(
        """
        from functools import partial
        from repro.distributed.compression import error_feedback_psum
        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)), jnp.float32)
        err0 = jnp.zeros((8, 64), jnp.float32)

        @partial(jax.shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                 out_specs=(P("data"), P("data")))
        def red(g, e):
            m, ne = error_feedback_psum(g[0], e[0], "data")
            return m[None], ne[None]

        mean, err = red(x, err0)
        true_mean = jnp.mean(x, axis=0)
        q_err = float(jnp.max(jnp.abs(mean[0] - true_mean)))
        assert q_err < 0.05, q_err                     # int8-level accuracy
        # error feedback: the residual equals what quantization dropped
        total_err = np.asarray(err).sum(0)
        # second round with zero new gradient recovers the dropped mass
        mean2, _ = red(jnp.zeros_like(x), err)
        recovered = mean[0] + mean2[0]
        q2 = float(jnp.max(jnp.abs(recovered - true_mean)))
        assert q2 < q_err + 1e-6
        print("OK", q_err)
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = _run_sub(
        """
        import dataclasses
        from repro.configs import get_reduced
        from repro.models import Model
        from repro.optim import AdamWConfig
        from repro.train.step import make_train_step, init_state
        cfg = dataclasses.replace(get_reduced("qwen2.5-32b"), dtype="float32", remat=False)
        model = Model(cfg)
        opt_cfg = AdamWConfig(lr=1e-3)
        rngs = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rngs.integers(0, cfg.vocab, (8, 32)), jnp.int32)}
        # single-device reference
        step1, _ = make_train_step(model, opt_cfg, donate=False)
        p1, o1 = init_state(model, opt_cfg, jax.random.PRNGKey(0))
        p1n, o1n, m1 = step1(p1, o1, batch)
        # 4x2 mesh (DPxTP)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        step2, sh = make_train_step(model, opt_cfg, mesh=mesh, donate=False)
        p2, o2 = init_state(model, opt_cfg, jax.random.PRNGKey(0), sh)
        p2n, o2n, m2 = step2(p2, o2, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (m1["loss"], m2["loss"])
        for a, b in zip(jax.tree.leaves(p1n), jax.tree.leaves(p2n)):
            # f32 reduction-order noise across shardings gets amplified by
            # Adam's rsqrt for near-zero second moments on isolated elements:
            # demand tight agreement for 99.99% of elements and a small
            # absolute bound on the stragglers.
            # (XLA CPU reduction tiling varies with host threading, so the
            # tail is load-dependent: gate the bulk + a loose abs cap.)
            d = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))
            scale = np.maximum(np.abs(np.asarray(a, np.float32)), 1e-3)
            rel = d / scale
            assert float(np.quantile(rel, 0.999)) < 1e-2, float(rel.max())
            assert float(d.max()) < 2e-2, float(d.max())
        print("OK", float(m1["loss"]))
        """
    )
    assert "OK" in out


@pytest.mark.slow
def test_elastic_reshard_subprocess(tmp_path):
    out = _run_sub(
        f"""
        import dataclasses
        from repro.configs import get_reduced
        from repro.models import Model
        from repro.checkpoint import Checkpointer
        from repro.distributed.elastic import elastic_restore
        cfg = dataclasses.replace(get_reduced("starcoder2-3b"), dtype="float32")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        ck = Checkpointer(r"{tmp_path}")
        ck.save(42, params)
        # 'failure': continue on a smaller mesh (8 -> 4 devices)
        devs = jax.devices()[:4]
        import jax.sharding as jsh
        new_mesh = jsh.Mesh(np.asarray(devs).reshape(2, 2), ("data", "model"))
        step, params2 = elastic_restore(r"{tmp_path}", model.abstract_params(), new_mesh)
        assert step == 42
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored params live on the new mesh
        leaf = jax.tree.leaves(params2)[0]
        assert set(leaf.sharding.mesh.devices.flat) <= set(devs)
        print("OK")
        """
    )
    assert "OK" in out


def test_emulated_train_step_2device_mesh():
    """Regression (ROADMAP, found in PR 4): `launch.train --backend
    ozaki2_* --mesh 2x1` died in XLA SPMD partitioning ("compare s64[] vs
    s32[]") for every emulated execution — under jax_enable_x64 the layer
    scan's internal counter is int64, and the partitioner rejects s64
    dynamic_update_slice indices on the sharded layer stack when it
    transposes the remat scan.  `Model._run_group` now threads an explicit
    int32 carry index and gathers the stacked layer params in the body, so
    an emulated remat train step must compile and take a finite step on a
    real (forced-host) 2-device mesh.

    Not slow-marked: a deliberately tiny config keeps the subprocess under
    ~1 min — this is the only tier-1 coverage of emulated training on a
    multi-device mesh.
    """
    out = _run_sub(
        """
        from repro.core.policy import GemmPolicy
        from repro.models import Model
        from repro.models.config import ModelConfig
        from repro.train.step import make_train_step, init_state
        from repro.optim import AdamWConfig

        mesh = jax.make_mesh((2, 1), ("data", "model"))
        cfg = ModelConfig(
            name="tiny", n_layers=2, d_model=32, vocab=64, n_heads=2,
            n_kv_heads=2, head_dim=16, d_ff=64, dtype="float32", remat=True,
            gemm_policy=GemmPolicy(
                backend="ozaki2_f32", n_moduli=4, execution="reference"
            ),
        )
        model = Model(cfg)
        step, sh = make_train_step(model, AdamWConfig(), mesh=mesh, donate=False)
        params, opt = init_state(
            model, AdamWConfig(), jax.random.PRNGKey(0), sh
        )
        batch = jax.device_put(
            {"tokens": jnp.asarray(
                np.random.default_rng(0).integers(0, cfg.vocab, (4, 16)),
                jnp.int32,
            )},
            sh["batch"],
        )
        _, _, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), loss
        print("OK", loss)
        """,
        devices=2,
    )
    assert "OK" in out


def test_ssd_train_step_2device_mesh_and_index_widths():
    """Regression (found by `repro.analysis.ScanIndexWidthPass`, PR 7): the
    SSD block's chunk-boundary gathers used negative *integer* indexing
    (`acs[:, :, -1, :]`, `h[:, -1]`), which lowers to a dynamic_slice whose
    normalized index scalars are s64 under jax_enable_x64 — inside the remat
    layer scan, i.e. exactly the s64-index-in-scan-body shape the SPMD
    partitioner chokes on (the PR 4 bug class the two tests above pin for
    the layer scan and chunked CE).  `blocks.ssd_scan` / `rglru_prefill`
    now slice-then-squeeze (a static lax.slice).  Certify the traced train
    step index-width-clean AND take a finite emulated step on a real
    (forced-host) 2-device mesh.

    Not slow-marked: the reduced mamba2 config is tiny and this is the only
    tier-1 coverage of an SSD/recurrent block under SPMD.
    """
    out = _run_sub(
        """
        import dataclasses
        from repro.analysis import ScanIndexWidthPass
        from repro.configs import get_reduced
        from repro.core.policy import GemmPolicy
        from repro.models import Model
        from repro.train.step import make_train_step, init_state
        from repro.optim import AdamWConfig

        mesh = jax.make_mesh((2, 1), ("data", "model"))
        cfg = dataclasses.replace(
            get_reduced("mamba2-130m"), dtype="float32", remat=True,
            gemm_policy=GemmPolicy(
                backend="ozaki2_f32", n_moduli=4, execution="reference"
            ),
        )
        model = Model(cfg)
        step, sh = make_train_step(model, AdamWConfig(), mesh=mesh, donate=False)
        params, opt = init_state(
            model, AdamWConfig(), jax.random.PRNGKey(0), sh
        )
        batch = jax.device_put(
            {"tokens": jnp.asarray(
                np.random.default_rng(0).integers(0, cfg.vocab, (4, 16)),
                jnp.int32,
            )},
            sh["batch"],
        )
        findings = ScanIndexWidthPass().run(
            jax.make_jaxpr(step)(params, opt, batch)
        )
        assert findings == [], [str(f) for f in findings]
        _, _, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), loss
        print("OK", loss)
        """,
        devices=2,
    )
    assert "OK" in out


def test_chunked_ce_train_step_2device_mesh():
    """Regression: `loss_vocab_chunk` on a multi-device mesh died the same
    s64-vs-s32 SPMD death as the layer scan (PR 4) — `Model._chunked_ce`
    scanned *over* the vocab-slab stack as scan xs, so under jax_enable_x64
    the scan indexed the stack with an s64 counter that the partitioner
    rejects when it transposes the remat scan.  The body now gathers the
    slab with an explicit int32 carry index (xs=None), so a chunked-CE
    emulated train step must compile and take a finite step on a real
    (forced-host) 2-device mesh.  The mesh puts both devices on the
    *model* axis — the crash needs the head weights (and so the slab
    stack) actually sharded; a data-only mesh compiles even unfixed.

    Not slow-marked: the tiny config keeps the subprocess fast, and this is
    the only tier-1 coverage of the chunked-CE loss under SPMD.
    """
    out = _run_sub(
        """
        from repro.core.policy import GemmPolicy
        from repro.models import Model
        from repro.models.config import ModelConfig
        from repro.train.step import make_train_step, init_state
        from repro.optim import AdamWConfig

        mesh = jax.make_mesh((1, 2), ("data", "model"))
        cfg = ModelConfig(
            name="tiny", n_layers=2, d_model=32, vocab=64, n_heads=2,
            n_kv_heads=2, head_dim=16, d_ff=64, dtype="float32", remat=True,
            loss_vocab_chunk=16,
            gemm_policy=GemmPolicy(
                backend="ozaki2_f32", n_moduli=4, execution="reference"
            ),
        )
        model = Model(cfg)
        step, sh = make_train_step(model, AdamWConfig(), mesh=mesh, donate=False)
        params, opt = init_state(
            model, AdamWConfig(), jax.random.PRNGKey(0), sh
        )
        batch = jax.device_put(
            {"tokens": jnp.asarray(
                np.random.default_rng(0).integers(0, cfg.vocab, (4, 16)),
                jnp.int32,
            )},
            sh["batch"],
        )
        _, _, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), loss
        print("OK", loss)
        """,
        devices=2,
    )
    assert "OK" in out
