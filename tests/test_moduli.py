"""CRT constant construction: coprimality, exact splits, Garner tables."""
import math

import numpy as np
import pytest

from repro.core.moduli import (
    MAX_MODULI,
    default_moduli,
    make_crt_context,
    min_moduli_for_bits,
)


@pytest.mark.parametrize("n", [1, 2, 7, 13, 16, 20])
def test_moduli_pairwise_coprime_odd(n):
    p = default_moduli(n)
    assert len(p) == n
    for i in range(n):
        assert p[i] % 2 == 1 and 3 <= p[i] <= 255
        for j in range(i + 1, n):
            assert math.gcd(p[i], p[j]) == 1


@pytest.mark.parametrize("n", [2, 8, 13, 16, 20])
def test_context_invariants(n):
    ctx = make_crt_context(n)
    P = 1
    for pl in ctx.moduli:
        P *= pl
    assert ctx.P == P
    assert abs(ctx.log2_P - math.log2(float(P))) < 1e-6 or ctx.log2_P > 900
    # P expansion is exact
    assert sum(int(x) for x in ctx.P_exp) == P
    # w splits at a fixed absolute position: every w_hi is a multiple of
    # 2^cutpos and w - w_hi < 2^cutpos (+ f64 rounding in the low part)
    import math as _math

    hi_bits = 53 - 7 - max(1, _math.ceil(_math.log2(max(n, 2))))
    ws = []
    for pl in ctx.moduli:
        M = P // pl
        q = pow(M % pl, -1, pl)
        ws.append(M * q)
    cutpos = max(w.bit_length() for w in ws) - hi_bits
    for l, (w, pl) in enumerate(zip(ws, ctx.moduli)):
        hi = int(ctx.w_hi[l])
        assert hi % (1 << max(cutpos, 0)) == 0
        assert 0 <= w - hi < (1 << max(cutpos, 1))
        assert abs((w - hi) - ctx.w_lo[l]) <= 2.0 ** max(cutpos - 50, 0)
        # CRT property: w_l == 1 mod p_l, == 0 mod p_j (j != l)
        assert w % pl == 1
        for j, pj in enumerate(ctx.moduli):
            if j != l:
                assert w % pj == 0


def test_garner_tables():
    ctx = make_crt_context(9)
    for t in range(ctx.n):
        for s in range(t):
            inv = int(ctx.garner_inv[s, t])
            assert (inv * ctx.moduli[s]) % ctx.moduli[t] == 1


def test_min_moduli_for_bits():
    n = min_moduli_for_bits(100.0)
    assert make_crt_context(n).log2_P > 100.0
    assert make_crt_context(n - 1).log2_P <= 100.0


def test_max_moduli_bound():
    with pytest.raises(ValueError):
        default_moduli(MAX_MODULI + 1)
