"""End-to-end Ozaki-II emulation accuracy + exactness of the CRT pipeline.

The key validations of the paper's claims (SIV-A):
  * the emulated product of the *quantized* matrices is EXACT (checked
    against arbitrary-precision Python integers),
  * the uniqueness condition (4) holds under both scaling modes,
  * accuracy bands: CGEMM-level at N~7, ZGEMM-level at N~13-14, and the
    complex Karatsuba formulation needs one modulus fewer than real DGEMM.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import phi_matrix
from repro.core import make_crt_context, ozaki2_cgemm, ozaki2_gemm
from repro.core import scaling
from repro.core.gemm import _n_limbs
from repro.core.residues import quantize, residues_from_quantized

M, K, N = 48, 192, 40


def _ref(a, b):
    return a.astype(np.clongdouble if np.iscomplexobj(a) else np.longdouble) @ b.astype(
        np.clongdouble if np.iscomplexobj(b) else np.longdouble
    )


def _maxrel(c, ref):
    denom = np.maximum(np.abs(ref), 1e-300)
    if np.iscomplexobj(ref):
        return float(
            max(
                np.max(np.abs(np.real(c) - np.real(ref)) / np.maximum(np.abs(np.real(ref)), 1e-300)),
                np.max(np.abs(np.imag(c) - np.imag(ref)) / np.maximum(np.abs(np.imag(ref)), 1e-300)),
            )
        )
    return float(np.max(np.abs(c - ref) / denom))


@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("method", ["paper", "dd", "garner"])
def test_f64_accuracy(rng, mode, method):
    a = phi_matrix(rng, (M, K), 1.0, np.float64)
    b = phi_matrix(rng, (K, N), 1.0, np.float64)
    c = np.asarray(ozaki2_gemm(jnp.asarray(a), jnp.asarray(b), 16, mode, method))
    assert _maxrel(c, _ref(a, b)) < 1e-13


@pytest.mark.parametrize("mode", ["fast", "accu"])
def test_f32_accuracy(rng, mode):
    a = phi_matrix(rng, (M, K), 0.5, np.float32)
    b = phi_matrix(rng, (K, N), 0.5, np.float32)
    c = np.asarray(ozaki2_gemm(jnp.asarray(a), jnp.asarray(b), 8, mode))
    assert _maxrel(c, _ref(a, b)) < 2e-4


def test_quantized_product_is_exact(rng):
    """C' from the CRT pipeline == A'B' computed in exact Python ints."""
    ctx = make_crt_context(10)
    a = phi_matrix(rng, (8, 32), 1.0, np.float64)
    b = phi_matrix(rng, (32, 6), 1.0, np.float64)
    e_mu, e_nu = scaling.scale_fast_real(jnp.asarray(a), jnp.asarray(b), ctx)
    aq = np.asarray(quantize(jnp.asarray(a), scaling.exp2_vector(e_mu), 0))
    bq = np.asarray(quantize(jnp.asarray(b), scaling.exp2_vector(e_nu), 1))
    ai = aq.astype(object).astype(int) if False else np.vectorize(int, otypes=[object])(aq)
    bi = np.vectorize(int, otypes=[object])(bq)
    exact = ai @ bi  # arbitrary-precision integer matmul
    # uniqueness condition (4): 2 * sum |a'||b'| < P
    bound = np.vectorize(abs, otypes=[object])(ai) @ np.vectorize(abs, otypes=[object])(bi)
    assert all(2 * int(v) < ctx.P for v in bound.ravel())
    # emulated C should equal exact / (mu nu) to f64 rounding
    c = np.asarray(
        ozaki2_gemm(jnp.asarray(a), jnp.asarray(b), 10, "fast", "garner")
    )
    mu = np.ldexp(1.0, np.asarray(e_mu))
    nu = np.ldexp(1.0, np.asarray(e_nu))
    expect = np.array(
        [[float(exact[i, j]) / (mu[i] * nu[j]) for j in range(6)] for i in range(8)]
    )
    np.testing.assert_allclose(c, expect, rtol=1e-15, atol=0)


def test_condition4_accurate_mode_extreme_range(rng):
    """Accurate mode must maintain (4) even at wide dynamic range (phi=4)."""
    ctx = make_crt_context(14)
    a = phi_matrix(rng, (M, K), 4.0, np.float64)
    b = phi_matrix(rng, (K, N), 4.0, np.float64)
    e_mu, e_nu = scaling.scale_accurate_real(jnp.asarray(a), jnp.asarray(b), ctx)
    aq = np.asarray(quantize(jnp.asarray(a), scaling.exp2_vector(e_mu), 0))
    bq = np.asarray(quantize(jnp.asarray(b), scaling.exp2_vector(e_nu), 1))
    ai = np.vectorize(int, otypes=[object])(np.abs(aq))
    bi = np.vectorize(int, otypes=[object])(np.abs(bq))
    bound = ai @ bi
    assert all(2 * int(v) < ctx.P for v in bound.ravel())


def _medrel(c, ref):
    r = np.maximum(
        np.abs(np.real(c) - np.real(ref))
        / np.maximum(np.abs(np.real(ref)), 1e-300),
        np.abs(np.imag(c) - np.imag(ref))
        / np.maximum(np.abs(np.imag(ref)), 1e-300),
    )
    return float(np.median(r))


@pytest.mark.parametrize("phi", [0.5, 1.0, 2.0])
def test_zgemm_band(rng, phi):
    """Paper Fig. 5: ZGEMM-level accuracy from N=13-14 (complex).

    Uses the median relative error: the max-rel metric is dominated by
    near-cancelling output entries at these small test sizes."""
    a = phi_matrix(rng, (M, K), phi, np.complex128)
    b = phi_matrix(rng, (K, N), phi, np.complex128)
    ref = _ref(a, b)
    native_max = _maxrel(np.asarray(a @ b), ref)
    emul_med = _medrel(
        np.asarray(ozaki2_cgemm(jnp.asarray(a), jnp.asarray(b), 14, "accu")), ref
    )
    assert emul_med < max(native_max, 1e-13)


def test_karatsuba_no_accuracy_penalty(rng):
    """Residue-ring Karatsuba is exact modular arithmetic, so the complex
    emulation at N moduli stays within the real-DGEMM band at the same N
    (this is why ZGEMM needs 13 moduli where real DGEMM needs 14)."""
    for n_mod in (13, 14):
        a = phi_matrix(rng, (M, K), 1.0, np.complex128)
        b = phi_matrix(rng, (K, N), 1.0, np.complex128)
        ar = phi_matrix(rng, (M, K), 1.0, np.float64)
        br = phi_matrix(rng, (K, N), 1.0, np.float64)
        err_c = _maxrel(
            np.asarray(ozaki2_cgemm(jnp.asarray(a), jnp.asarray(b), n_mod, "fast")),
            _ref(a, b),
        )
        err_r = _maxrel(
            np.asarray(ozaki2_gemm(jnp.asarray(ar), jnp.asarray(br), n_mod, "fast")),
            _ref(ar, br),
        )
        assert err_c < err_r * 50  # same band (modulo instance noise)


def test_complex_formulations_agree_exactly(rng):
    """(7), (8) and Karatsuba compute identical residues => identical C."""
    a = phi_matrix(rng, (M, K), 1.0, np.complex64)
    b = phi_matrix(rng, (K, N), 1.0, np.complex64)
    outs = [
        np.asarray(ozaki2_cgemm(jnp.asarray(a), jnp.asarray(b), 7, "fast", formulation=f))
        for f in ("karatsuba", "block_a", "block_b")
    ]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_n_blocking_is_exact(rng):
    a = phi_matrix(rng, (M, K), 1.0, np.complex64)
    b = phi_matrix(rng, (K, N), 1.0, np.complex64)
    full = np.asarray(ozaki2_cgemm(jnp.asarray(a), jnp.asarray(b), 7))
    blocked = np.asarray(ozaki2_cgemm(jnp.asarray(a), jnp.asarray(b), 7, n_block=16))
    np.testing.assert_array_equal(full, blocked)


def test_batched_gemm(rng):
    a = phi_matrix(rng, (3, 16, 32), 0.5, np.float32)
    b = phi_matrix(rng, (3, 32, 8), 0.5, np.float32)
    c = np.asarray(ozaki2_gemm(jnp.asarray(a), jnp.asarray(b), 8))
    ref = np.einsum("bij,bjk->bik", a.astype(np.float64), b.astype(np.float64))
    np.testing.assert_allclose(c, ref, rtol=2e-4, atol=1e-6)


def test_bitwise_reproducible(rng):
    a = phi_matrix(rng, (M, K), 1.0, np.float64)
    b = phi_matrix(rng, (K, N), 1.0, np.float64)
    c1 = np.asarray(ozaki2_gemm(jnp.asarray(a), jnp.asarray(b), 13))
    c2 = np.asarray(ozaki2_gemm(jnp.asarray(a), jnp.asarray(b), 13))
    np.testing.assert_array_equal(c1, c2)


def test_ozaki1_baseline(rng):
    """The paper's comparison baseline (SIV 'OS I-S'), reimplemented: S=9
    slices reach DGEMM-level accuracy at S(S+1)/2 = 45 int8 GEMMs where
    Ozaki-II needs 14-16 — the quadratic-vs-linear gap behind Figs. 10/12."""
    from repro.core.ozaki1 import int8_gemm_count, ozaki1_cgemm, ozaki1_gemm

    a = phi_matrix(rng, (M, K), 1.0, np.float64)
    b = phi_matrix(rng, (K, N), 1.0, np.float64)
    err9 = _maxrel(np.asarray(ozaki1_gemm(jnp.asarray(a), jnp.asarray(b), 9)), _ref(a, b))
    err5 = _maxrel(np.asarray(ozaki1_gemm(jnp.asarray(a), jnp.asarray(b), 5)), _ref(a, b))
    assert err9 < 1e-11 and err5 > err9 * 100  # accuracy scales with slices
    assert int8_gemm_count(9) == 45
    az = phi_matrix(rng, (M, K), 1.0, np.complex128)
    bz = phi_matrix(rng, (K, N), 1.0, np.complex128)
    errz = _maxrel(np.asarray(ozaki1_cgemm(jnp.asarray(az), jnp.asarray(bz), 9)), _ref(az, bz))
    assert errz < 1e-11


def test_prepared_operand_matches_direct(rng):
    """Beyond-paper: pre-residue-cast A amortizes step 1 across calls and
    is bit-compatible with the direct fast-mode pipeline."""
    from repro.core import PreparedOperand, gemm_prepared

    a = phi_matrix(rng, (M, K), 1.0, np.float64)
    prep = PreparedOperand(jnp.asarray(a), 14)
    for seed in range(3):
        b = phi_matrix(np.random.default_rng(seed), (K, N), 1.0, np.float64)
        c1 = np.asarray(gemm_prepared(prep, jnp.asarray(b)))
        c2 = np.asarray(ozaki2_gemm(jnp.asarray(a), jnp.asarray(b), 14, "fast"))
        np.testing.assert_array_equal(c1, c2)


def test_zero_and_degenerate_inputs():
    a = jnp.zeros((4, 8), jnp.float64)
    b = jnp.ones((8, 3), jnp.float64)
    c = np.asarray(ozaki2_gemm(a, b, 8))
    np.testing.assert_array_equal(c, 0.0)
    # single row/col degenerate values
    a2 = jnp.asarray(np.array([[1e300, 1e-300]] * 2))
    b2 = jnp.asarray(np.array([[1.0], [1.0]]))
    c2 = np.asarray(ozaki2_gemm(a2, b2, 12))
    assert np.isfinite(c2).all()
