"""The execution-policy redesign: `repro.linalg` + `use_policy` + shims.

What this file guarantees:

  * `policy_matmul` / `linalg.matmul` with ``execution="kernel"`` runs the
    modulus-batched Pallas pipeline (asserted by the traced `pallas_call`
    count, including the 3-launch prepared-weight path) and is
    - bitwise-identical to ``execution="per_modulus_kernel"`` for every
      dtype x mode x prepared combination (kernel-path parity), and
    - bitwise-identical to ``execution="reference"`` for the f32-grade
      dtypes (f32/c64): the kernel path casts through f32 and reconstructs
      in double-single, which the f32 output rounding absorbs exactly; the
      f64-grade dtypes agree to the kernel path's f32-grade band instead.
  * `use_policy` scoping: thread-local, nestable, captured at config
    construction (ModelConfig) and at trace time (linalg.matmul).
  * the four legacy `ozaki2_*` entry points warn `DeprecationWarning` and
    still agree bitwise with `linalg.matmul` under the equivalent policy.
  * `prepare_weights` rewrites "w" leaves reached through list/tuple
    bundles (scanned layer groups) and casts with the policy's execution
    backend, so prepared serving is bit-identical on the kernel path;
    `ServeEngine(prepare=True, prepared_dir=...)` restores the persisted
    residue planes bitwise instead of re-preparing.
"""
import dataclasses
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import FAST_K, FAST_M, FAST_N, phi_matrix
import repro
from repro import linalg
from repro.core import GemmPolicy, PreparedOperand, perfmodel
from repro.core.policy import BACKEND_FOR_DTYPE, policy_matmul, prepare_weights
from repro.kernels.common import count_pallas_launches

M, K, N = FAST_M, FAST_K, FAST_N

DTYPES = [np.float32, np.float64, np.complex64, np.complex128]
# small moduli counts keep the interpret-mode sweeps fast; parity is
# independent of N
N_MODULI = {"float32": 5, "float64": 6, "complex64": 5, "complex128": 6}
F32_GRADE = ("float32", "complex64")


def _policy(dtype, execution, **kw):
    name = np.dtype(dtype).name
    kw.setdefault("n_moduli", N_MODULI[name])
    kw.setdefault("interpret", True)
    return GemmPolicy(backend=BACKEND_FOR_DTYPE[name], execution=execution, **kw)


def _operands(rng, dtype):
    x = jnp.asarray(phi_matrix(rng, (M, K), 0.5, dtype))
    w = jnp.asarray(phi_matrix(rng, (K, N), 0.5, dtype))
    return x, w


# ===================================================== execution parity


@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_policy_execution_parity(rng, dtype, mode):
    """Tentpole: the execution axis selects the backend without changing the
    numbers — batched kernels == per-modulus kernels bitwise everywhere, and
    == the jnp reference bitwise at f32 grade."""
    x, w = _operands(rng, dtype)
    ys = {
        ex: np.asarray(policy_matmul(x, w, _policy(dtype, ex, mode=mode)))
        for ex in ("reference", "kernel", "per_modulus_kernel")
    }
    np.testing.assert_array_equal(ys["kernel"], ys["per_modulus_kernel"])
    name = np.dtype(dtype).name
    if name in F32_GRADE:
        np.testing.assert_array_equal(ys["kernel"], ys["reference"])
    else:
        # the kernel path quantizes through f32, so f64-grade operands agree
        # with the f64 reference only to the f32-grade band
        scale = np.max(np.abs(ys["reference"]))
        assert np.max(np.abs(ys["kernel"] - ys["reference"])) / scale < 1e-6


@pytest.mark.parametrize("execution", ["reference", "kernel"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_policy_prepared_parity(rng, dtype, execution):
    """`prepare_weights` casts with the *selected* execution backend, so the
    prepared fast path is bit-identical to the unprepared run per execution
    (kernel path included — its f32 cast must be baked into the residues)."""
    x, w = _operands(rng, dtype)
    pol = _policy(dtype, execution)
    direct = np.asarray(policy_matmul(x, w, pol))
    tree = prepare_weights({"w": w}, pol)
    assert isinstance(tree["w"], PreparedOperand)
    prepped = np.asarray(policy_matmul(x, tree["w"], pol))
    np.testing.assert_array_equal(direct, prepped)


@pytest.mark.parametrize("execution", ["reference", "kernel"])
@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_policy_prepared_accu_parity(rng, dtype, execution):
    """ROADMAP follow-up from PR 3: accu-mode preparation stores the
    per-column 7-bit bound alongside the residue planes (and the raw
    operand — the coupled exponents force a per-call cast) and stays
    bitwise identical to the unprepared accu run on both backends."""
    x, w = _operands(rng, dtype)
    pol = _policy(dtype, execution, mode="accu", n_moduli=6)
    direct = np.asarray(policy_matmul(x, w, pol))
    tree = prepare_weights({"w": w}, pol)
    prep = tree["w"]
    assert isinstance(prep, PreparedOperand)
    assert prep.raw is not None and prep.bound[0].dtype == jnp.int8
    prepped = np.asarray(policy_matmul(x, prep, pol))
    np.testing.assert_array_equal(direct, prepped)


def test_policy_prepared_accu_requires_raw(rng):
    """A fast-prepared operand (no raw retained) used under an accu policy
    fails loudly with re-preparation guidance, never silently degrades."""
    x, w = _operands(rng, np.float32)
    fast_pol = _policy(np.float32, "kernel", n_moduli=6)
    prep = prepare_weights({"w": w}, fast_pol)["w"]
    assert prep.raw is None  # fast preparation keeps the memory win
    accu_pol = _policy(np.float32, "kernel", mode="accu", n_moduli=6)
    with pytest.raises(ValueError, match="raw operand"):
        policy_matmul(x, prep, accu_pol)


def test_policy_prepared_auto_formulation_parity(rng):
    """Regression: gemm_prepared must charge the perfmodel the executing
    backend's real launch capabilities, or formulation='auto' can pick a
    different Fig. 1 strategy for the prepared run than the unprepared run
    it must bit-match (e.g. block_a vs karatsuba on the batched kernels)."""
    x = jnp.asarray(phi_matrix(rng, (64, 64), 0.5, np.complex64))
    w = jnp.asarray(phi_matrix(rng, (64, 64), 0.5, np.complex64))
    for execution in ("reference", "kernel"):
        pol = _policy(np.complex64, execution, formulation="auto")
        direct = np.asarray(policy_matmul(x, w, pol))
        prep = prepare_weights({"w": w}, pol)["w"]
        prepped = np.asarray(policy_matmul(x, prep, pol))
        np.testing.assert_array_equal(direct, prepped)


def test_policy_out_dtype_axis(rng):
    """out_dtype is a policy axis: f64-grade output from f32 operands."""
    x, w = _operands(rng, np.float32)
    pol = _policy(np.float32, "reference", n_moduli=8, out_dtype="float64")
    y = policy_matmul(x, w, pol)
    assert y.dtype == jnp.float64
    ref = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
    assert np.max(np.abs(np.asarray(y) - ref)) / np.max(np.abs(ref)) < 1e-7


# ===================================================== launch counting


def test_policy_kernel_launch_counts(rng):
    """Acceptance: the policy path really runs the batched Pallas pipeline —
    4 launches per GEMM (cast, cast, product, reconstruct) at any N, 3 with
    a prepared weight, 3+N on the per-modulus parity path."""
    x, w = _operands(rng, np.float32)
    pol = _policy(np.float32, "kernel")
    got = count_pallas_launches(lambda a, b: policy_matmul(a, b, pol), x, w)
    assert got == perfmodel.kernel_launch_count(pol.n_moduli, "real") == 4

    prep = prepare_weights({"w": w}, pol)["w"]
    got_prep = count_pallas_launches(
        lambda a: policy_matmul(a, prep, pol), x
    )
    assert (
        got_prep
        == perfmodel.kernel_launch_count(pol.n_moduli, "real", prepared=True)
        == 3
    )

    pm = _policy(np.float32, "per_modulus_kernel")
    got_pm = count_pallas_launches(lambda a, b: policy_matmul(a, b, pm), x, w)
    assert got_pm == perfmodel.kernel_launch_count(
        pm.n_moduli, "real", modulus_batched=False
    ) == 3 + pm.n_moduli


def test_acceptance_c64_kernel_drop_in(rng):
    """The ISSUE acceptance scenario verbatim: `repro.linalg.matmul` under
    `use_policy(GemmPolicy(backend="ozaki2_c64", execution="kernel"))` runs
    the batched Pallas path (jaxpr launch count) and is bitwise-identical to
    execution="reference" in interpret mode."""
    x, w = _operands(rng, np.complex64)
    kpol = GemmPolicy(backend="ozaki2_c64", execution="kernel", interpret=True)
    with repro.use_policy(kpol):
        y_kernel = np.asarray(linalg.matmul(x, w))
        launches = count_pallas_launches(linalg.matmul, x, w)
    with repro.use_policy(dataclasses.replace(kpol, execution="reference")):
        y_ref = np.asarray(linalg.matmul(x, w))
    assert launches == perfmodel.kernel_launch_count(
        kpol.n_moduli or 7, "karatsuba"
    ) == 4
    np.testing.assert_array_equal(y_kernel, y_ref)
    # and it is numerically a complex128-grade product of the c64 operands
    ref = np.asarray(x, np.complex128) @ np.asarray(w, np.complex128)
    assert np.max(np.abs(y_kernel - ref)) / np.max(np.abs(ref)) < 1e-5


# ===================================================== use_policy scoping


def test_use_policy_scoping():
    assert repro.current_policy() == GemmPolicy()
    p1 = GemmPolicy(backend="ozaki2_f32", n_moduli=6)
    p2 = GemmPolicy(backend="ozaki2_c64", execution="kernel")
    with repro.use_policy(p1):
        assert repro.current_policy() == p1
        with repro.use_policy(p2):
            assert repro.current_policy() == p2
        assert repro.current_policy() == p1
    assert repro.current_policy() == GemmPolicy()
    # backend-name shorthand
    with repro.use_policy("ozaki2_f64") as pol:
        assert pol.backend == "ozaki2_f64"
        assert repro.current_policy() == pol
    with pytest.raises(TypeError):
        with repro.use_policy(42):
            pass


def test_use_policy_restores_on_error():
    try:
        with repro.use_policy("ozaki2_f32"):
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert repro.current_policy() == GemmPolicy()


def test_policy_validation():
    with pytest.raises(ValueError):
        GemmPolicy(execution="gpu")
    with pytest.raises(ValueError):
        GemmPolicy(backend="ozaki2_f32", execution="kernel", method="paper")
    with pytest.raises(ValueError):
        GemmPolicy(backend="cublas")
    # method='auto' resolves per execution
    assert GemmPolicy(backend="ozaki2_f32").resolved_method == "paper"
    assert (
        GemmPolicy(backend="ozaki2_f32", execution="kernel").resolved_method
        == "garner"
    )
    # out_dtype spellings normalize into one hashable policy
    assert GemmPolicy(out_dtype=jnp.float64) == GemmPolicy(out_dtype="float64")


def test_model_config_pins_ambient_policy():
    from repro.models import ModelConfig

    kw = dict(name="t", n_layers=1, d_model=8, vocab=16)
    assert ModelConfig(**kw).gemm_policy == GemmPolicy()
    pol = GemmPolicy(backend="ozaki2_f32", n_moduli=6, execution="kernel")
    with repro.use_policy(pol):
        cfg = ModelConfig(**kw)
    assert cfg.gemm_policy == pol
    # pinned: leaving the scope does not unpin
    assert dataclasses.replace(cfg, d_model=16).gemm_policy == pol
    # explicit None re-resolves against the (now empty) scope
    assert dataclasses.replace(cfg, gemm_policy=None).gemm_policy == GemmPolicy()


def test_config_registry_resolves_ambient_policy():
    """Registry configs are import-time objects; get_config/get_reduced must
    re-pin the ambient policy at lookup (explicit overrides still win)."""
    from repro.configs import get_reduced

    pol = GemmPolicy(backend="ozaki2_f32", n_moduli=6, execution="kernel")
    with repro.use_policy(pol):
        assert get_reduced("starcoder2-3b").gemm_policy == pol
        explicit = GemmPolicy(backend="ozaki2_f64")
        assert (
            get_reduced("starcoder2-3b", gemm_policy=explicit).gemm_policy
            == explicit
        )
    assert get_reduced("starcoder2-3b").gemm_policy == GemmPolicy()


# ===================================================== BLAS-shaped wrappers


def test_blas_wrappers_force_compute_dtype(rng):
    x, w = _operands(rng, np.float32)
    # cgemm is the emulated complex64 product whatever the ambient backend
    y = linalg.cgemm(x, w, policy=GemmPolicy(n_moduli=5))
    assert y.dtype == jnp.complex64
    z = linalg.dgemm(x, w, policy=GemmPolicy(n_moduli=6))
    assert z.dtype == jnp.float64
    ref = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
    assert np.max(np.abs(np.asarray(z) - ref)) / np.max(np.abs(ref)) < 1e-4
    s = linalg.sgemm(x, w, policy=GemmPolicy(n_moduli=8))
    assert s.dtype == jnp.float32
    zz = linalg.zgemm(
        *_operands(rng, np.complex128), policy=GemmPolicy(n_moduli=6)
    )
    assert zz.dtype == jnp.complex128


def test_matmul_batched_weight_and_errors(rng):
    xb = jnp.asarray(phi_matrix(rng, (2, M, K), 0.5, np.float32))
    wb = jnp.asarray(phi_matrix(rng, (2, K, N), 0.5, np.float32))
    pol = _policy(np.float32, "reference", n_moduli=8)
    y = linalg.matmul(xb, wb, policy=pol)
    assert y.shape == (2, M, N)
    ref = np.einsum("bmk,bkn->bmn", np.asarray(xb), np.asarray(wb))
    assert np.max(np.abs(np.asarray(y) - ref)) < 1e-4 * np.max(np.abs(ref))
    with pytest.raises(ValueError):
        linalg.matmul(jnp.ones((4,)), jnp.ones((4, 2)), policy=pol)


# ===================================================== legacy shims


def test_legacy_shims_deprecated_and_agree(rng):
    from repro.core import ozaki2_cgemm, ozaki2_gemm
    from repro.kernels import ozaki2_cgemm_kernels, ozaki2_gemm_kernels

    x, w = _operands(rng, np.float64)
    cx, cw = _operands(rng, np.complex128)
    fx, fw = x.astype(jnp.float32), w.astype(jnp.float32)
    c4x, c4w = cx.astype(jnp.complex64), cw.astype(jnp.complex64)

    with pytest.warns(DeprecationWarning, match="ozaki2_gemm is deprecated"):
        legacy = np.asarray(ozaki2_gemm(x, w, 6, "fast"))
    modern = np.asarray(
        linalg.matmul(x, w, policy=GemmPolicy(backend="ozaki2_f64", n_moduli=6))
    )
    np.testing.assert_array_equal(legacy, modern)

    with pytest.warns(DeprecationWarning, match="ozaki2_cgemm is deprecated"):
        legacy = np.asarray(ozaki2_cgemm(cx, cw, 6, "accu", formulation="block_a"))
    modern = np.asarray(
        linalg.matmul(
            cx,
            cw,
            policy=GemmPolicy(
                backend="ozaki2_c128", n_moduli=6, mode="accu",
                formulation="block_a",
            ),
        )
    )
    np.testing.assert_array_equal(legacy, modern)

    with pytest.warns(DeprecationWarning, match="ozaki2_gemm_kernels"):
        legacy = np.asarray(ozaki2_gemm_kernels(fx, fw, n_moduli=5, interpret=True))
    modern = np.asarray(linalg.matmul(fx, fw, policy=_policy(np.float32, "kernel")))
    np.testing.assert_array_equal(legacy, modern)

    with pytest.warns(DeprecationWarning, match="ozaki2_cgemm_kernels"):
        legacy = np.asarray(
            ozaki2_cgemm_kernels(c4x, c4w, n_moduli=5, interpret=True)
        )
    modern = np.asarray(
        linalg.matmul(c4x, c4w, policy=_policy(np.complex64, "kernel"))
    )
    np.testing.assert_array_equal(legacy, modern)


# ===================================================== prepare_weights walk


def test_prepare_weights_scanned_bundles(rng):
    """Regression: "w" values reached through list/tuple nesting (scanned /
    stacked weight bundles) are prepared too, not silently left raw."""
    w2 = jnp.asarray(phi_matrix(rng, (K, N), 0.5, np.float32))
    wstack = jnp.asarray(
        np.stack([phi_matrix(rng, (K, N), 0.5, np.float32) for _ in range(3)])
    )
    pol = _policy(np.float32, "kernel")
    tree = {
        "dense": {"w": w2, "b": jnp.zeros((N,), jnp.float32)},
        "groups": [
            {"attn": {"w": wstack}},
            {"mlp": {"w": (wstack, w2)}},  # the formerly-missed case
        ],
        "meta": {"steps": jnp.arange(3)},
    }
    out = prepare_weights(tree, pol)
    assert isinstance(out["dense"]["w"], PreparedOperand)
    assert isinstance(out["groups"][0]["attn"]["w"], PreparedOperand)
    assert out["groups"][0]["attn"]["w"].residues[0].shape[0] == 3
    tup = out["groups"][1]["mlp"]["w"]
    assert isinstance(tup, tuple) and all(
        isinstance(v, PreparedOperand) for v in tup
    )
    # non-"w" leaves untouched
    assert isinstance(out["dense"]["b"], jnp.ndarray)
    assert isinstance(out["meta"]["steps"], jnp.ndarray)
    # the scanned stack slices per layer exactly like the raw weights
    x = jnp.asarray(phi_matrix(rng, (M, K), 0.5, np.float32))
    sl = jax.tree.map(lambda v: v[1], tup[0])
    got = np.asarray(policy_matmul(x, sl, pol))
    want = np.asarray(policy_matmul(x, wstack[1], pol))
    np.testing.assert_array_equal(got, want)


# ===================================================== serving round trip


def _tiny_engine_cfg(execution):
    from repro.configs import get_reduced

    pol = GemmPolicy(
        backend="ozaki2_f32", n_moduli=6, execution=execution, interpret=True
    )
    with repro.use_policy(pol):
        # gemm_policy=None: the config pins the ambient policy — the
        # context-scoped deployment path the redesign is about
        cfg = dataclasses.replace(
            get_reduced("starcoder2-3b"),
            gemm_policy=None,
            dtype="float32",
            n_layers=1,
        )
    assert cfg.gemm_policy == pol
    return cfg


def test_serve_engine_kernel_prepared_and_restore(rng, tmp_path):
    """Acceptance + satellite: prepared serving on the *kernel* execution is
    bit-transparent, and a second engine restores the persisted residue
    planes (bitwise) instead of re-preparing."""
    from repro.models import Model
    from repro.serve.engine import ServeEngine

    cfg = _tiny_engine_cfg("kernel")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    batch = {"tokens": tokens}
    plain = ServeEngine(model, params, cache_len=16, batch_size=1)
    pdir = str(tmp_path / "prepared")
    prepped = ServeEngine(
        model, params, cache_len=16, batch_size=1, prepare=True,
        prepared_dir=pdir,
    )
    t1 = np.asarray(plain.generate(batch, max_new_tokens=2))
    t2 = np.asarray(prepped.generate(batch, max_new_tokens=2))
    np.testing.assert_array_equal(t1, t2)

    # restart: restores instead of re-preparing, bitwise-equal planes
    restored = ServeEngine(
        model, params, cache_len=16, batch_size=1, prepare=True,
        prepared_dir=pdir,
    )
    leaves1 = jax.tree.leaves(prepped.params)
    leaves2 = jax.tree.leaves(restored.params)
    assert len(leaves1) == len(leaves2)
    prepared_leaf_seen = False
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        prepared_leaf_seen |= np.asarray(a).dtype == np.int8
    assert prepared_leaf_seen  # residue planes actually round-tripped
    t3 = np.asarray(restored.generate(batch, max_new_tokens=2))
    np.testing.assert_array_equal(t1, t3)

    # stale cache: a save from a different policy (here: a reference-cast
    # cache reused under another execution) must be detected and re-prepared
    # loudly, not silently served
    cfg_ref = dataclasses.replace(
        cfg, gemm_policy=dataclasses.replace(cfg.gemm_policy,
                                             execution="reference")
    )
    model_ref = Model(cfg_ref)
    with pytest.warns(UserWarning, match="re-preparing"):
        reprep = ServeEngine(
            model_ref, params, cache_len=16, batch_size=1, prepare=True,
            prepared_dir=pdir,
        )
    # f32 casts agree between backends, so generation still matches
    np.testing.assert_array_equal(
        t1, np.asarray(reprep.generate(batch, max_new_tokens=2))
    )
    # non-prepared leaves (embeddings, norms, biases) do not invalidate the
    # cache: only the weights preparation consumes are fingerprinted
    embed_bumped = dict(params, embed=params["embed"] + 1e-3)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ServeEngine(
            model_ref, embed_bumped, cache_len=16, batch_size=1, prepare=True,
            prepared_dir=pdir,
        )
    # stale weights: perturbing a prepared "w" leaf must re-prepare, loudly
    jtu = jax.tree_util
    w_bumped = jtu.tree_map_with_path(
        lambda path, a: a + 1e-3 if jtu.keystr(path).endswith("['w']") else a,
        params,
    )
    assert any(
        jtu.keystr(p).endswith("['w']")
        for p, _ in jtu.tree_flatten_with_path(params)[0]
    )
    with pytest.warns(UserWarning, match="re-preparing"):
        ServeEngine(
            model_ref, w_bumped, cache_len=16, batch_size=1, prepare=True,
            prepared_dir=pdir,
        )


def test_serve_engine_c64_kernel_prepared(rng):
    """Acceptance tail: the complex kernel policy is bit-transparent through
    `ServeEngine(prepare=True)` too (tiny 1-layer model, interpret mode)."""
    from repro.models import Model, ModelConfig
    from repro.serve.engine import ServeEngine

    pol = GemmPolicy(
        backend="ozaki2_c64", n_moduli=5, execution="kernel", interpret=True
    )
    with repro.use_policy(pol):
        cfg = ModelConfig(
            name="tiny-c64", n_layers=1, d_model=32, vocab=64,
            n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64,
            dtype="float32",
        )
    assert cfg.gemm_policy == pol
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (1, 4)), jnp.int32)}
    plain = ServeEngine(model, params, cache_len=8, batch_size=1)
    prepped = ServeEngine(model, params, cache_len=8, batch_size=1, prepare=True)
    t1 = np.asarray(plain.generate(batch, max_new_tokens=2))
    t2 = np.asarray(prepped.generate(batch, max_new_tokens=2))
    np.testing.assert_array_equal(t1, t2)


def test_prepared_operand_checkpoint_roundtrip(rng):
    """Direct checkpointer round-trip of real + complex PreparedOperands."""
    import tempfile

    from repro.checkpoint import Checkpointer

    w = jnp.asarray(phi_matrix(rng, (K, N), 0.5, np.complex64))
    tree = {
        "c": PreparedOperand(w, 5, side="right"),
        "r": PreparedOperand(jnp.real(w), 5, side="left"),
    }
    like = jax.eval_shape(lambda: tree)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(3, tree)
        out = ck.restore(3, like)
    for key in ("c", "r"):
        a, b = tree[key], out[key]
        assert (a.side, a.n_moduli, a.n_limbs, a.dtype) == (
            b.side, b.n_moduli, b.n_limbs, b.dtype,
        )
        assert len(a.residues) == len(b.residues)
        np.testing.assert_array_equal(np.asarray(a.e_scale), np.asarray(b.e_scale))
        for ra, rb in zip(a.residues, b.residues):
            np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))
