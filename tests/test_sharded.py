"""`GemmPolicy(execution="sharded")`: the residue pipeline over the mesh.

What this file guarantees (tests/test_linalg.py covers the single-device
policy stack; this file covers its distribution):

  * sharded execution is **bitwise identical to execution="kernel"** — on a
    1-device mesh (the acceptance criterion) and, because the partial
    reconstruction combines in the exact order-independent f64 split of
    `core/crt.partial_split`, on EVERY mesh shape (data x model x residue),
    for {f32, f64, c64, c128} x {fast, accu} x all three complex
    formulations and under output-column blocking;
  * the only cross-device traffic is the psum of the reconstructed output's
    exact partial planes — **no int8 residue array appears in any
    collective** (asserted against the traced jaxpr);
  * the mesh/axis plumbing: `use_mesh` / `use_policy(mesh=...)` thread-local
    defaults, `shard_axes` overrides, `resolve_gemm_axes` fallbacks, and
    the serve/train-facing model path (a model under a sharded ambient
    policy generates the same tokens as under the kernel policy, and
    `jax.grad` through the sharded custom VJP matches the kernel VJP).

Multi-device cases run on whatever `jax.devices()` offers and skip
otherwise; CI's multi-device job forces 8 host devices
(XLA_FLAGS=--xla_force_host_platform_device_count=8) so the full matrix
runs there.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import FAST_K, FAST_M, FAST_N, phi_matrix
import repro
from repro import linalg
from repro.core import GemmPolicy
from repro.core.policy import BACKEND_FOR_DTYPE, policy_matmul, prepare_weights
from repro.distributed.sharding import (
    GemmShardAxes,
    residue_plane_specs,
    resolve_gemm_axes,
)
from repro.analysis import CollectiveSafetyPass, collect_collectives

M, K, N = FAST_M, FAST_K, FAST_N
DTYPES = [np.float32, np.float64, np.complex64, np.complex128]
N_MODULI = {"float32": 5, "float64": 6, "complex64": 5, "complex128": 6}


def _mesh(data=1, model=1, residue=1):
    need = data * model * residue
    if len(jax.devices()) < need:
        pytest.skip(f"needs {need} devices, have {len(jax.devices())}")
    return jax.make_mesh((data, model, residue), ("data", "model", "residue"))


def _policy(dtype, execution, **kw):
    name = np.dtype(dtype).name
    kw.setdefault("n_moduli", N_MODULI[name])
    kw.setdefault("interpret", True)
    return GemmPolicy(backend=BACKEND_FOR_DTYPE[name], execution=execution, **kw)


def _operands(rng, dtype, m=M, n=N):
    x = jnp.asarray(phi_matrix(rng, (m, K), 0.5, dtype))
    w = jnp.asarray(phi_matrix(rng, (K, n), 0.5, dtype))
    return x, w


# ================================================= parity: 1-device mesh


@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_sharded_bitwise_kernel_1device(rng, dtype, mode):
    """Acceptance: on a 1-device mesh the sharded execution is bitwise
    identical to execution='kernel' for every dtype x mode."""
    x, w = _operands(rng, dtype)
    mesh = _mesh(1, 1, 1)
    y_k = np.asarray(policy_matmul(x, w, _policy(dtype, "kernel", mode=mode)))
    y_s = np.asarray(
        policy_matmul(x, w, _policy(dtype, "sharded", mode=mode, mesh=mesh))
    )
    np.testing.assert_array_equal(y_k, y_s)


@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("formulation", ["karatsuba", "block_a", "block_b"])
def test_sharded_formulations_bitwise(rng, formulation, mode):
    """All three Fig. 1 complex strategies x both modes compose through the
    sharded worker (the block embeddings from its dynamic-modulus
    residue_matmul, the fused-Karatsuba kernel from the chunk carry)."""
    x, w = _operands(rng, np.complex64)
    residue = 2 if len(jax.devices()) >= 2 else 1
    mesh = _mesh(1, 1, residue)
    y_k = np.asarray(
        policy_matmul(
            x, w,
            _policy(np.complex64, "kernel", formulation=formulation, mode=mode),
        )
    )
    y_s = np.asarray(
        policy_matmul(
            x, w,
            _policy(np.complex64, "sharded", formulation=formulation,
                    mode=mode, mesh=mesh),
        )
    )
    np.testing.assert_array_equal(y_k, y_s)


# ============================================ parity: multi-device meshes


@pytest.mark.parametrize(
    "meshdims", [(2, 1, 1), (1, 2, 1), (1, 1, 2), (2, 2, 2), (1, 1, 8)]
)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sharded_multi_mesh_bitwise(rng, dtype, meshdims):
    """The falsifiable tentpole claim: residue arithmetic is exact and the
    partial combine is order-independent, so EVERY mesh shape reproduces the
    1-device kernel output bit for bit — residue-sharded (N=5/6 planes over
    2 or 8 shards exercises the zero-plane padding), m/n-sharded, and both."""
    x, w = _operands(rng, dtype)
    mesh = _mesh(*meshdims)
    y_k = np.asarray(policy_matmul(x, w, _policy(dtype, "kernel")))
    y_s = np.asarray(policy_matmul(x, w, _policy(dtype, "sharded", mesh=mesh)))
    np.testing.assert_array_equal(y_k, y_s)


def test_sharded_accu_multi_mesh_bitwise(rng):
    """Accurate mode across a (2, 2, 2) mesh: the pmax-combined bound maxima
    reproduce the global exponents exactly (int32 pmax is exact)."""
    mesh = _mesh(2, 2, 2)
    for dtype in (np.float32, np.complex128):
        x, w = _operands(rng, dtype)
        y_k = np.asarray(policy_matmul(x, w, _policy(dtype, "kernel", mode="accu")))
        y_s = np.asarray(
            policy_matmul(x, w, _policy(dtype, "sharded", mode="accu", mesh=mesh))
        )
        np.testing.assert_array_equal(y_k, y_s)


def test_sharded_n_block_bitwise(rng):
    """Output-column blocking under sharding: each block combines with its
    own psum, and the concatenated blocks still match the kernel path."""
    residue = min(2, len(jax.devices()))
    mesh = _mesh(1, 1, residue)
    x, w = _operands(rng, np.float32)
    y_k = np.asarray(policy_matmul(x, w, _policy(np.float32, "kernel", n_block=8)))
    y_s = np.asarray(
        policy_matmul(x, w, _policy(np.float32, "sharded", n_block=8, mesh=mesh))
    )
    np.testing.assert_array_equal(y_k, y_s)


def test_sharded_indivisible_dims_drop_to_replicated(rng):
    """m/n that don't divide their mesh axes drop to replicated (the
    parameter-rule convention) instead of failing shard_map."""
    mesh = _mesh(2, 2, 2)
    x, w = _operands(rng, np.float32, m=M + 1, n=N + 1)  # 33, 25: odd
    y_k = np.asarray(policy_matmul(x, w, _policy(np.float32, "kernel")))
    y_s = np.asarray(policy_matmul(x, w, _policy(np.float32, "sharded", mesh=mesh)))
    np.testing.assert_array_equal(y_k, y_s)


def test_sharded_reference_inner_bitwise(rng):
    """The debuggable flavour: a ShardedBackend wrapping the jnp reference
    backend (no Pallas) runs the worker's dynamic-modulus f64 product and
    Karatsuba paths and still bit-matches the unsharded reference run."""
    from repro.core.executor import REFERENCE, run_plan
    from repro.core.plan import make_plan
    from repro.distributed.sharded_gemm import ShardedBackend

    mesh = _mesh(1, 1, 2)  # residue sharding is what exercises the dyn ops
    for dtype in (np.float32, np.complex64):
        x, w = _operands(rng, dtype)
        formulation = (
            "karatsuba" if np.issubdtype(dtype, np.complexfloating) else None
        )
        plan = make_plan(
            dtype, n_moduli=5, method="garner", formulation=formulation
        )
        want = np.asarray(run_plan(plan, x, w, REFERENCE))
        got = np.asarray(
            ShardedBackend(REFERENCE, mesh).run_plan(plan, x, w)
        )
        np.testing.assert_array_equal(want, got)


# ==================================================== collective hygiene


def test_no_int8_crosses_the_mesh(rng):
    """The distribution contract: the ONLY communicated arrays are the
    exact f64 partial-reconstruction planes (and int32 bound maxima in accu
    mode) — never the int8 residue planes.  Certified by the shared
    `repro.analysis.CollectiveSafetyPass` (which the analysis CLI also runs
    on every matrix row in CI)."""
    mesh = _mesh(1, 1, 2)
    x, w = _operands(rng, np.complex64)
    for mode in ("fast", "accu"):
        pol = _policy(np.complex64, "sharded", mode=mode, mesh=mesh)
        jaxpr = jax.make_jaxpr(lambda a, b: policy_matmul(a, b, pol))(x, w)
        findings = CollectiveSafetyPass().run(jaxpr)
        assert findings == [], [str(f) for f in findings]
        colls = collect_collectives(jaxpr)
        assert colls, "sharded residue execution must communicate via psum"
        # the payload is the exact f64 partial planes
        assert any(
            name == "psum" and any(dt == jnp.float64 for dt in dtypes)
            for name, dtypes in colls
        )
    # and the same invariant on the compiled (SPMD-partitioned) HLO: no
    # collective op touches an s8 array
    pol = _policy(np.complex64, "sharded", mesh=mesh)
    hlo = (
        jax.jit(lambda a, b: policy_matmul(a, b, pol)).lower(x, w)
        .compile().as_text()
    )
    coll_lines = [
        ln for ln in hlo.splitlines()
        if any(
            f"{c}(" in ln or f"{c}-start(" in ln
            for c in ("all-reduce", "all-gather", "all-to-all",
                      "collective-permute", "reduce-scatter")
        )
    ]
    assert coll_lines, "partitioned HLO should contain the output psum"
    for ln in coll_lines:
        assert "s8[" not in ln, f"int8 in compiled collective: {ln.strip()}"


# ========================================================= differentiation


def test_sharded_grad_matches_kernel(rng):
    """jax.grad through the sharded custom VJP (cotangents are sharded
    emulated GEMMs too) matches the kernel execution bitwise."""
    residue = min(2, len(jax.devices()))
    mesh = _mesh(1, 1, residue)
    x, w = _operands(rng, np.float32)

    def loss(pol):
        return lambda a, b: jnp.sum(linalg.matmul(a, b, policy=pol) ** 2)

    gk = jax.grad(loss(_policy(np.float32, "kernel")), argnums=(0, 1))(x, w)
    gs = jax.grad(
        loss(_policy(np.float32, "sharded", mesh=mesh)), argnums=(0, 1)
    )(x, w)
    for a, b in zip(gk, gs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ============================================== model / serve / train route


def test_sharded_model_generates_like_kernel(rng):
    """The drop-in route: a model built under a sharded ambient policy
    (ModelConfig pins it) serves the same tokens as under the kernel policy
    — one use_policy scope distributes every matmul in the model."""
    from repro.models import Model, ModelConfig
    from repro.serve.engine import ServeEngine

    residue = min(2, len(jax.devices()))
    mesh = _mesh(1, 1, residue)
    kw = dict(
        name="tiny-sharded", n_layers=1, d_model=32, vocab=64, n_heads=2,
        n_kv_heads=1, head_dim=16, d_ff=64, dtype="float32",
    )
    toks = {}
    for execution in ("kernel", "sharded"):
        pol = GemmPolicy(
            backend="ozaki2_f32", n_moduli=6, execution=execution,
            interpret=True, mesh=mesh if execution == "sharded" else None,
        )
        with repro.use_policy(pol):
            cfg = ModelConfig(**kw)
        assert cfg.gemm_policy == pol
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, cache_len=8, batch_size=1)
        batch = {"tokens": jnp.asarray([[3, 1, 4, 1]], jnp.int32)}
        toks[execution] = np.asarray(eng.generate(batch, max_new_tokens=2))
    np.testing.assert_array_equal(toks["kernel"], toks["sharded"])


# =============================================== mesh/axis resolution API


def test_sharded_needs_a_mesh(rng):
    x, w = _operands(rng, np.float32)
    pol = _policy(np.float32, "sharded")
    with pytest.raises(ValueError, match="needs a mesh"):
        policy_matmul(x, w, pol)


def test_use_mesh_threadlocal_default(rng):
    """mesh=None resolves the thread-local `use_mesh` default at trace time;
    `use_policy(policy, mesh=...)` scopes both in one statement."""
    mesh = _mesh(1, 1, 1)
    x, w = _operands(rng, np.float32)
    y_k = np.asarray(policy_matmul(x, w, _policy(np.float32, "kernel")))
    assert repro.current_mesh() is None
    with repro.use_mesh(mesh):
        assert repro.current_mesh() is mesh
        y_s = np.asarray(policy_matmul(x, w, _policy(np.float32, "sharded")))
    assert repro.current_mesh() is None
    np.testing.assert_array_equal(y_k, y_s)
    with repro.use_policy(_policy(np.float32, "sharded"), mesh=mesh):
        assert repro.current_mesh() is mesh
        y_s2 = np.asarray(linalg.matmul(x, w))
    np.testing.assert_array_equal(y_k, y_s2)
    with pytest.raises(TypeError):
        with repro.use_mesh("not a mesh"):
            pass


def test_matmul_jit_resolves_ambient_mesh_before_cache(rng):
    """Regression: matmul_jit caches on (shapes, policy) — a mesh-less
    sharded policy must fold the ambient use_mesh mesh into the policy
    BEFORE jit, or the second scope would silently reuse the first mesh
    from the cache (wrong devices, no error)."""
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices")
    x, w = _operands(rng, np.float32)
    pol = _policy(np.float32, "sharded")
    mesh1 = jax.make_mesh((1, 1, 2), ("data", "model", "residue"))
    mesh2 = jax.make_mesh((1, 1, 4), ("data", "model", "residue"))
    with repro.use_mesh(mesh1):
        y1 = linalg.matmul_jit(x, w, policy=pol)
    with repro.use_mesh(mesh2):
        y2 = linalg.matmul_jit(x, w, policy=pol)
    assert {d.id for d in y2.devices()} != {d.id for d in y1.devices()}
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_resolve_gemm_axes_rules():
    mesh = _mesh(1, 1, 1)
    axes = resolve_gemm_axes(mesh)
    assert axes == GemmShardAxes(residue="residue", m="data", n="model")
    # no residue axis: fall back to model, which then can't also carry n
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    assert resolve_gemm_axes(mesh2) == GemmShardAxes(
        residue="model", m="data", n=None
    )
    # size-aware m/n: indivisible dims drop to replicated
    assert resolve_gemm_axes(mesh, m=33, n=24).m == (
        "data" if mesh.shape["data"] == 1 else None
    )
    # overrides taken verbatim, validated against the mesh
    assert resolve_gemm_axes(mesh2, overrides=(None, None, "model")) == (
        GemmShardAxes(residue=None, m=None, n="model")
    )
    with pytest.raises(ValueError, match="not on mesh"):
        resolve_gemm_axes(mesh2, overrides=("residue", None, None))
    # the spec table spells the design: int8 stacks shard planes, the psum
    # payload and output never carry the residue axis
    specs = residue_plane_specs(resolve_gemm_axes(mesh))
    assert specs["a_residues"][0] == "residue"
    assert "residue" not in tuple(specs["partial"]) + tuple(specs["out"])


def test_sharded_policy_is_hashable_and_jit_static(rng):
    mesh = _mesh(1, 1, 1)
    pol = _policy(np.float32, "sharded", mesh=mesh)
    assert hash(pol) == hash(dataclasses.replace(pol))
    x, w = _operands(rng, np.float32)
    y = np.asarray(linalg.matmul_jit(x, w, policy=pol))  # policy as jit static
    y_k = np.asarray(policy_matmul(x, w, _policy(np.float32, "kernel")))
    np.testing.assert_array_equal(y, y_k)


def test_prepared_and_sharded_raise(rng):
    """Prepared weights meeting a sharded execution fail FAST with a
    NotImplementedError that names the remediation (serve on 'kernel' /
    'fused' outside a mesh, or pass raw weights) — not a deep generic
    failure.  The fused execution inside a mesh scope resolves to the same
    sharded pipeline, so it must refuse identically."""
    mesh = _mesh(1, 1, 1)
    x, w = _operands(rng, np.float32)
    kpol = _policy(np.float32, "kernel")
    spol = _policy(np.float32, "sharded", mesh=mesh)
    prep = prepare_weights({"w": w}, kpol)["w"]
    with pytest.raises(NotImplementedError, match="execution='kernel'"):
        policy_matmul(x, prep, spol)
    with pytest.raises(NotImplementedError, match="execution='kernel'"):
        prepare_weights({"w": w}, spol)
    fpol = _policy(np.float32, "fused", mesh=mesh)
    with pytest.raises(NotImplementedError, match="mesh"):
        policy_matmul(x, prep, fpol)
    with pytest.raises(NotImplementedError, match="mesh"):
        prepare_weights({"w": w}, fpol)
    # NotImplementedError is not a ValueError: callers that caught the old
    # generic error by type must not silently swallow the new one
    assert not issubclass(NotImplementedError, ValueError)


def test_sharded_plan_prices_communication():
    """plan_for consults the perfmodel's sharded communication term and the
    per-shard shapes, so 'auto' selections model what each shard runs."""
    from repro.core import perfmodel

    mesh = _mesh(1, 1, 1)
    pol = _policy(np.complex64, "sharded", mesh=mesh, formulation="auto")
    plan = pol.plan_for(M, K, N)  # resolves without error on the tiny mesh
    assert plan.formulation in ("karatsuba", "block_a", "block_b")
    # the comm term itself: zero on one shard, grows with the part count
    assert perfmodel.sharded_comm_time_s(256, 256, 8, 1) == 0.0
    t2 = perfmodel.sharded_comm_time_s(256, 256, 8, 2)
    t8 = perfmodel.sharded_comm_time_s(256, 256, 8, 8)
    assert t8 > t2 > perfmodel.COLLECTIVE_LAUNCH_S
    parts = perfmodel.crt_partial_parts(8)
    assert parts >= 2  # ~64-bit weights split into >= 2 exact f64 parts


# ======================================== parity: the fused megakernel


@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_bitwise_kernel_single_device(rng, dtype, mode):
    """Acceptance: execution='fused' (no mesh — the plain megakernel) is
    bitwise identical to execution='kernel' for every dtype x mode at the
    policy entry point."""
    x, w = _operands(rng, dtype)
    y_k = np.asarray(policy_matmul(x, w, _policy(dtype, "kernel", mode=mode)))
    y_f = np.asarray(policy_matmul(x, w, _policy(dtype, "fused", mode=mode)))
    np.testing.assert_array_equal(y_k, y_f)


@pytest.mark.parametrize(
    "meshdims", [(2, 1, 1), (1, 2, 1), (1, 1, 2), (2, 2, 2), (1, 1, 8)]
)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_multi_mesh_bitwise(rng, dtype, meshdims):
    """The megakernel under every mesh shape reproduces the 1-device kernel
    output bit for bit: m/n-sharded meshes run the fused worker (one launch
    per shard), residue-sharded meshes fall back to the composed worker
    with the two-phase deferred psum — both produce the same canonical
    residues, hence the same bits."""
    x, w = _operands(rng, dtype)
    mesh = _mesh(*meshdims)
    y_k = np.asarray(policy_matmul(x, w, _policy(dtype, "kernel")))
    y_f = np.asarray(policy_matmul(x, w, _policy(dtype, "fused", mesh=mesh)))
    np.testing.assert_array_equal(y_k, y_f)


def test_fused_worker_engages_on_mn_mesh(rng):
    """Structural check behind the mesh parity: on an m/n-only mesh the
    sharded wrapper delegates to the fused worker — the traced program holds
    exactly ONE `pallas_call` — while a residue-sharded mesh falls back to
    the composed worker (multiple launches, two-phase psum), since the fused
    Garner epilogue needs the full compile-time-static modulus set."""
    from repro.analysis import count_pallas_calls
    from repro.kernels import FusedBackend, KernelBackend
    from repro.distributed.sharded_gemm import ShardedBackend

    x, w = _operands(rng, np.float32)
    mesh_mn = _mesh(1, 2, 1)
    assert ShardedBackend(FusedBackend(True), mesh_mn, None).megakernel
    assert not ShardedBackend(KernelBackend(True), mesh_mn, None).megakernel
    got_mn = count_pallas_calls(
        lambda a, b: policy_matmul(
            a, b, _policy(np.float32, "fused", mesh=mesh_mn)
        ),
        x, w,
    )
    assert got_mn == 1
    if len(jax.devices()) >= 2:
        mesh_r = _mesh(1, 1, 2)
        got_r = count_pallas_calls(
            lambda a, b: policy_matmul(
                a, b, _policy(np.float32, "fused", mesh=mesh_r)
            ),
            x, w,
        )
        assert got_r > 1  # composed fallback: per-stage launches
