"""Documentation can't rot: every documented snippet executes in CI.

Two kinds of coverage:

  * the fenced ```python blocks of README.md and docs/paper_map.md run
    top-to-bottom in one shared namespace per file (blocks may build on
    earlier blocks, exactly as a reader would type them);
  * the doctest examples of the public API surface — `repro.linalg`
    (matmul, the BLAS wrappers, `use_policy`), `repro.core.policy`
    (`GemmPolicy`, `use_mesh`) and `repro.core.executor`
    (`PreparedOperand`) — run via `doctest.testmod`.

CI runs this file with JAX_PLATFORMS=cpu (the tier-1 doctest step); the
snippets are written against small shapes so the whole file stays fast.
"""
import doctest
import pathlib
import re

import pytest

import repro
import repro.core.executor
import repro.core.policy

REPO = pathlib.Path(__file__).resolve().parent.parent

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _python_blocks(path: pathlib.Path) -> list[str]:
    return _FENCE.findall(path.read_text())


@pytest.mark.parametrize(
    "relpath",
    ["README.md", "docs/paper_map.md", "docs/static_analysis.md",
     "docs/calibration.md", "docs/accuracy.md"],
    ids=["readme", "paper_map", "static_analysis", "calibration",
         "accuracy"],
)
def test_markdown_snippets_execute(relpath):
    """All ```python blocks of the document run (shared namespace, in
    order) — the asserts inside them are the documented claims."""
    path = REPO / relpath
    assert path.exists(), f"{relpath} is missing"
    blocks = _python_blocks(path)
    assert blocks, f"{relpath} documents no runnable python"
    ns: dict = {}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"{relpath}[block {i}]", "exec"), ns)
        except Exception as e:  # pragma: no cover - the repr IS the report
            raise AssertionError(
                f"{relpath} block {i} failed: {type(e).__name__}: {e}\n"
                f"--- block ---\n{block}"
            ) from e


@pytest.mark.parametrize(
    "mod",
    [repro.linalg, repro.core.policy, repro.core.executor],
    ids=lambda m: m.__name__,
)
def test_api_doctests(mod):
    """The runnable examples in the public docstrings pass verbatim."""
    result = doctest.testmod(mod, verbose=False)
    assert result.attempted > 0, f"{mod.__name__} documents no examples"
    assert result.failed == 0, f"{mod.__name__}: {result.failed} doctest failures"


def test_readme_documents_the_policy_surface():
    """The policy surface stays in sync everywhere it is spelled out: the
    `Execution` Literal, the README's policy-axis table, and every CLI's
    `--execution` choices.  The actual checking lives in the shared
    `repro.analysis.lint` source linter (which `python -m repro.analysis`
    also runs in CI); this test just asserts it comes back clean."""
    from repro.analysis.lint import lint_policy_surface

    findings = lint_policy_surface(REPO)
    assert findings == [], [str(f) for f in findings]
