"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles.

The three integer kernels must be BIT-EXACT against the oracles; the Garner
reconstruction kernel is compared at its double-single precision.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.moduli import make_crt_context
from repro.kernels import (
    crt_garner,
    int8_mod_gemm,
    karatsuba_mod_gemm,
    ozaki2_cgemm_kernels,
    ozaki2_gemm_kernels,
    residue_cast,
)
from repro.kernels import ref
from repro.kernels.common import split_scale_exponent

SHAPES_MK = [(128, 256), (256, 512), (8, 128)]
MODULI_SWEEP = [3, 199, 251, 255]


@pytest.mark.parametrize("m,k", SHAPES_MK)
@pytest.mark.parametrize("n_mod", [2, 7, 13])
@pytest.mark.parametrize("scale_axis", [0, 1])
def test_residue_cast_sweep(rng, m, k, n_mod, scale_axis):
    ctx = make_crt_context(n_mod)
    a = (rng.standard_normal((m, k)) * 10.0 ** rng.integers(-3, 4)).astype(np.float32)
    dim = m if scale_axis == 0 else k
    e = rng.integers(-10, 20, size=dim).astype(np.int32)
    s1, s2 = split_scale_exponent(jnp.asarray(e))
    kw = dict(moduli=ctx.moduli, n_limbs=2, scale_axis=scale_axis)
    out = residue_cast(jnp.asarray(a), s1, s2, bm=min(128, m), bk=128, **kw)
    expect = ref.residue_cast_ref(jnp.asarray(a), s1, s2, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("m,n,k", [(128, 128, 256), (256, 64, 512), (8, 128, 128)])
@pytest.mark.parametrize("p", MODULI_SWEEP)
def test_int8_mod_gemm_sweep(rng, m, n, k, p):
    h = (p - 1) // 2
    a = rng.integers(-h, h + 1, size=(m, k)).astype(np.int8)
    b = rng.integers(-h, h + 1, size=(k, n)).astype(np.int8)
    out = int8_mod_gemm(jnp.asarray(a), jnp.asarray(b), p=p, bm=128, bn=64, bk=128)
    expect = ref.int8_mod_gemm_ref(jnp.asarray(a), jnp.asarray(b), p=p)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("p", MODULI_SWEEP)
def test_karatsuba_fused_sweep(rng, p):
    m, n, k = 128, 128, 256
    h = (p - 1) // 2
    mats = [
        rng.integers(-h, h + 1, size=s).astype(np.int8)
        for s in [(m, k), (m, k), (k, n), (k, n)]
    ]
    cr, ci = karatsuba_mod_gemm(*map(jnp.asarray, mats), p=p, bm=128, bn=128, bk=128)
    er, ei = ref.karatsuba_mod_gemm_ref(*map(jnp.asarray, mats), p=p)
    np.testing.assert_array_equal(np.asarray(cr), np.asarray(er))
    np.testing.assert_array_equal(np.asarray(ci), np.asarray(ei))


@pytest.mark.parametrize("n_mod", [2, 7, 13, 16])
@pytest.mark.parametrize("out_dd", [False, True])
def test_crt_garner_sweep(rng, n_mod, out_dd):
    ctx = make_crt_context(n_mod)
    m, n = 128, 128
    e = np.stack(
        [
            rng.integers(-(p - 1) // 2, (p - 1) // 2 + 1, size=(m, n))
            for p in ctx.moduli
        ]
    ).astype(np.int8)
    emu = rng.integers(10, 60, size=m).astype(np.int32)
    enu = rng.integers(10, 60, size=n).astype(np.int32)
    out = crt_garner(jnp.asarray(e), jnp.asarray(emu), jnp.asarray(enu), ctx, out_dd=out_dd)
    expect = np.asarray(ref.crt_garner_ref(jnp.asarray(e), jnp.asarray(emu), jnp.asarray(enu), ctx))
    got = (
        np.asarray(out[0], np.float64) + np.asarray(out[1], np.float64)
        if out_dd
        else np.asarray(out, np.float64)
    )
    tol = 2.0**-44 if out_dd else 2.0**-21
    denom = np.maximum(np.abs(expect), np.max(np.abs(expect)) * 1e-6 + 1e-300)
    assert np.max(np.abs(got - expect) / denom) < tol


def test_full_kernel_gemm_pipeline(rng):
    m, k, n = 256, 512, 256
    a = (rng.random((m, k)) - 0.5).astype(np.float32)
    b = (rng.random((k, n)) - 0.5).astype(np.float32)
    y = np.asarray(ozaki2_gemm_kernels(jnp.asarray(a), jnp.asarray(b), n_moduli=8))
    expect = a.astype(np.float64) @ b.astype(np.float64)
    scale = np.max(np.abs(expect))
    assert np.max(np.abs(y - expect)) / scale < 1e-5


def test_full_kernel_cgemm_pipeline(rng):
    m, k, n = 256, 512, 256
    a = ((rng.random((m, k)) - 0.5) + 1j * (rng.random((m, k)) - 0.5)).astype(np.complex64)
    b = ((rng.random((k, n)) - 0.5) + 1j * (rng.random((k, n)) - 0.5)).astype(np.complex64)
    y = np.asarray(ozaki2_cgemm_kernels(jnp.asarray(a), jnp.asarray(b), n_moduli=7))
    expect = a.astype(np.complex128) @ b.astype(np.complex128)
    scale = np.max(np.abs(expect))
    assert np.max(np.abs(y - expect)) / scale < 1e-5


@pytest.mark.parametrize("formulation", ["block_a", "block_b"])
def test_kernel_block_formulations_match_karatsuba(rng, formulation):
    """The block embeddings (eqs. 7/8), composed in the shared executor over
    `int8_mod_gemm`, produce residues identical to the fused-Karatsuba
    kernel => bitwise-equal outputs on the kernel path too."""
    m, k, n = 128, 128, 128
    a = ((rng.random((m, k)) - 0.5) + 1j * (rng.random((m, k)) - 0.5)).astype(np.complex64)
    b = ((rng.random((k, n)) - 0.5) + 1j * (rng.random((k, n)) - 0.5)).astype(np.complex64)
    base = np.asarray(ozaki2_cgemm_kernels(jnp.asarray(a), jnp.asarray(b), n_moduli=4))
    alt = np.asarray(
        ozaki2_cgemm_kernels(
            jnp.asarray(a), jnp.asarray(b), n_moduli=4, formulation=formulation
        )
    )
    np.testing.assert_array_equal(base, alt)


@pytest.mark.parametrize(
    "b,s,h,kv,d", [(2, 256, 4, 2, 64), (1, 512, 8, 1, 32), (2, 128, 4, 4, 64)]
)
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_flash_attention_sweep(rng, b, s, h, kv, d, dtype):
    from repro.kernels import flash_attention

    dt = jnp.dtype(dtype)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dt)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), dt)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), dt)
    out = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                 expect.astype(jnp.float32)))) < tol


def test_kernel_pipeline_matches_core_residues(rng):
    """Kernel path and core path produce identical int8 residue planes."""
    from repro.core import scaling
    from repro.core.residues import quantize, residues_from_quantized

    ctx = make_crt_context(7)
    m, k = 128, 256
    a = (rng.random((m, k)) - 0.5).astype(np.float32)
    e = rng.integers(0, 20, size=m).astype(np.int32)
    s1, s2 = split_scale_exponent(jnp.asarray(e))
    kern = residue_cast(jnp.asarray(a), s1, s2, moduli=ctx.moduli, n_limbs=2)
    aq = quantize(jnp.asarray(a, jnp.float64), scaling.exp2_vector(jnp.asarray(e)), 0)
    core = residues_from_quantized(aq, ctx, 2)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(core))
