"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles.

The three integer kernels must be BIT-EXACT against the oracles; the Garner
reconstruction kernel is compared at its double-single precision.  The
modulus-batched kernels (one `pallas_call` for all N planes) must be
BIT-IDENTICAL to the retained per-modulus launches, including ragged
(non-block-divisible) shapes and chunked-K carries, and the pipeline's
launch counts must match the perfmodel's `kernel_launch_count` (certified
through the shared `repro.analysis.LaunchCountPass`).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import FAST_K, FAST_M, FAST_N, phi_matrix
from repro.analysis import certify_launch_count
from repro.core import perfmodel
from repro.core.executor import execute_plan
from repro.core.moduli import make_crt_context
from repro.core.plan import make_plan
from repro.kernels import (
    FusedBackend,
    KernelBackend,
    PerModulusKernelBackend,
    crt_garner,
    int8_mod_gemm,
    int8_mod_gemm_batched,
    karatsuba_mod_gemm,
    karatsuba_mod_gemm_batched,
    ozaki2_cgemm_kernels,
    ozaki2_gemm_kernels,
    residue_cast,
)
from repro.kernels import ref
from repro.kernels.common import split_scale_exponent

SHAPES_MK = [(128, 256), (256, 512), (8, 128)]
MODULI_SWEEP = [3, 199, 251, 255]


@pytest.mark.parametrize("m,k", SHAPES_MK)
@pytest.mark.parametrize("n_mod", [2, 7, 13])
@pytest.mark.parametrize("scale_axis", [0, 1])
def test_residue_cast_sweep(rng, m, k, n_mod, scale_axis):
    ctx = make_crt_context(n_mod)
    a = (rng.standard_normal((m, k)) * 10.0 ** rng.integers(-3, 4)).astype(np.float32)
    dim = m if scale_axis == 0 else k
    e = rng.integers(-10, 20, size=dim).astype(np.int32)
    s1, s2 = split_scale_exponent(jnp.asarray(e))
    kw = dict(moduli=ctx.moduli, n_limbs=2, scale_axis=scale_axis)
    out = residue_cast(jnp.asarray(a), s1, s2, bm=min(128, m), bk=128, **kw)
    expect = ref.residue_cast_ref(jnp.asarray(a), s1, s2, **kw)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("m,n,k", [(128, 128, 256), (256, 64, 512), (8, 128, 128)])
@pytest.mark.parametrize("p", MODULI_SWEEP)
def test_int8_mod_gemm_sweep(rng, m, n, k, p):
    h = (p - 1) // 2
    a = rng.integers(-h, h + 1, size=(m, k)).astype(np.int8)
    b = rng.integers(-h, h + 1, size=(k, n)).astype(np.int8)
    out = int8_mod_gemm(jnp.asarray(a), jnp.asarray(b), p=p, bm=128, bn=64, bk=128)
    expect = ref.int8_mod_gemm_ref(jnp.asarray(a), jnp.asarray(b), p=p)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("p", MODULI_SWEEP)
def test_karatsuba_fused_sweep(rng, p):
    m, n, k = 128, 128, 256
    h = (p - 1) // 2
    mats = [
        rng.integers(-h, h + 1, size=s).astype(np.int8)
        for s in [(m, k), (m, k), (k, n), (k, n)]
    ]
    cr, ci = karatsuba_mod_gemm(*map(jnp.asarray, mats), p=p, bm=128, bn=128, bk=128)
    er, ei = ref.karatsuba_mod_gemm_ref(*map(jnp.asarray, mats), p=p)
    np.testing.assert_array_equal(np.asarray(cr), np.asarray(er))
    np.testing.assert_array_equal(np.asarray(ci), np.asarray(ei))


@pytest.mark.parametrize("n_mod", [2, 7, 13, 16])
@pytest.mark.parametrize("out_dd", [False, True])
def test_crt_garner_sweep(rng, n_mod, out_dd):
    ctx = make_crt_context(n_mod)
    m, n = 128, 128
    e = np.stack(
        [
            rng.integers(-(p - 1) // 2, (p - 1) // 2 + 1, size=(m, n))
            for p in ctx.moduli
        ]
    ).astype(np.int8)
    emu = rng.integers(10, 60, size=m).astype(np.int32)
    enu = rng.integers(10, 60, size=n).astype(np.int32)
    out = crt_garner(jnp.asarray(e), jnp.asarray(emu), jnp.asarray(enu), ctx, out_dd=out_dd)
    expect = np.asarray(ref.crt_garner_ref(jnp.asarray(e), jnp.asarray(emu), jnp.asarray(enu), ctx))
    got = (
        np.asarray(out[0], np.float64) + np.asarray(out[1], np.float64)
        if out_dd
        else np.asarray(out, np.float64)
    )
    tol = 2.0**-44 if out_dd else 2.0**-21
    denom = np.maximum(np.abs(expect), np.max(np.abs(expect)) * 1e-6 + 1e-300)
    assert np.max(np.abs(got - expect) / denom) < tol


def test_full_kernel_gemm_pipeline(rng):
    m, k, n = 256, 512, 256
    a = (rng.random((m, k)) - 0.5).astype(np.float32)
    b = (rng.random((k, n)) - 0.5).astype(np.float32)
    y = np.asarray(ozaki2_gemm_kernels(jnp.asarray(a), jnp.asarray(b), n_moduli=8))
    expect = a.astype(np.float64) @ b.astype(np.float64)
    scale = np.max(np.abs(expect))
    assert np.max(np.abs(y - expect)) / scale < 1e-5


def test_full_kernel_cgemm_pipeline(rng):
    m, k, n = 256, 512, 256
    a = ((rng.random((m, k)) - 0.5) + 1j * (rng.random((m, k)) - 0.5)).astype(np.complex64)
    b = ((rng.random((k, n)) - 0.5) + 1j * (rng.random((k, n)) - 0.5)).astype(np.complex64)
    y = np.asarray(ozaki2_cgemm_kernels(jnp.asarray(a), jnp.asarray(b), n_moduli=7))
    expect = a.astype(np.complex128) @ b.astype(np.complex128)
    scale = np.max(np.abs(expect))
    assert np.max(np.abs(y - expect)) / scale < 1e-5


@pytest.mark.parametrize("formulation", ["block_a", "block_b"])
def test_kernel_block_formulations_match_karatsuba(rng, formulation):
    """The block embeddings (eqs. 7/8), composed in the shared executor over
    `int8_mod_gemm`, produce residues identical to the fused-Karatsuba
    kernel => bitwise-equal outputs on the kernel path too."""
    m, k, n = 128, 128, 128
    a = ((rng.random((m, k)) - 0.5) + 1j * (rng.random((m, k)) - 0.5)).astype(np.complex64)
    b = ((rng.random((k, n)) - 0.5) + 1j * (rng.random((k, n)) - 0.5)).astype(np.complex64)
    base = np.asarray(ozaki2_cgemm_kernels(jnp.asarray(a), jnp.asarray(b), n_moduli=4))
    alt = np.asarray(
        ozaki2_cgemm_kernels(
            jnp.asarray(a), jnp.asarray(b), n_moduli=4, formulation=formulation
        )
    )
    np.testing.assert_array_equal(base, alt)


@pytest.mark.parametrize(
    "b,s,h,kv,d", [(2, 256, 4, 2, 64), (1, 512, 8, 1, 32), (2, 128, 4, 4, 64)]
)
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_flash_attention_sweep(rng, b, s, h, kv, d, dtype):
    from repro.kernels import flash_attention

    dt = jnp.dtype(dtype)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dt)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), dt)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), dt)
    out = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                 expect.astype(jnp.float32)))) < tol


def test_kernel_pipeline_matches_core_residues(rng):
    """Kernel path and core path produce identical int8 residue planes."""
    from repro.core import scaling
    from repro.core.residues import quantize, residues_from_quantized

    ctx = make_crt_context(7)
    m, k = 128, 256
    a = (rng.random((m, k)) - 0.5).astype(np.float32)
    e = rng.integers(0, 20, size=m).astype(np.int32)
    s1, s2 = split_scale_exponent(jnp.asarray(e))
    kern = residue_cast(jnp.asarray(a), s1, s2, moduli=ctx.moduli, n_limbs=2)
    aq = quantize(jnp.asarray(a, jnp.float64), scaling.exp2_vector(jnp.asarray(e)), 0)
    core = residues_from_quantized(aq, ctx, 2)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(core))


# ================================================= modulus-batched kernels


BATCHED = KernelBackend(interpret=True)
PER_MODULUS = PerModulusKernelBackend(interpret=True)


def _garner_plan(dtype, mode="fast", formulation=None, n_moduli=5, n_block=None):
    return make_plan(
        dtype, n_moduli=n_moduli, mode=mode, method="garner",
        formulation=formulation, n_block=n_block,
    )


def _operands(rng, dtype, m=FAST_M, k=FAST_K, n=FAST_N):
    a = jnp.asarray(phi_matrix(rng, (m, k), 0.5, dtype))
    b = jnp.asarray(phi_matrix(rng, (k, n), 0.5, dtype))
    return a, b


@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_batched_matches_per_modulus_real(rng, dtype, mode):
    """Tentpole parity: the single-launch batched kernels are bitwise
    identical to the retained per-modulus launches (real pipelines)."""
    a, b = _operands(rng, dtype)
    plan = _garner_plan(dtype, mode)
    got = np.asarray(execute_plan(plan, a, b, BATCHED))
    want = np.asarray(execute_plan(plan, a, b, PER_MODULUS))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("formulation", ["karatsuba", "block_a", "block_b"])
@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_batched_matches_per_modulus_complex(rng, dtype, mode, formulation):
    """Tentpole parity, complex: batched vs per-modulus across all three
    Fig. 1 formulations (Karatsuba uses the fused kernel on both sides;
    the block embeddings compose over the real residue product)."""
    a, b = _operands(rng, dtype)
    plan = _garner_plan(dtype, mode, formulation)
    got = np.asarray(execute_plan(plan, a, b, BATCHED))
    want = np.asarray(execute_plan(plan, a, b, PER_MODULUS))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("p", [3, 251])
def test_int8_mod_gemm_ragged_regression(rng, p):
    """Non-block-divisible shapes previously raised ValueError on the kernel
    path; pad-and-slice must keep them bit-exact (m,n,k prime)."""
    m, n, k = 37, 29, 53
    h = (p - 1) // 2
    a = rng.integers(-h, h + 1, size=(m, k)).astype(np.int8)
    b = rng.integers(-h, h + 1, size=(k, n)).astype(np.int8)
    out = int8_mod_gemm(jnp.asarray(a), jnp.asarray(b), p=p, bm=16, bn=16, bk=16)
    expect = ref.int8_mod_gemm_ref(jnp.asarray(a), jnp.asarray(b), p=p)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_karatsuba_ragged_regression(rng):
    m, n, k, p = 37, 29, 53, 251
    h = (p - 1) // 2
    mats = [
        rng.integers(-h, h + 1, size=s).astype(np.int8)
        for s in [(m, k), (m, k), (k, n), (k, n)]
    ]
    cr, ci = karatsuba_mod_gemm(*map(jnp.asarray, mats), p=p, bm=16, bn=16, bk=16)
    er, ei = ref.karatsuba_mod_gemm_ref(*map(jnp.asarray, mats), p=p)
    np.testing.assert_array_equal(np.asarray(cr), np.asarray(er))
    np.testing.assert_array_equal(np.asarray(ci), np.asarray(ei))


def test_full_pipeline_ragged_default_blocks(rng):
    """m=257 exceeds the default 256-row block and is not divisible by it —
    exactly the case that raised before pad-and-slice; the padded pipeline
    must stay inside the f32 accuracy band and match per-modulus bitwise."""
    m, k, n = 257, 131, 67
    a = (rng.random((m, k)) - 0.5).astype(np.float32)
    b = (rng.random((k, n)) - 0.5).astype(np.float32)
    y = np.asarray(ozaki2_gemm_kernels(jnp.asarray(a), jnp.asarray(b), n_moduli=8))
    expect = a.astype(np.float64) @ b.astype(np.float64)
    assert np.max(np.abs(y - expect)) / np.max(np.abs(expect)) < 1e-5
    plan = _garner_plan(np.float32, n_moduli=8)
    want = np.asarray(
        execute_plan(plan, jnp.asarray(a), jnp.asarray(b), PER_MODULUS)
    )
    np.testing.assert_array_equal(y, want)


@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_ragged_n_block_split(rng, dtype):
    """n_block=3 on n=FAST_N leaves a ragged tail block; the kernel path
    must produce the same bits as the unblocked run (same residues sliced)."""
    a, b = _operands(rng, dtype)
    formulation = "karatsuba" if np.issubdtype(dtype, np.complexfloating) else None
    full = np.asarray(
        execute_plan(_garner_plan(dtype, formulation=formulation), a, b, BATCHED)
    )
    blocked = np.asarray(
        execute_plan(
            _garner_plan(dtype, formulation=formulation, n_block=3), a, b, BATCHED
        )
    )
    np.testing.assert_array_equal(full, blocked)


def test_chunked_k_carry_epilogue(rng, monkeypatch):
    """Acceptance: chunked-K stays on the batched path — one launch per
    K-chunk, inter-chunk sym_mod folded into the kernel carry epilogue, and
    the result is bitwise identical to the single-chunk run.  Both the real
    product and the Karatsuba (R, I) pairs chunk through the one shared
    `chunked_residue_matmul` loop, so a single K_CHUNK_LIMIT patch governs
    both; the un-chunked baselines are computed BEFORE patching."""
    import repro.core.executor as executor

    a, b = _operands(rng, np.float32, k=160)
    plan = _garner_plan(np.float32)
    ca, cb = _operands(rng, np.complex64, k=160)
    cplan = _garner_plan(np.complex64, formulation="karatsuba")
    whole = np.asarray(execute_plan(plan, a, b, BATCHED))
    cwhole = np.asarray(execute_plan(cplan, ca, cb, BATCHED))

    monkeypatch.setattr(executor, "K_CHUNK_LIMIT", 64)
    chunked = np.asarray(execute_plan(plan, a, b, BATCHED))
    np.testing.assert_array_equal(whole, chunked)
    # 3 chunks of k=160 -> 2 casts + 3 products + 1 reconstruct = 6 launches
    want = perfmodel.kernel_launch_count(5, "real", n_chunks=3)
    assert want == 6
    assert certify_launch_count(
        want, lambda x, y: execute_plan(plan, x, y, BATCHED), a, b
    ) == []

    # complex Karatsuba: CR/CI chunk carries thread through the fused kernel
    cchunked = np.asarray(execute_plan(cplan, ca, cb, BATCHED))
    np.testing.assert_array_equal(cwhole, cchunked)


@pytest.mark.parametrize("n_moduli", [3, 7])
def test_launch_counts_independent_of_n(rng, n_moduli):
    """Acceptance: exactly one `pallas_call` per cast, one for the modular
    product, one for reconstruction — at ANY modulus count — while the
    per-modulus reference scales with N.  Counts must agree with the
    perfmodel's `kernel_launch_count` (which drives formulation='auto')."""
    a, b = _operands(rng, np.float32)
    plan = _garner_plan(np.float32, n_moduli=n_moduli)
    want = perfmodel.kernel_launch_count(n_moduli, "real")
    assert want == 4
    assert certify_launch_count(
        want, lambda x, y: execute_plan(plan, x, y, BATCHED), a, b
    ) == []
    want_pm = perfmodel.kernel_launch_count(
        n_moduli, "real", modulus_batched=False
    )
    assert want_pm == 3 + n_moduli
    assert certify_launch_count(
        want_pm, lambda x, y: execute_plan(plan, x, y, PER_MODULUS), a, b
    ) == []


@pytest.mark.parametrize("formulation", ["karatsuba", "block_a"])
def test_launch_counts_complex(rng, formulation):
    ca, cb = _operands(rng, np.complex64)
    plan = _garner_plan(np.complex64, formulation=formulation, n_moduli=4)
    # stacked casts (re+im together), one batched product, stacked CR/CI
    # reconstruction: 4 launches total regardless of N or formulation
    want = perfmodel.kernel_launch_count(4, formulation)
    assert want == 4
    assert certify_launch_count(
        want, lambda x, y: execute_plan(plan, x, y, BATCHED), ca, cb
    ) == []
    want_pm = perfmodel.kernel_launch_count(
        4, formulation, modulus_batched=False
    )
    assert certify_launch_count(
        want_pm, lambda x, y: execute_plan(plan, x, y, PER_MODULUS), ca, cb
    ) == []


def test_batched_kernels_direct_parity(rng):
    """Kernel-level parity: one batched call == N per-modulus calls, with
    and without a carry operand."""
    ctx = make_crt_context(4)
    m, n, k = 32, 24, 48
    ares = rng.integers(-127, 128, size=(4, m, k)).astype(np.int8)
    bres = rng.integers(-127, 128, size=(4, k, n)).astype(np.int8)
    carry = rng.integers(-100, 101, size=(4, m, n)).astype(np.int8)
    got = np.asarray(
        int8_mod_gemm_batched(
            jnp.asarray(ares), jnp.asarray(bres), moduli=ctx.moduli,
            carry=jnp.asarray(carry),
        )
    )
    for l, p in enumerate(ctx.moduli):
        exact = ares[l].astype(np.int64) @ bres[l].astype(np.int64) + carry[l]
        r = exact % p
        r = np.where(r > (p - 1) // 2, r - p, r)
        np.testing.assert_array_equal(got[l], r)
    mats = [
        rng.integers(-127, 128, size=s).astype(np.int8)
        for s in [(4, m, k), (4, m, k), (4, k, n), (4, k, n)]
    ]
    crb, cib = karatsuba_mod_gemm_batched(
        *map(jnp.asarray, mats), moduli=ctx.moduli
    )
    for l, p in enumerate(ctx.moduli):
        er, ei = ref.karatsuba_mod_gemm_ref(
            *(jnp.asarray(mm[l]) for mm in mats), p=int(p)
        )
        np.testing.assert_array_equal(np.asarray(crb)[l], np.asarray(er))
        np.testing.assert_array_equal(np.asarray(cib)[l], np.asarray(ei))


# ---------------------------------------------- block shrink (pad economics)


@pytest.mark.parametrize("m", [129, 257])
def test_block_shrink_just_over_multiple(rng, m):
    """ROADMAP follow-up from PR 2: a dim just above a block multiple picks
    the next-smaller legal block instead of padding ~2x, behind the
    perfmodel-visible BLOCK_SHRINK knob — and the padded pipeline stays
    bitwise identical (zero padding is residue-exact either way)."""
    from repro.kernels.common import block_and_padded

    # m=129 < 256 shrinks the block to the dim (no padding at all);
    # m=257 > 256 picks the aligned 128 block and pads to 384, not 512
    expect = {129: (129, 129), 257: (128, 384)}[m]
    assert block_and_padded(m, 256, align=128) == expect
    assert perfmodel.select_block(m, 256, 128) == expect[0]
    assert perfmodel.padded_dim(m, 256, 128) == expect[1]
    assert perfmodel.padded_dim(m, 256, 128) < 2 * m  # never ~2x anymore

    # the knob restores the legacy round-up (the economics are visible)
    perfmodel.BLOCK_SHRINK = False
    try:
        legacy = block_and_padded(m, 256, align=128)
        assert legacy == ((129, 129) if m == 129 else (256, 512))
    finally:
        perfmodel.BLOCK_SHRINK = True

    # numerics: shrunken blocks are still the same bits as the reference
    k, n = 40, 33
    a = (rng.random((m, k)) - 0.5).astype(np.float32)
    b = (rng.random((k, n)) - 0.5).astype(np.float32)
    plan = _garner_plan(np.float32, n_moduli=6)
    got = np.asarray(execute_plan(plan, jnp.asarray(a), jnp.asarray(b), BATCHED))
    want = np.asarray(
        execute_plan(plan, jnp.asarray(a), jnp.asarray(b), PER_MODULUS)
    )
    np.testing.assert_array_equal(got, want)
    expect_f64 = a.astype(np.float64) @ b.astype(np.float64)
    assert np.max(np.abs(got - expect_f64)) / np.max(np.abs(expect_f64)) < 1e-5


# --------------------------------------------------------------- megakernel

FUSED = FusedBackend(interpret=True)


@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_fused_launch_count_real(rng, dtype, mode):
    """Acceptance: the megakernel traces a real emulated GEMM — fast AND
    accu (the scaling pass is pallas-free) — to exactly ONE `pallas_call`,
    matching `kernel_launch_count(..., fused=True)`, and stays bitwise
    identical to the 4-launch kernel path."""
    a, b = _operands(rng, dtype)
    plan = _garner_plan(dtype, mode)
    want = perfmodel.kernel_launch_count(5, "real", fused=True)
    assert want == 1
    assert certify_launch_count(
        want, lambda x, y: execute_plan(plan, x, y, FUSED), a, b
    ) == []
    np.testing.assert_array_equal(
        np.asarray(execute_plan(plan, a, b, FUSED)),
        np.asarray(execute_plan(plan, a, b, BATCHED)),
    )


@pytest.mark.parametrize("formulation", ["karatsuba", "block_a", "block_b"])
@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_fused_launch_count_complex(rng, dtype, mode, formulation):
    """Acceptance: one `pallas_call` for a complex emulated GEMM on every
    Fig. 1 formulation x mode, bitwise identical to the kernel path (the
    block embeddings ride the real megakernel on embedded operands; the
    Karatsuba megakernel fuses cast + D/E/F + both Garner epilogues)."""
    a, b = _operands(rng, dtype)
    plan = _garner_plan(dtype, mode, formulation, n_moduli=4)
    want = perfmodel.kernel_launch_count(4, formulation, fused=True)
    assert want == 1
    assert certify_launch_count(
        want, lambda x, y: execute_plan(plan, x, y, FUSED), a, b
    ) == []
    np.testing.assert_array_equal(
        np.asarray(execute_plan(plan, a, b, FUSED)),
        np.asarray(execute_plan(plan, a, b, BATCHED)),
    )


@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", [np.float32, np.complex64])
def test_fused_prepared_one_launch(rng, dtype, mode):
    """Prepared serving on the megakernel: the pre-cast weight planes feed
    the kernel's B residue inputs directly, so the whole prepared GEMM is
    still ONE launch (vs 3 on the kernel path) and bitwise identical."""
    from repro.core.executor import PreparedOperand, gemm_prepared

    a, b = _operands(rng, dtype)
    keep_raw = mode == "accu"
    wk = PreparedOperand(b, 5, side="right", backend=BATCHED, keep_raw=keep_raw)
    wf = PreparedOperand(b, 5, side="right", backend=FUSED, keep_raw=keep_raw)
    kw = dict(method="garner", mode=mode)
    want_model = perfmodel.kernel_launch_count(
        5, "real" if dtype == np.float32 else "karatsuba",
        fused=True, prepared=True,
    )
    assert want_model == 1
    assert certify_launch_count(
        want_model, lambda x: gemm_prepared(wf, x, backend=FUSED, **kw), a
    ) == []
    np.testing.assert_array_equal(
        np.asarray(gemm_prepared(wf, a, backend=FUSED, **kw)),
        np.asarray(gemm_prepared(wk, a, backend=BATCHED, **kw)),
    )


def test_fused_chunked_k_one_launch(rng, monkeypatch):
    """K-chunking moves INSIDE the megakernel grid (k innermost = Pallas
    double-buffers the block fetches): the host carry loop of the kernel
    path collapses into one launch, still bitwise identical — the in-kernel
    chunk reduction produces the same canonical residues as the host
    carries."""
    import repro.core.executor as executor

    a, b = _operands(rng, np.float32, k=160)
    plan = _garner_plan(np.float32)
    ca, cb = _operands(rng, np.complex64, k=160)
    cplan = _garner_plan(np.complex64, formulation="karatsuba")
    whole = np.asarray(execute_plan(plan, a, b, BATCHED))
    cwhole = np.asarray(execute_plan(cplan, ca, cb, BATCHED))

    monkeypatch.setattr(executor, "K_CHUNK_LIMIT", 64)
    np.testing.assert_array_equal(
        whole, np.asarray(execute_plan(plan, a, b, FUSED))
    )
    np.testing.assert_array_equal(
        cwhole, np.asarray(execute_plan(cplan, ca, cb, FUSED))
    )
    want = perfmodel.kernel_launch_count(5, "real", n_chunks=3, fused=True)
    assert want == 1
    assert certify_launch_count(
        want, lambda x, y: execute_plan(plan, x, y, FUSED), a, b
    ) == []


def test_fused_n_block_launch_per_block(rng):
    """Output-column blocking still fans out one launch PER BLOCK (the
    n_blocks factor of `kernel_launch_count`), each block a full megakernel,
    bitwise identical to the blocked kernel path."""
    a, b = _operands(rng, np.float32)
    plan = _garner_plan(np.float32, n_block=8)  # FAST_N=24 -> 3 blocks
    want = perfmodel.kernel_launch_count(5, "real", fused=True, n_blocks=3)
    assert want == 3
    assert certify_launch_count(
        want, lambda x, y: execute_plan(plan, x, y, FUSED), a, b
    ) == []
    np.testing.assert_array_equal(
        np.asarray(execute_plan(plan, a, b, FUSED)),
        np.asarray(execute_plan(plan, a, b, BATCHED)),
    )
