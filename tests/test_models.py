"""Per-architecture smoke tests (reduced configs, deliverable f) plus
decode-vs-forward consistency and gradient flow checks."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.configs.shapes import SHAPES, applicable
from repro.models import Model

B, S = 2, 32


def _batch(cfg, rng, s=S):
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, s)), jnp.int32)}
    if cfg.frontend:
        out["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_prefix_embeds, cfg.d_model)) * 0.02,
            jnp.float32,
        )
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    """One forward + one train step on CPU: output shapes + no NaNs."""
    cfg = get_reduced(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits, aux = model.forward(params, batch)
    npre = cfg.n_prefix_embeds if cfg.frontend else 0
    assert logits.shape == (B, S + npre, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch, rng):
    cfg = get_reduced(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    npre = cfg.n_prefix_embeds if cfg.frontend else 0
    cache = model.init_cache(B, S + npre + 4)
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    logits2, _ = model.decode_step(params, tok, cache, jnp.int32(S + npre))
    assert logits2.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits2)))


@pytest.mark.parametrize(
    "arch", ["qwen2.5-32b", "mamba2-130m", "recurrentgemma-2b", "musicgen-medium"]
)
def test_decode_matches_forward_f32(arch, rng):
    """In float32 the incremental decode path must match the full forward
    to tight tolerance (MoE archs excluded: capacity dispatch is
    batch-global and intentionally differs between the two — DESIGN.md)."""
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    npre = cfg.n_prefix_embeds if cfg.frontend else 0
    full, _ = model.forward(params, batch)
    sp = S - 3
    cache = model.init_cache(B, S + npre)
    lp, cache = model.prefill(params, dict(batch, tokens=batch["tokens"][:, :sp]), cache)
    np.testing.assert_allclose(
        np.asarray(lp[:, -1]), np.asarray(full[:, npre + sp - 1]), rtol=2e-3, atol=2e-3
    )
    for i in range(3):
        pos = npre + sp + i
        ld, cache = model.decode_step(
            params, batch["tokens"][:, sp + i : sp + i + 1], cache, jnp.int32(pos)
        )
        np.testing.assert_allclose(
            np.asarray(ld[:, 0]), np.asarray(full[:, pos]), rtol=2e-3, atol=2e-3
        )


def test_chunked_vocab_ce_matches_dense(rng):
    cfg = dataclasses.replace(get_reduced("qwen2.5-32b"), dtype="float32")
    model_dense = Model(cfg)
    model_chunk = Model(dataclasses.replace(cfg, loss_vocab_chunk=128))
    params = model_dense.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    l1, _ = model_dense.loss(params, batch)
    l2, _ = model_chunk.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    # gradients agree too
    g1 = jax.grad(lambda p: model_dense.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: model_chunk.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=3e-2, atol=3e-4
        )


def test_emulated_backend_model(rng):
    """A model whose matmuls run on the Ozaki-II backend trains: the paper's
    technique as a framework feature (fwd/bwd through emulated GEMMs)."""
    from repro.core.policy import GemmPolicy

    cfg = dataclasses.replace(
        get_reduced("starcoder2-3b"),
        gemm_policy=GemmPolicy(backend="ozaki2_f32", n_moduli=8),
        dtype="float32",
    )
    cfg_native = dataclasses.replace(cfg, gemm_policy=GemmPolicy())
    m_em, m_nat = Model(cfg), Model(cfg_native)
    params = m_em.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    l_em, _ = m_em.loss(params, batch)
    l_nat, _ = m_nat.loss(params, batch)
    np.testing.assert_allclose(float(l_em), float(l_nat), rtol=1e-3)
    g = jax.grad(lambda p: m_em.loss(p, batch)[0])(params)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in jax.tree.leaves(g))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_shapes_metadata(arch):
    """Full (published) configs: abstract params build + sane param counts
    (metadata only — no allocation)."""
    cfg = get_config(arch)
    model = Model(cfg)
    shapes = model.param_shapes()
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    expected = {
        "mamba2-130m": (0.10e9, 0.3e9),
        "internvl2-26b": (17e9, 27e9),   # LLM backbone only (no ViT)
        "qwen2.5-32b": (30e9, 35e9),
        "nemotron-4-15b": (14e9, 17e9),
        "starcoder2-3b": (2.5e9, 3.5e9),
        "minitron-4b": (3.5e9, 5e9),
        "recurrentgemma-2b": (2e9, 3.2e9),
        "granite-moe-3b-a800m": (2.5e9, 4e9),
        "deepseek-moe-16b": (14e9, 18e9),
        "musicgen-medium": (1.2e9, 2.2e9),
    }[arch]
    assert expected[0] < n_params < expected[1], f"{arch}: {n_params/1e9:.2f}B"
    for shape in SHAPES:
        ok, why = applicable(cfg, shape)
        assert ok or "full-attention" in why
