"""Tests for the `repro.analysis` certifier: each jaxpr pass gets a
positive certificate (the real pipeline / boundary case comes back clean)
AND a negative test (a deliberately broken program is flagged), plus
property tests that the chunking machinery always satisfies the bound the
OverflowPass proves, and lint tests on synthetic repos.

The negative programs are raw `lax` constructions on purpose: the library
entry points (`int8_matmul`, `fp8_mod_gemm_batched`, ...) raise ValueError
above their chunk limits, so the only way to put an over-limit dot in a
jaxpr is to bypass them — exactly the regression the passes guard against.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import (
    CollectiveSafetyPass,
    Finding,
    LaunchCountPass,
    OverflowPass,
    ScanIndexWidthPass,
    certify_launch_count,
    certify_partial_split,
    collect_collectives,
    count_pallas_calls,
    expected_launch_count,
    lint_policy_surface,
    passes_for_backend,
    run_passes,
)
from repro.analysis.jaxprs import count_primitive, iter_eqns, unwrap
from repro.analysis.lint import execution_choices
from repro.core.moduli import K_CHUNK_LIMIT, make_crt_context
from repro.core.policy import EXECUTIONS, GemmPolicy


# ---------------------------------------------------------------------------
# OverflowPass: int8 accumulation bound
# ---------------------------------------------------------------------------

def _int8_dot_jaxpr(k):
    """Raw int8 dot_general of contraction length k (shapes only; traced)."""
    a = jax.ShapeDtypeStruct((2, k), jnp.int8)
    b = jax.ShapeDtypeStruct((k, 3), jnp.int8)
    return jax.make_jaxpr(
        lambda x, y: jax.lax.dot_general(
            x, y, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    )(a, b)


def test_overflow_int8_at_limit_certifies():
    assert OverflowPass().run(_int8_dot_jaxpr(K_CHUNK_LIMIT)) == []


def test_overflow_int8_beyond_limit_flagged():
    findings = OverflowPass().run(_int8_dot_jaxpr(K_CHUNK_LIMIT + 1))
    assert len(findings) == 1
    f = findings[0]
    assert f.pass_name == "overflow" and f.primitive == "dot_general"
    assert "K_CHUNK_LIMIT" in f.message
    assert "dot_general" in str(f)


def test_overflow_float_dots_never_flagged():
    """Ordinary float compute is out of scope — no bound is provable."""
    a = jax.ShapeDtypeStruct((2, K_CHUNK_LIMIT * 4), jnp.float32)
    b = jax.ShapeDtypeStruct((K_CHUNK_LIMIT * 4, 3), jnp.float32)
    jaxpr = jax.make_jaxpr(jnp.matmul)(a, b)
    assert OverflowPass().run(jaxpr) == []


def test_overflow_sees_through_pallas_grid(rng):
    """Inside a pallas kernel the effective K is per-block contraction x the
    innermost grid axis; the kernel launch at the engine's exact limit must
    certify (the grid multiplies a small block dot up to K_CHUNK_LIMIT)."""
    from repro.core.executor import execute_plan
    from repro.kernels import KernelBackend

    pol = GemmPolicy(backend="ozaki2_f32", n_moduli=4, execution="kernel",
                     interpret=True)
    plan = pol.plan_for(8, 256, 8)
    a = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 8)), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda x, y: execute_plan(plan, x, y, KernelBackend(interpret=True))
    )(a, b)
    assert OverflowPass().run(jaxpr) == []
    # tighten the limit below the kernel's effective K: the same trace is
    # now flagged, proving the grid axis is counted
    assert OverflowPass(k_limit=128).run(jaxpr) != []


# ---------------------------------------------------------------------------
# OverflowPass: fp8 digit bound
# ---------------------------------------------------------------------------

def _fp8_dot_jaxpr(k):
    a = jax.ShapeDtypeStruct((2, k), jnp.float8_e4m3fn)
    b = jax.ShapeDtypeStruct((k, 3), jnp.float8_e4m3fn)
    return jax.make_jaxpr(
        lambda x, y: jax.lax.dot_general(
            x, y, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    )(a, b)


def test_overflow_fp8_cross_term_bound():
    """The fp8 rule admits concatenated-digit (Karatsuba cross-term) dots up
    to 2*FP8_K_CHUNK_LIMIT and flags one element more."""
    from repro.kernels.fp8_mod_gemm import FP8_K_CHUNK_LIMIT

    assert OverflowPass().run(_fp8_dot_jaxpr(2 * FP8_K_CHUNK_LIMIT)) == []
    findings = OverflowPass().run(_fp8_dot_jaxpr(2 * FP8_K_CHUNK_LIMIT + 1))
    assert len(findings) == 1
    assert "FP8_K_CHUNK_LIMIT" in findings[0].message


def test_overflow_fp8_kernel_launch_at_limit(rng):
    """The real fp8 pallas kernel at its exact chunk limit certifies clean;
    an artificially tighter limit flags the very same trace."""
    from repro.kernels.fp8_mod_gemm import FP8_K_CHUNK_LIMIT, fp8_mod_gemm_batched

    ctx = make_crt_context(4)
    k = FP8_K_CHUNK_LIMIT
    a = jax.ShapeDtypeStruct((len(ctx.moduli), 8, k), jnp.int8)
    b = jax.ShapeDtypeStruct((len(ctx.moduli), k, 8), jnp.int8)
    jaxpr = jax.make_jaxpr(
        lambda x, y: fp8_mod_gemm_batched(x, y, moduli=ctx.moduli, interpret=True)
    )(a, b)
    assert OverflowPass().run(jaxpr) == []
    assert OverflowPass(fp8_limit=FP8_K_CHUNK_LIMIT // 8).run(jaxpr) != []


# ---------------------------------------------------------------------------
# OverflowPass: f64 provable-bound rule (CRT partial dots)
# ---------------------------------------------------------------------------

def _const_dot_jaxpr(scale):
    table = np.full((4, 3), scale)

    def f(x):
        return jnp.dot(x.astype(jnp.float64), jnp.asarray(table))

    return jax.make_jaxpr(f)(jax.ShapeDtypeStruct((2, 4), jnp.int8))


def test_overflow_f64_const_dot_within_window():
    # 127 * 2^40 * 4 ~ 5.6e14 < 2^53: exact, certifies
    assert OverflowPass().run(_const_dot_jaxpr(2.0**40)) == []


def test_overflow_f64_const_dot_beyond_window_flagged():
    # 127 * 2^48 * 4 ~ 1.4e17 > 2^53: the partial-combine would round
    findings = OverflowPass().run(_const_dot_jaxpr(2.0**48))
    assert len(findings) == 1
    assert "2^53" in findings[0].message


# ---------------------------------------------------------------------------
# CollectiveSafetyPass
# ---------------------------------------------------------------------------

def _psum_jaxpr(dtype):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("r",))

    def f(x):
        return shard_map(
            lambda v: jax.lax.psum(v, "r"),
            mesh=mesh, in_specs=P("r"), out_specs=P(),
        )(x)

    return jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4, 4), dtype))


def test_collective_safety_f64_psum_clean():
    jaxpr = _psum_jaxpr(jnp.float64)
    assert CollectiveSafetyPass().run(jaxpr) == []
    # inside shard_map the collective appears as psum2 in recent jax
    colls = collect_collectives(jaxpr)
    assert any(name in ("psum", "psum2") for name, _ in colls)


def test_collective_safety_int8_psum_flagged():
    findings = CollectiveSafetyPass().run(_psum_jaxpr(jnp.int8))
    assert findings, "int8 crossing the mesh must be a finding"
    for f in findings:
        assert f.pass_name == "collective-safety"
        assert "int8" in f.message


# ---------------------------------------------------------------------------
# LaunchCountPass
# ---------------------------------------------------------------------------

def test_launch_count_zero_for_pure_xla():
    a = jnp.zeros((4, 4))
    assert certify_launch_count(0, jnp.matmul, a, a) == []
    findings = certify_launch_count(3, jnp.matmul, a, a)
    assert len(findings) == 1
    assert "0 pallas_call" in findings[0].message
    assert "predicts 3" in findings[0].message


def test_launch_count_against_real_kernel(rng):
    from repro.core.executor import execute_plan
    from repro.kernels import KernelBackend

    pol = GemmPolicy(backend="ozaki2_f32", n_moduli=4, execution="kernel",
                     interpret=True)
    plan = pol.plan_for(8, 64, 8)
    a = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    want = expected_launch_count(KernelBackend(interpret=True), plan, (8, 64, 8))
    run = lambda x, y: execute_plan(plan, x, y, KernelBackend(interpret=True))
    assert certify_launch_count(want, run, a, b) == []
    assert certify_launch_count(want + 1, run, a, b) != []
    assert count_pallas_calls(run, a, b) == want


def test_expected_launch_count_zero_for_reference():
    from repro.core.executor import ReferenceBackend

    pol = GemmPolicy(backend="ozaki2_f32", n_moduli=4, execution="reference")
    plan = pol.plan_for(8, 64, 8)
    assert expected_launch_count(ReferenceBackend(), plan, (8, 64, 8)) == 0


# ---------------------------------------------------------------------------
# ScanIndexWidthPass
# ---------------------------------------------------------------------------

def _scan_index_jaxpr(index_dtype):
    x = jnp.zeros((8, 4))

    def f():
        def body(carry, i):
            row = jax.lax.dynamic_slice_in_dim(x, i, 1, axis=0)
            return carry + row.sum(), None

        return jax.lax.scan(body, 0.0, jnp.arange(8, dtype=index_dtype))[0]

    return jax.make_jaxpr(f)()


def test_scan_index_width_int32_clean():
    assert ScanIndexWidthPass().run(_scan_index_jaxpr(jnp.int32)) == []


def test_scan_index_width_int64_flagged():
    findings = ScanIndexWidthPass().run(_scan_index_jaxpr(jnp.int64))
    assert findings, "s64 scan-body index must be a finding"
    f = findings[0]
    assert f.pass_name == "scan-index-width"
    assert f.primitive == "dynamic_slice"
    assert "scan" in f.path


def test_scan_index_width_outside_scan_not_flagged():
    """s64 dynamic_slice OUTSIDE a scan body is fine (no carry involved)."""
    x = jnp.zeros((8, 4))
    jaxpr = jax.make_jaxpr(
        lambda i: jax.lax.dynamic_slice_in_dim(x, i, 1, axis=0)
    )(jnp.int64(3))
    assert ScanIndexWidthPass().run(jaxpr) == []


# ---------------------------------------------------------------------------
# certify_partial_split
# ---------------------------------------------------------------------------

def test_partial_split_tables_certify_for_all_sizes():
    for n in (2, 5, 14, 20):
        ctx = make_crt_context(n)
        assert certify_partial_split(ctx.moduli) == []


def test_partial_split_rejects_bad_tables():
    moduli = make_crt_context(3).moduli
    msgs = [f.message for f in certify_partial_split(
        moduli, u=np.array([[-1.0]]), part_bits=8)]
    assert any("negative" in m for m in msgs)
    msgs = [f.message for f in certify_partial_split(
        moduli, u=np.array([[300.0]]), part_bits=8)]
    assert any("part_bits" in m for m in msgs)
    msgs = [f.message for f in certify_partial_split(
        moduli, u=np.array([[2.0**55]]), part_bits=60)]
    assert any("2^53" in m for m in msgs)


# ---------------------------------------------------------------------------
# backend.analyze hook + run_passes
# ---------------------------------------------------------------------------

def test_backend_analyze_hook_matches_passes_for_backend():
    from repro.core.executor import ReferenceBackend

    pol = GemmPolicy(backend="ozaki2_f32", n_moduli=4, execution="reference")
    plan = pol.plan_for(8, 64, 8)
    backend = ReferenceBackend()
    suite = backend.analyze(plan, (8, 64, 8))
    kinds = [type(p).__name__ for p in suite]
    assert kinds == [
        "OverflowPass", "CollectiveSafetyPass", "ScanIndexWidthPass",
        "LaunchCountPass",
    ]
    # without a shape there is no launch expectation to pin
    assert [type(p).__name__ for p in backend.analyze(plan)] == kinds[:-1]

    a = jnp.zeros((8, 64), jnp.float32)
    b = jnp.zeros((64, 8), jnp.float32)
    from repro.core.executor import execute_plan

    jaxpr = jax.make_jaxpr(lambda x, y: execute_plan(plan, x, y, backend))(a, b)
    assert run_passes(suite, jaxpr) == []


# ---------------------------------------------------------------------------
# property tests: the chunk loop always satisfies the bound the pass proves
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    SET = settings(max_examples=20, deadline=None)
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dependency; CI installs it
    HAVE_HYPOTHESIS = False


def _residue_stack(moduli):
    """jnp reference mod-GEMM stack: (N,m,k)x(N,k,n) int8 -> (N,m,n) int8
    canonical symmetric residues (exact as long as k <= K_CHUNK_LIMIT)."""
    q = jnp.asarray(moduli, jnp.int32).reshape(-1, 1, 1)

    def stack(a, b):
        p = jax.lax.dot_general(
            a, b, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )
        r = jnp.remainder(p, q)
        return jnp.where(r > (q - 1) // 2, r - q, r).astype(jnp.int8)

    return stack


if HAVE_HYPOTHESIS:

    @given(st.integers(min_value=1, max_value=256),
           st.integers(min_value=8, max_value=64))
    @SET
    def test_chunked_residue_matmul_always_certifies(k, chunk_limit):
        """For ANY k and chunk limit, the shared K-chunk loop's trace
        certifies under OverflowPass(k_limit=chunk_limit): every engine dot
        it emits contracts at most chunk_limit elements.  The un-chunked
        stack is the control: flagged exactly when k exceeds the limit."""
        from repro.core.executor import chunked_residue_matmul

        ctx = make_crt_context(3)
        stack = _residue_stack(ctx.moduli)
        a = jax.ShapeDtypeStruct((3, 2, k), jnp.int8)
        b = jax.ShapeDtypeStruct((3, k, 2), jnp.int8)
        chunked = jax.make_jaxpr(
            lambda x, y: chunked_residue_matmul(
                stack, x, y, ctx, chunk_limit=chunk_limit
            )
        )(a, b)
        assert OverflowPass(k_limit=chunk_limit).run(chunked) == []
        direct = jax.make_jaxpr(stack)(a, b)
        flagged = OverflowPass(k_limit=chunk_limit).run(direct) != []
        assert flagged == (k > chunk_limit)

    @given(st.integers(min_value=1, max_value=2 * K_CHUNK_LIMIT))
    @SET
    def test_int8_dot_certification_is_exactly_the_limit(k):
        flagged = OverflowPass().run(_int8_dot_jaxpr(k)) != []
        assert flagged == (k > K_CHUNK_LIMIT)

    @given(st.integers(min_value=1, max_value=512),
           st.integers(min_value=8, max_value=128))
    @SET
    def test_fp8_dot_certification_is_twice_the_limit(k, fp8_limit):
        """The fp8 rule is parametric in the limit and always admits exactly
        2*limit (the concatenated Karatsuba cross-term width)."""
        flagged = OverflowPass(fp8_limit=fp8_limit).run(_fp8_dot_jaxpr(k)) != []
        assert flagged == (k > 2 * fp8_limit)

else:  # pragma: no cover - surfaced as an explicit skip, not silence

    @pytest.mark.skip(reason="optional dependency: hypothesis not installed")
    def test_analysis_property_suite():
        pass


# ---------------------------------------------------------------------------
# source lint
# ---------------------------------------------------------------------------

def _fake_repo(tmp_path, *, skip_execution=None, break_cli=None):
    """A minimal repo satisfying the policy-surface lint, with optional
    deliberate defects."""
    import dataclasses as dc

    fields = " ".join(f.name for f in dc.fields(GemmPolicy))
    execs = [e for e in EXECUTIONS if e != skip_execution]
    (tmp_path / "README.md").write_text(
        " ".join(f"`{e}`" for e in execs) + "\n" + fields + "\n"
    )
    cli_body = (
        "import argparse\n"
        "p = argparse.ArgumentParser()\n"
        f"p.add_argument(\"--execution\", choices={list(EXECUTIONS)!r})\n"
        "p.add_argument(\"--rtol\", type=float, default=None)\n"
    )
    broken_body = (
        "import argparse\n"
        "p = argparse.ArgumentParser()\n"
        f"p.add_argument(\"--execution\", choices={list(EXECUTIONS[:-1])!r})\n"
        "p.add_argument(\"--rtol\", type=float, default=None)\n"
    )
    from repro.analysis.lint import EXECUTION_CLIS

    for rel in EXECUTION_CLIS:
        path = tmp_path / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(broken_body if rel == break_cli else cli_body)
    return tmp_path


def test_lint_clean_on_synced_repo(tmp_path):
    assert lint_policy_surface(_fake_repo(tmp_path)) == []


def test_lint_flags_undocumented_execution(tmp_path):
    findings = lint_policy_surface(_fake_repo(tmp_path, skip_execution="fused"))
    assert len(findings) == 1
    assert "`fused`" in findings[0].message
    assert "README" in findings[0].message


def test_lint_flags_out_of_sync_cli(tmp_path):
    broken = "src/repro/launch/train.py"
    findings = lint_policy_surface(_fake_repo(tmp_path, break_cli=broken))
    assert len(findings) == 1
    assert broken in findings[0].message
    assert "missing" in findings[0].message


def test_lint_flags_missing_rtol_flag(tmp_path):
    repo = _fake_repo(tmp_path)
    target = repo / "src/repro/launch/serve.py"
    target.write_text(
        "\n".join(
            line for line in target.read_text().splitlines()
            if "--rtol" not in line
        )
        + "\n"
    )
    findings = lint_policy_surface(repo)
    assert len(findings) == 1
    assert "--rtol" in findings[0].message


def test_lint_flags_missing_cli(tmp_path):
    repo = _fake_repo(tmp_path)
    (repo / "src/repro/launch/serve.py").unlink()
    findings = lint_policy_surface(repo)
    assert len(findings) == 1
    assert "not found" in findings[0].message


def test_execution_choices_none_without_flag(tmp_path):
    p = tmp_path / "noflag.py"
    p.write_text("import argparse\np = argparse.ArgumentParser()\n")
    assert execution_choices(p) is None


def test_real_repo_lints_clean():
    from pathlib import Path

    repo = Path(__file__).resolve().parents[1]
    assert lint_policy_surface(repo) == []


# ---------------------------------------------------------------------------
# walker + CLI plumbing
# ---------------------------------------------------------------------------

def test_walker_counts_nested_primitives():
    def f(x):
        def body(c, _):
            return c * 2.0, None

        y, _ = jax.lax.scan(body, x, None, length=3)
        return jax.jit(jnp.sin)(y)

    jaxpr = jax.make_jaxpr(f)(1.0)
    open_jaxpr, consts = unwrap(jaxpr)
    assert count_primitive(open_jaxpr, "scan") == 1
    prims = {eqn.primitive.name for eqn, _ in iter_eqns(jaxpr)}
    assert "sin" in prims, "iter_eqns must descend into pjit bodies"
    in_scan = [ctx.in_scan_body for eqn, ctx in iter_eqns(jaxpr)
               if eqn.primitive.name == "mul"]
    assert in_scan == [True]


def test_cli_smoke_row_exits_clean(capsys):
    from repro.analysis.__main__ import main

    rc = main([
        "--executions", "reference", "--dtypes", "float32",
        "--modes", "fast", "--skip-model",
    ])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "certified clean" in out


def test_finding_str_static():
    f = Finding("overflow", "boom")
    assert str(f) == "[overflow] <static>: boom"
