"""repro.tune: calibration cache, scoping, tuned blocks, bench hygiene.

The contract under test (ISSUE 9 / docs/calibration.md):

* the cache round-trips exactly and *degrades, never breaks*: a stale,
  corrupt or missing file warns and falls back to the presets + static
  default blocks;
* with no calibration present, behaviour is bitwise identical to the
  pre-calibration code — presets price every 'auto' decision and the
  kernels launch the static default blocks;
* with a calibration active, the measured `HW` drives the 'auto'
  selections deterministically and the kernels launch the tuned blocks —
  which can never change numerics (pad-and-slice), only speed;
* bench_throughput's tracked-record merge dedupes and its --compare diff
  catches per-device-throughput regressions.
"""
import dataclasses
import json

import numpy as np
import jax.numpy as jnp
import pytest

import repro  # noqa: F401  (enables x64)
from repro.core import perfmodel
from repro.core.perfmodel import HW, TPU_V5E
from repro.core.policy import GemmPolicy
from repro.kernels.common import DEFAULT_GEMM_BLOCKS, resolve_blocks
from repro.tune.cache import (
    Calibration,
    block_key,
    calibration_hash,
    default_cache_path,
    live_key,
    load_calibration,
    save_calibration,
    set_calibration,
    shape_bucket,
    use_calibration,
)

from conftest import phi_matrix


def make_cal(blocks=None, **hw_over) -> Calibration:
    """A live-keyed calibration with a distinctive measured HW."""
    hw = dataclasses.replace(
        HW("calibrated/test", mem_bw=1e10, int8_ops=5e12, native_c64=0.0,
           native_c128=0.0, ici_bw=1e9, fp8_ops=0.0, gemm_launch_s=1e-4,
           collective_launch_s=3e-4),
        **hw_over,
    )
    return Calibration(**live_key(), hw=hw).with_blocks(blocks or {})


# --------------------------------------------------------------- the cache


def test_cache_roundtrip(tmp_path):
    cal = make_cal({
        block_key("kernel", "real", 256, 256, 512): (128, 128, 256),
        block_key("fused", "complex", 2048, 2048, 2048): (512, 512, 512),
    })
    path = save_calibration(cal, str(tmp_path / "cal.json"))
    loaded = load_calibration(path)
    assert loaded == cal
    assert hash(loaded) == hash(cal)  # frozen: rides in jit statics
    assert calibration_hash(loaded) == calibration_hash(cal)
    assert loaded.block_for("kernel/real/m256n256k512") == (128, 128, 256)
    assert loaded.block_for("kernel/real/m128n128k128") is None


def test_cache_stale_key_warns_and_falls_back(tmp_path):
    cal = make_cal()
    path = str(tmp_path / "cal.json")
    save_calibration(cal, path)
    obj = json.load(open(path))
    obj["key"]["device_count"] += 7  # measured on a different machine
    json.dump(obj, open(path, "w"))
    with pytest.warns(RuntimeWarning, match="stale"):
        assert load_calibration(path) is None
    # staleness check is opt-out for offline inspection
    assert load_calibration(path, check_staleness=False) is not None


@pytest.mark.parametrize("payload", [
    "definitely not json {",
    json.dumps({"schema": 1}),                      # missing key/hw
    json.dumps({"schema": 99, "key": {}, "hw": {}}),  # wrong schema
])
def test_cache_corruption_warns_and_falls_back(tmp_path, payload):
    path = tmp_path / "cal.json"
    path.write_text(payload)
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert load_calibration(str(path)) is None


def test_cache_malformed_blocks_rejected(tmp_path):
    cal = make_cal()
    path = str(tmp_path / "cal.json")
    save_calibration(cal, path)
    obj = json.load(open(path))
    obj["blocks"] = {"kernel/real/m128n128k128": [256, -1, 0]}
    json.dump(obj, open(path, "w"))
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert load_calibration(path) is None


def test_cache_missing_file_warns_none(tmp_path):
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert load_calibration(str(tmp_path / "nope.json")) is None


def test_default_cache_path_respects_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CALIBRATION_DIR", str(tmp_path))
    p = default_cache_path()
    assert p.startswith(str(tmp_path))
    assert p.endswith(".json")


def test_shape_bucketing():
    assert shape_bucket(1, 1, 1) == "m128n128k128"       # floor: MXU tile
    assert shape_bucket(129, 256, 300) == "m256n256k512"  # round up pow2
    assert shape_bucket(10**6, 1, 1).startswith("m16384")  # cap
    with pytest.raises(ValueError):
        block_key("nope", "real", 1, 1, 1)
    with pytest.raises(ValueError):
        block_key("kernel", "int8", 1, 1, 1)


# ------------------------------------------------------------------ scoping


def test_scoping_thread_local_beats_global():
    from repro.tune.cache import current_calibration

    a, b = make_cal(), make_cal(mem_bw=2e10)
    assert current_calibration() is None
    try:
        set_calibration(a)
        assert current_calibration() == a
        with use_calibration(b):
            assert current_calibration() == b  # innermost wins
        assert current_calibration() == a
    finally:
        set_calibration(None)
    assert current_calibration() is None


def test_use_calibration_from_unfit_path_is_noop(tmp_path):
    from repro.tune.cache import current_calibration

    bad = tmp_path / "bad.json"
    bad.write_text("{")
    with pytest.warns(RuntimeWarning):
        with use_calibration(str(bad)):
            assert current_calibration() is None  # degraded, not broken


# ------------------------------------- measured HW drives 'auto' decisions


def test_default_hw_is_preset_without_calibration():
    assert perfmodel.default_hw() is TPU_V5E


def test_default_hw_follows_active_calibration():
    cal = make_cal()
    with use_calibration(cal):
        assert perfmodel.default_hw() == cal.hw
    assert perfmodel.default_hw() is TPU_V5E


def test_calibrated_hw_flips_engine_auto_selection():
    """An fp8-rich measured HW flips select_engine — the smoke proof that
    'auto' decisions really price against the measurement, not the preset."""
    shape = (4096, 4096, 4096, 14)
    assert perfmodel.select_engine(*shape) == "int8"  # v5e has no fp8 MXU
    fp8_rich = make_cal(fp8_ops=100 * 5e12)
    with use_calibration(fp8_rich):
        assert perfmodel.select_engine(*shape) == "fp8"
    assert perfmodel.select_engine(*shape) == "int8"


def test_pinned_policy_calibration_is_deterministic(tmp_path):
    """GemmPolicy(calibration=path): same plan on every call, identical to
    the plan under an ambient use_calibration of the same cache — and the
    pin beats a different ambient calibration (no scope leakage into the
    jit-static plan)."""
    cal = make_cal(mem_bw=1e9, gemm_launch_s=5e-3)  # launch-dominated
    path = save_calibration(cal, str(tmp_path / "cal.json"))
    base = dict(backend="ozaki2_c64", n_moduli=5, formulation="auto",
                n_block="auto")
    pinned = GemmPolicy(calibration=path, **base)
    plan1 = pinned.plan_for(96, 96, 96)
    plan2 = pinned.plan_for(96, 96, 96)
    assert plan1 == plan2
    with use_calibration(cal):
        ambient_plan = GemmPolicy(**base).plan_for(96, 96, 96)
    assert plan1 == ambient_plan
    other = make_cal(mem_bw=9e14, int8_ops=9e15, gemm_launch_s=1e-9)
    with use_calibration(other):
        assert pinned.plan_for(96, 96, 96) == plan1


def test_policy_pinned_unfit_cache_degrades(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("broken")
    with pytest.warns(RuntimeWarning):
        pol = GemmPolicy(backend="ozaki2_c64", n_moduli=5,
                         formulation="auto", calibration=str(bad))
        plan = pol.plan_for(64, 64, 64)
    ref = GemmPolicy(backend="ozaki2_c64", n_moduli=5,
                     formulation="auto").plan_for(64, 64, 64)
    assert plan == ref  # unfit pin == no pin == presets


# ----------------------------------------- tuned blocks: resolution + parity


def test_resolve_blocks_defaults_without_calibration():
    assert resolve_blocks("kernel", "real", 300, 300, 300) == \
        DEFAULT_GEMM_BLOCKS


def test_resolve_blocks_reads_tuned_and_respects_overrides():
    key = block_key("kernel", "real", 300, 300, 300)
    cal = make_cal({key: (128, 128, 256)})
    with use_calibration(cal):
        assert resolve_blocks("kernel", "real", 300, 300, 300) == \
            (128, 128, 256)
        # explicit per-axis args always beat the tuned winner
        assert resolve_blocks("kernel", "real", 300, 300, 300, bm=64) == \
            (64, 128, 256)
        assert resolve_blocks(
            "kernel", "real", 300, 300, 300, bm=1, bn=2, bk=3
        ) == (1, 2, 3)
        # a slot the cache does not cover falls back to the static default
        assert resolve_blocks("fused", "real", 300, 300, 300) == \
            DEFAULT_GEMM_BLOCKS
    assert resolve_blocks("kernel", "real", 300, 300, 300) == \
        DEFAULT_GEMM_BLOCKS


def test_no_calibration_kernel_blocks_are_the_static_defaults(rng):
    """No cache present => the batched kernel runs exactly the static
    default blocks: bitwise identity against an explicit (256, 256, 512)
    call (the pre-calibration behaviour)."""
    from repro.core.moduli import make_crt_context
    from repro.kernels.int8_mod_gemm import int8_mod_gemm_batched

    ctx = make_crt_context(5)
    a = jnp.asarray(rng.integers(-60, 61, (5, 40, 72), dtype=np.int8))
    b = jnp.asarray(rng.integers(-60, 61, (5, 72, 56), dtype=np.int8))
    y_auto = int8_mod_gemm_batched(a, b, moduli=ctx.moduli, interpret=True)
    y_static = int8_mod_gemm_batched(
        a, b, moduli=ctx.moduli, bm=256, bn=256, bk=512, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(y_auto), np.asarray(y_static))


def test_tuned_blocks_never_change_numerics_kernel(rng):
    """Pad-and-slice: a tuned block shape on a non-divisible shape is
    bitwise identical to the default — the autotuner only trades speed."""
    from repro.core.moduli import make_crt_context
    from repro.kernels.int8_mod_gemm import int8_mod_gemm_batched

    ctx = make_crt_context(5)
    m, k, n = 40, 72, 56  # nothing divides the 32-tile evenly
    a = jnp.asarray(rng.integers(-60, 61, (5, m, k), dtype=np.int8))
    b = jnp.asarray(rng.integers(-60, 61, (5, k, n), dtype=np.int8))
    y_default = int8_mod_gemm_batched(a, b, moduli=ctx.moduli,
                                      interpret=True)
    cal = make_cal({block_key("kernel", "real", m, n, k): (32, 32, 32)})
    with use_calibration(cal):
        assert resolve_blocks("kernel", "real", m, n, k) == (32, 32, 32)
        y_tuned = int8_mod_gemm_batched(a, b, moduli=ctx.moduli,
                                        interpret=True)
    np.testing.assert_array_equal(np.asarray(y_default), np.asarray(y_tuned))


def test_tuned_blocks_never_change_numerics_fused(rng):
    from repro.core.moduli import make_crt_context
    from repro.core.plan import n_limbs_for_ctx
    from repro.kernels.int8_mod_gemm import fused_mod_gemm

    ctx = make_crt_context(4)
    n_limbs = n_limbs_for_ctx(ctx)
    m, k, n = 40, 72, 56
    a = jnp.asarray(rng.integers(-500, 501, (m, k)), jnp.float32)
    b = jnp.asarray(rng.integers(-500, 501, (k, n)), jnp.float32)
    e_mu = jnp.zeros((m,), jnp.int32)
    e_nu = jnp.zeros((n,), jnp.int32)
    y_default = fused_mod_gemm(a, b, e_mu, e_nu, ctx, n_limbs=n_limbs,
                               interpret=True)
    cal = make_cal({block_key("fused", "real", m, n, k): (32, 32, 32)})
    with use_calibration(cal):
        y_tuned = fused_mod_gemm(a, b, e_mu, e_nu, ctx, n_limbs=n_limbs,
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(y_default), np.asarray(y_tuned))


def test_tuned_blocks_bitwise_through_the_policy_route(rng):
    """End to end: linalg.matmul on the kernel execution under a tuned
    calibration scope == the same matmul with no calibration, bitwise."""
    from repro import linalg

    m, k, n = 40, 72, 56
    a = jnp.asarray(phi_matrix(rng, (m, k), 0.5, np.float32))
    b = jnp.asarray(phi_matrix(rng, (k, n), 0.5, np.float32))
    pol = GemmPolicy(backend="ozaki2_f32", n_moduli=5, execution="kernel",
                     interpret=True)
    y_default = linalg.matmul(a, b, policy=pol)
    cal = make_cal({
        block_key("kernel", "real", m, n, k): (32, 32, 32),
        block_key("fused", "real", m, n, k): (32, 32, 32),
    })
    with use_calibration(cal):
        y_tuned = linalg.matmul(a, b, policy=pol)
    np.testing.assert_array_equal(np.asarray(y_default), np.asarray(y_tuned))


# ------------------------------------------- bench record hygiene + compare


def _rec(name="sgemm/fast/48", execution="kernel", mesh="1", devices=1,
         tflops=1.0, calibration=None):
    return {
        "name": name, "execution": execution, "mesh": mesh,
        "devices": devices, "us_per_call": 10.0,
        "tflops_aggregate": tflops * devices,
        "tflops_per_device": tflops, "calibration": calibration,
    }


def test_bench_merge_replaces_rekeys_and_dedupes():
    from benchmarks.bench_throughput import merge_records, record_key

    old = [
        _rec(tflops=1.0),             # duplicate pair: same key twice —
        _rec(tflops=2.0),             # the later record must win the dedupe
        _rec(execution="fused", tflops=3.0),
    ]
    new = [_rec(tflops=9.0)]
    merged = merge_records(old, new)
    keys = [record_key(r) for r in merged]
    assert len(keys) == len(set(keys)) == 2  # deduped + replaced
    by_key = {record_key(r): r for r in merged}
    assert by_key[record_key(new[0])]["tflops_per_device"] == 9.0
    assert by_key[record_key(old[2])]["tflops_per_device"] == 3.0


def test_bench_merge_calibration_stamp_separates_trajectories():
    from benchmarks.bench_throughput import merge_records

    old = [_rec(tflops=1.0, calibration=None)]
    new = [_rec(tflops=2.0, calibration="abc123def456")]
    merged = merge_records(old, new)
    assert len(merged) == 2  # tuned run never clobbers the untuned baseline


def test_bench_merge_refuses_unkeyed_without_force():
    from benchmarks.bench_throughput import merge_records

    old = [{"legacy": True}]
    with pytest.raises(SystemExit):
        merge_records(old, [_rec()])
    assert merge_records(old, [_rec()], force=True) == [_rec()]


def test_bench_compare_flags_only_real_regressions():
    from benchmarks.bench_throughput import compare_records

    baseline = [
        _rec(tflops=0.8),
        _rec(tflops=1.0),  # duplicate: the baseline bar is the max
        _rec(execution="fused", tflops=2.0),
    ]
    ok = [_rec(tflops=0.9)]  # -10%: inside the 15% tolerance
    assert compare_records(ok, baseline) == []
    slow = [_rec(tflops=0.5)]  # -50%: regression
    out = compare_records(slow, baseline)
    assert len(out) == 1 and "0.5" in out[0]
    # tuned records are held to the untuned bar (stamp ignored in matching)
    tuned_slow = [_rec(tflops=0.5, calibration="abc123def456")]
    assert len(compare_records(tuned_slow, baseline)) == 1
    # configs absent from the baseline are new coverage, not regressions
    novel = [_rec(execution="fp8", tflops=0.001)]
    assert compare_records(novel, baseline) == []
    # tolerance is a knob
    assert compare_records(ok, baseline, tolerance=0.01) != []
