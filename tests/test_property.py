"""Hypothesis property tests on the system's numeric invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dependency: property tests need hypothesis"
)
from hypothesis import given, settings, strategies as st

from repro.core.moduli import make_crt_context
from repro.core.residues import (
    residues_from_quantized,
    split_limbs,
    sym_mod_int32,
    sym_mod_small,
)
from repro.core import crt

SET = settings(max_examples=25, deadline=None)


@given(
    st.integers(min_value=-(2**60), max_value=2**60),
    st.integers(min_value=0, max_value=19),
)
@SET
def test_residue_of_any_integer_is_exact(x, mod_idx):
    """Residue extraction via limb split == exact Python mod, for any
    f64-representable integer."""
    ctx = make_crt_context(20)
    p = ctx.moduli[mod_idx]
    xf = float(x)
    if int(xf) != x:  # keep only exactly-representable ints
        x = int(xf)
    arr = jnp.asarray([[xf]], jnp.float64)
    res = residues_from_quantized(arr, ctx, n_limbs=3)
    r = int(res[mod_idx, 0, 0])
    assert (r - x) % p == 0
    assert abs(r) <= (p - 1) // 2


@given(st.integers(min_value=-(2**62), max_value=2**62), st.integers(2, 5))
@SET
def test_split_limbs_reconstructs(x, n_limbs):
    xf = float(x)
    x = int(xf)
    if abs(x) >= 2 ** (24 * n_limbs):
        return
    limbs = np.asarray(split_limbs(jnp.asarray([xf], jnp.float64), n_limbs))
    val = sum(int(limbs[i, 0]) * (1 << (24 * i)) for i in range(n_limbs))
    assert val == x


@given(
    st.integers(min_value=-(2**31) + 1, max_value=2**31 - 1),
    st.sampled_from([3, 127, 199, 251, 255]),
)
@SET
def test_sym_mod_int32(v, p):
    r = int(sym_mod_int32(jnp.asarray([v], jnp.int32), p)[0])
    assert (r - v) % p == 0
    assert abs(r) <= (p - 1) // 2


@given(
    st.integers(min_value=-(2**17), max_value=2**17),
    st.sampled_from([3, 127, 199, 251, 255]),
)
@SET
def test_sym_mod_small_f32(v, p):
    r = int(np.asarray(sym_mod_small(jnp.asarray([float(v)], jnp.float32), float(p), float((p - 1) // 2)))[0])
    assert (r - v) % p == 0
    assert abs(r) <= (p - 1) // 2


@given(st.data())
@settings(max_examples=15, deadline=None)
def test_crt_roundtrip_random_integers(data):
    """Any integer |x| < P/2: residues -> (garner|paper|dd) -> x exactly."""
    n = data.draw(st.integers(min_value=2, max_value=16))
    ctx = make_crt_context(n)
    # condition (4) keeps |C'| strictly below P/2 with >= 2 bits of scaling
    # slack; draw within 49% of P (the boundary itself is unreachable)
    half = int(ctx.P * 0.49)
    x = data.draw(st.integers(min_value=-half, max_value=half))
    e = np.zeros((n, 1, 1), np.int8)
    for l, p in enumerate(ctx.moduli):
        r = x % p
        if r > (p - 1) // 2:
            r -= p
        e[l, 0, 0] = r
    # absolute error floors (in C' units): garner converts digits MS-first
    # (~P*2^-100); dd accumulates N products of ~P*127 (~P*2^-93); the paper
    # eq.(5) split keeps ~P*2^-80 (w_lo parts are rounded doubles).  All are
    # far below the scheme's truncation floor (DESIGN.md S2).
    tols = {"garner": 2.0**-100, "dd": 2.0**-93, "paper": 2.0**-78}
    for method in ("garner", "dd", "paper"):
        hi, lo = crt.reconstruct(jnp.asarray(e), ctx, method)
        got = float(hi[0, 0]) + float(lo[0, 0])
        tol = max(abs(x) * 2.0**-90, float(ctx.P) * tols[method], 1e-9)
        assert abs(got - float(x)) <= tol, (method, n, x, got)


@given(
    st.floats(0.0, 3.0),
    st.integers(min_value=0, max_value=2**31 - 1),
    st.sampled_from([8, 12, 16]),
)
@settings(max_examples=10, deadline=None)
def test_condition4_fast_mode(phi, seed, n_mod):
    """The uniqueness condition (4): 2 sum_h |a'||b'| < P must hold for the
    fast-mode scaling across random dynamic ranges (else CRT is ambiguous
    and the whole scheme silently corrupts)."""
    import jax.numpy as jnp

    from repro.core import scaling
    from repro.core.residues import quantize

    ctx = make_crt_context(n_mod)
    rng = np.random.default_rng(seed)
    a = (rng.random((8, 48)) - 0.5) * np.exp(rng.standard_normal((8, 48)) * phi)
    b = (rng.random((48, 6)) - 0.5) * np.exp(rng.standard_normal((48, 6)) * phi)
    e_mu, e_nu = scaling.scale_fast_real(jnp.asarray(a), jnp.asarray(b), ctx)
    aq = np.asarray(quantize(jnp.asarray(a), scaling.exp2_vector(e_mu), 0))
    bq = np.asarray(quantize(jnp.asarray(b), scaling.exp2_vector(e_nu), 1))
    ai = np.vectorize(int, otypes=[object])(np.abs(aq))
    bi = np.vectorize(int, otypes=[object])(np.abs(bq))
    bound = ai @ bi
    assert all(2 * int(v) < ctx.P for v in bound.ravel())


@given(
    st.floats(-1e6, 1e6, allow_subnormal=False),
    st.floats(-1e6, 1e6, allow_subnormal=False),
)
@SET
def test_two_sum_exact(a, b):
    from repro.core.expansion import two_sum

    s, e = two_sum(jnp.float64(a), jnp.float64(b))
    # two_sum is exact: s + e == a + b with s = fl(a+b)
    import math

    from fractions import Fraction

    assert Fraction(float(s)) + Fraction(float(e)) == Fraction(a) + Fraction(b)
    assert float(s) == a + b


@given(
    st.floats(-1e15, 1e15, allow_subnormal=False),
    st.floats(-1e15, 1e15, allow_subnormal=False),
)
@SET
def test_two_prod_exact(a, b):
    from fractions import Fraction

    from hypothesis import assume

    from repro.core.expansion import two_prod

    # two_prod's error-free guarantee requires no under/overflow of a*b
    assume(a == 0 or b == 0 or 1e-280 < abs(a * b) < 1e280)
    p, e = two_prod(jnp.float64(a), jnp.float64(b))
    assert Fraction(float(p)) + Fraction(float(e)) == Fraction(a) * Fraction(b)


# ------------------------------- perfmodel block selection (repro.tune base)


def _round_up(x, m):
    return -(-x // m) * m


@given(
    st.integers(min_value=1, max_value=5000),
    st.sampled_from([8, 32, 64, 128, 192, 256, 384, 512, 1024]),
    st.sampled_from([None, 8, 32, 128]),
)
@SET
def test_select_block_divides_padding(dim, block, align):
    """The selected block always divides the padded dim, and shrinking never
    pads MORE than the static default block would — the two invariants the
    pad-and-slice kernels (and so the autotuner's safety argument) rest on."""
    from repro.core import perfmodel

    b = perfmodel.select_block(dim, block, align)
    pad = perfmodel.padded_dim(dim, block, align)
    assert b >= 1
    assert pad % b == 0, f"block {b} does not divide padded dim {pad}"
    assert pad >= dim
    assert pad <= _round_up(dim, block), (
        f"shrunk block {b} pads {dim}->{pad}, worse than the static "
        f"block {block}'s {_round_up(dim, block)}"
    )
    # an aligned request stays aligned unless the dim itself is smaller
    if align is not None and block % align == 0 and dim > block:
        assert b % align == 0 or b == dim


@given(
    st.integers(min_value=1, max_value=5000),
    st.sampled_from([8, 32, 64, 128, 192, 256, 384, 512, 1024]),
    st.sampled_from([None, 8, 32, 128]),
)
@SET
def test_select_block_small_dim_is_exact(dim, block, align):
    """A dim no larger than the block never pads at all (block == dim)."""
    from repro.core import perfmodel

    if dim <= block:
        assert perfmodel.select_block(dim, block, align) == dim
        assert perfmodel.padded_dim(dim, block, align) == dim


def test_select_block_rejects_degenerate():
    from repro.core import perfmodel

    with pytest.raises(ValueError):
        perfmodel.select_block(0, 256, 128)
    with pytest.raises(ValueError):
        perfmodel.select_block(-3, 256, 128)
    with pytest.raises(ValueError):
        perfmodel.select_block(64, 0, 128)
