"""The FP8 (e4m3) residue engine — `GemmPolicy(execution="fp8")`.

What this file guarantees:

  * `kernels/fp8_mod_gemm.fp8_mod_gemm_batched` — residues split into
    balanced base-16 digits (exact in e4m3), three fp8 GEMMs per plane,
    per-plane rescale in the epilogue — is **bitwise identical** to the
    int8 engine (`int8_mod_gemm_batched`) including the carry input,
    ragged shapes, traced moduli, and K-chunking at its tighter f32
    accumulator bound (`FP8_K_CHUNK_LIMIT`).
  * the policy route: ``execution="fp8"`` through `repro.linalg.matmul`
    runs end-to-end for all four dtypes x {fast, accu} x all complex
    formulations, bitwise equal to ``execution="kernel"`` everywhere (the
    first non-int8 engine through the residue-backend protocol), with
    CI-pinned accuracy bands vs the exact reference product.
  * prepared weights and gradients ride the same backend seam unchanged.
  * `perfmodel` prices the engine: `ENGINE_OP_FACTOR`/`engine_rate` feed
    ``formulation="auto"`` via `GemmPolicy.plan_for`, and `select_engine`
    picks int8/fp8 per shape and hardware.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import FAST_K, FAST_M, FAST_N, phi_matrix
import repro
from repro import linalg
from repro.core import GemmPolicy, perfmodel
from repro.core.executor import Fp8Backend, chunked_residue_matmul
from repro.core.moduli import make_crt_context
from repro.core.policy import BACKEND_FOR_DTYPE, policy_matmul, prepare_weights
from repro.kernels import (
    FP8_K_CHUNK_LIMIT,
    count_pallas_launches,
    fp8_mod_gemm_batched,
    int8_mod_gemm_batched,
)

M, K, N = FAST_M, FAST_K, FAST_N

DTYPES = [np.float32, np.float64, np.complex64, np.complex128]
# small moduli counts keep the interpret-mode sweeps fast; engine parity is
# independent of N (the digit split is per-residue)
N_MODULI = {"float32": 5, "float64": 6, "complex64": 5, "complex128": 6}
F32_GRADE = ("float32", "complex64")

# CI-pinned max-relative-error bands of the fp8 execution vs the exact
# product, at the default per-dtype moduli counts.  The engine is exact, so
# these are the *pipeline's* bands: f32-grade quantization (the kernel cast
# goes through f32) bounds every dtype at ~2^-24; fast mode's Cauchy-Schwarz
# scaling is looser than accu's eq. 13-14 bound.  Identical to the int8
# kernel path's bands by bitwise parity (asserted separately).
ACCURACY_BAND = {"fast": 5e-6, "accu": 5e-6}


def _policy(dtype, execution, **kw):
    name = np.dtype(dtype).name
    kw.setdefault("n_moduli", N_MODULI[name])
    kw.setdefault("interpret", True)
    return GemmPolicy(backend=BACKEND_FOR_DTYPE[name], execution=execution, **kw)


def _operands(rng, dtype, shape_a=(M, K), shape_b=(K, N)):
    x = jnp.asarray(phi_matrix(rng, shape_a, 0.5, dtype))
    w = jnp.asarray(phi_matrix(rng, shape_b, 0.5, dtype))
    return x, w


def _residue_planes(rng, ctx, *shape):
    half = np.asarray(ctx.half_arr)
    return np.stack(
        [rng.integers(-h, h + 1, shape) for h in half]
    ).astype(np.int8)


# ===================================================== kernel-level parity


@pytest.mark.parametrize("shape", [(32, 64, 16), (33, 97, 25), (1, 31, 129)])
def test_fp8_kernel_bitwise_vs_int8(rng, shape):
    """The digit-split fp8 GEMM is exact: bitwise == the int8 engine on
    aligned and ragged shapes (pad-and-slice is residue-exact)."""
    m, k, n = shape
    ctx = make_crt_context(5)
    a = jnp.asarray(_residue_planes(rng, ctx, m, k))
    b = jnp.asarray(_residue_planes(rng, ctx, k, n))
    ref = int8_mod_gemm_batched(a, b, moduli=ctx.moduli, interpret=True)
    out = fp8_mod_gemm_batched(a, b, moduli=ctx.moduli, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_fp8_kernel_carry_and_traced_moduli(rng):
    """The chunk-carry epilogue and the traced-moduli (sharded-style) entry
    both stay bitwise-exact on the fp8 engine."""
    ctx = make_crt_context(4)
    a = jnp.asarray(_residue_planes(rng, ctx, 16, 48))
    b = jnp.asarray(_residue_planes(rng, ctx, 48, 24))
    carry = jnp.asarray(_residue_planes(rng, ctx, 16, 24))
    ref = int8_mod_gemm_batched(
        a, b, moduli=ctx.moduli, carry=carry, interpret=True
    )
    out = fp8_mod_gemm_batched(
        a, b, moduli=ctx.moduli, carry=carry, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    traced = fp8_mod_gemm_batched(
        a, b, moduli=jnp.asarray(ctx.moduli_arr), carry=carry, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(traced))


def test_fp8_chunked_matches_unchunked(rng):
    """`chunked_residue_matmul` at the fp8 engine's chunk limit: forcing a
    tiny chunk (many carry-epilogue launches) reproduces the one-launch
    result bitwise — the chunk combine happens in the residue ring."""
    ctx = make_crt_context(4)
    a = jnp.asarray(_residue_planes(rng, ctx, 8, 100))
    b = jnp.asarray(_residue_planes(rng, ctx, 100, 8))

    def gemm(x, y, carry):
        return fp8_mod_gemm_batched(
            x, y, moduli=ctx.moduli, carry=carry, interpret=True
        )

    one = chunked_residue_matmul(gemm, a, b, ctx, carry_epilogue=True)
    many = chunked_residue_matmul(
        gemm, a, b, ctx, carry_epilogue=True, chunk_limit=32
    )
    np.testing.assert_array_equal(np.asarray(one), np.asarray(many))


def test_fp8_kernel_rejects_oversized_k(rng):
    """A single launch must refuse K beyond the f32 digit-accumulator bound
    (the backend chunks instead of silently losing exactness)."""
    ctx = make_crt_context(2)
    a = jnp.zeros((2, 8, FP8_K_CHUNK_LIMIT + 32), jnp.int8)
    b = jnp.zeros((2, FP8_K_CHUNK_LIMIT + 32, 8), jnp.int8)
    with pytest.raises(ValueError, match="chunk"):
        fp8_mod_gemm_batched(a, b, moduli=ctx.moduli, interpret=True)


# ===================================================== policy-route parity


@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fp8_execution_parity(rng, dtype, mode):
    """Tentpole: execution="fp8" is bitwise identical to execution="kernel"
    for every dtype x mode — the engine changes, the numbers don't (casts
    and Garner reconstruction are shared; the digit products are exact)."""
    x, w = _operands(rng, dtype)
    yk = np.asarray(policy_matmul(x, w, _policy(dtype, "kernel", mode=mode)))
    yf = np.asarray(policy_matmul(x, w, _policy(dtype, "fp8", mode=mode)))
    np.testing.assert_array_equal(yk, yf)
    if np.dtype(dtype).name in F32_GRADE:
        yr = np.asarray(
            policy_matmul(x, w, _policy(dtype, "reference", mode=mode))
        )
        np.testing.assert_array_equal(yf, yr)


@pytest.mark.parametrize("formulation", ["karatsuba", "block_a", "block_b"])
def test_fp8_complex_formulations(rng, formulation):
    """All three Fig. 1 strategies run on the fp8 engine (Karatsuba is
    composed from 3 fp8 products — no fused kernel) and bit-match the int8
    kernel path under the same formulation."""
    x, w = _operands(rng, np.complex64)
    yk = np.asarray(
        policy_matmul(x, w, _policy(np.complex64, "kernel", formulation=formulation))
    )
    yf = np.asarray(
        policy_matmul(x, w, _policy(np.complex64, "fp8", formulation=formulation))
    )
    np.testing.assert_array_equal(yk, yf)


@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fp8_accuracy_bands(rng, dtype, mode):
    """End-to-end through `repro.linalg.matmul` at the default per-dtype
    moduli counts: the fp8 execution's max relative error vs the exact
    product stays inside the CI-pinned band (and equals the int8 kernel
    path's error exactly, by engine parity)."""
    x, w = _operands(rng, dtype)
    pol = GemmPolicy(
        backend=BACKEND_FOR_DTYPE[np.dtype(dtype).name],
        execution="fp8",
        mode=mode,
        interpret=True,
    )
    with repro.use_policy(pol):
        y = np.asarray(linalg.matmul(x, w))
    if np.issubdtype(np.dtype(dtype), np.complexfloating):
        ref = np.asarray(x, np.clongdouble) @ np.asarray(w, np.clongdouble)
    else:
        ref = np.asarray(x, np.longdouble) @ np.asarray(w, np.longdouble)
    err = float(np.max(np.abs(y - ref)) / np.max(np.abs(ref)))
    assert err < ACCURACY_BAND[mode], (np.dtype(dtype).name, mode, err)
    yk = np.asarray(
        linalg.matmul(x, w, policy=dataclasses.replace(pol, execution="kernel"))
    )
    np.testing.assert_array_equal(y, yk)


def test_fp8_prepared_weights_parity(rng):
    """`prepare_weights` under an fp8 policy casts with the fp8 backend's
    (shared) kernel cast, so prepared serving is bit-identical to the direct
    fp8 run — the backend seam covers the prepared path too."""
    x, w = _operands(rng, np.float32)
    pol = _policy(np.float32, "fp8")
    direct = np.asarray(policy_matmul(x, w, pol))
    tree = prepare_weights({"w": w}, pol)
    prepped = np.asarray(policy_matmul(x, tree["w"], pol))
    np.testing.assert_array_equal(direct, prepped)


def test_fp8_grad_matches_kernel(rng):
    """The custom VJP routes cotangent products through the same execution
    backend: grads under fp8 are bitwise those of the kernel path."""
    x, w = _operands(rng, np.float32)

    def loss(pol):
        return lambda a, b: jnp.sum(jnp.abs(policy_matmul(a, b, pol)) ** 2)

    gk = jax.grad(loss(_policy(np.float32, "kernel")), argnums=(0, 1))(x, w)
    gf = jax.grad(loss(_policy(np.float32, "fp8")), argnums=(0, 1))(x, w)
    for a, b in zip(gk, gf):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fp8_launch_counts(rng):
    """The fp8 path keeps the batched launch economics: 4 launches for a
    real GEMM (cast, cast, product, reconstruct) — and, since the fused
    fp8 Karatsuba kernel landed, the complex triple shares ONE launch per
    K-chunk (`fused_karatsuba=True`, the capability `Fp8Backend` now
    declares): 4 launches for complex too."""
    x, w = _operands(rng, np.float32)
    pol = _policy(np.float32, "fp8")
    n = count_pallas_launches(lambda a, b: policy_matmul(a, b, pol), x, w)
    assert n == perfmodel.kernel_launch_count(
        pol.n_moduli, "real", modulus_batched=True
    ) == 4
    xc, wc = _operands(rng, np.complex64)
    polc = _policy(np.complex64, "fp8", formulation="karatsuba")
    nc = count_pallas_launches(lambda a, b: policy_matmul(a, b, polc), xc, wc)
    assert nc == perfmodel.kernel_launch_count(
        polc.n_moduli, "karatsuba", modulus_batched=True, fused_karatsuba=True
    ) == 4


# ===================================================== perfmodel pricing


def test_engine_pricing_volume_factor():
    """At equal engine rates the fp8 engine costs strictly more (4x MAC
    volume), so `select_engine` keeps int8; a >4x e4m3 rate flips it."""
    hw = perfmodel.B200  # fp8_ops == int8_ops
    m = n = k = 4096
    t_i8 = perfmodel.engine_time_s("int8", m, n, k, 14, hw)
    t_f8 = perfmodel.engine_time_s("fp8", m, n, k, 14, hw)
    assert t_f8 > t_i8
    assert perfmodel.select_engine(m, n, k, 14, hw) == "int8"
    fast_fp8 = dataclasses.replace(hw, fp8_ops=5.0 * hw.int8_ops)
    assert perfmodel.select_engine(m, n, k, 14, fast_fp8) == "fp8"
    # no-native-fp8 preset (v5e): the engine runs at the upconvert rate
    assert perfmodel.engine_rate(perfmodel.TPU_V5E, "fp8") == pytest.approx(
        perfmodel.TPU_V5E.int8_ops / 2
    )


def test_fp8_auto_formulation_prices_engine():
    """`plan_for` reads the backend's `engine` capability, so an fp8
    policy's formulation='auto' decision is made at e4m3 pricing: with the
    op term 8x heavier (4x volume at half rate on the v5e preset), the
    compute-heavy Karatsuba-vs-embedding crossover moves."""
    pol = GemmPolicy(
        backend="ozaki2_c64", execution="fp8", formulation="auto",
        n_moduli=5, interpret=True,
    )
    plan = pol.plan_for(64, 64, 64)
    assert plan.formulation in ("karatsuba", "block_a", "block_b")
    # the engine term is really threaded: the two engines price differently
    t_int8 = perfmodel.formulation_time_s(
        "karatsuba", 512, 512, 512, 5, perfmodel.TPU_V5E,
        modulus_batched=True, engine="int8",
    )
    t_fp8 = perfmodel.formulation_time_s(
        "karatsuba", 512, 512, 512, 5, perfmodel.TPU_V5E,
        modulus_batched=True, engine="fp8",
    )
    assert t_fp8 > t_int8


def test_fp8_backend_capabilities():
    """The protocol capabilities the policy/plan layers read off the
    backend: batched launches, fused fp8 Karatsuba, fp8 engine tag."""
    be = Fp8Backend(True)
    assert be.modulus_batched and be.fused_karatsuba
    assert be.engine == "fp8"
    assert hash(be) == hash(Fp8Backend(True))  # jit-static eligible
    pol = GemmPolicy(backend="ozaki2_f32", execution="fp8", interpret=True)
    assert isinstance(pol.execution_backend(), Fp8Backend)
