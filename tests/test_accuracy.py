"""Accuracy-adaptive emulation: the paper-bound certification harness.

What this file pins (PR 10 tentpole + satellites):

  * the `core.accuracy` bound calculator is *sound*: across random shapes,
    dynamic ranges, dtypes x {fast, accu} x complex formulations x moduli
    counts, the measured componentwise error of the policy-routed emulation
    never exceeds `rel_bound` (hypothesis property suite);
  * `min_moduli_for` is monotone in rtol and consistent with the forward
    bound (the returned N meets rtol, N-1 does not);
  * the pinned golden accuracy bands: `benchmarks.bench_accuracy`'s smoke
    sweep stays inside its per-(dtype, mode, n_moduli) `BANDS` and every
    record stays below its static bound (`check_records` == []);
  * `GemmPolicy(rtol=...)` / ``mode="auto"`` resolve plans that provably
    and measurably meet the requested tolerance, eager and under jit;
  * non-adaptive policies are bitwise unchanged by the adaptive machinery
    (rtol metadata must never perturb numerics);
  * the PreparedOperand drift bugfix: serving a weight prepared under one
    resolution with a policy that resolves differently raises a clear
    ValueError instead of silently computing at the wrong accuracy —
    end to end through `ServeEngine(prepare=True)`;
  * `analysis.AccuracyPass` certifies declared-rtol plans statically and
    flags plans whose bound cannot meet their declaration.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro
from repro import linalg
from repro.core import (
    GemmPolicy,
    GemmStats,
    make_plan,
    min_moduli_for,
    policy_matmul,
    prepare_weights,
    probe_operands,
    rel_bound,
    rel_error,
)
from repro.core.policy import BACKEND_FOR_DTYPE

from conftest import FAST_K, FAST_M, FAST_N, phi_matrix

M, K, N = FAST_M, FAST_K, FAST_N

DTYPES = ("float32", "float64", "complex64", "complex128")


def _ref_product(a, b):
    ld = (
        np.clongdouble
        if np.issubdtype(a.dtype, np.complexfloating)
        else np.longdouble
    )
    return a.astype(ld) @ b.astype(ld)


def _emulated(a, b, policy):
    return np.asarray(linalg.matmul(jnp.asarray(a), jnp.asarray(b), policy=policy))


# ===================================================== bound calculator


def test_rel_bound_monotone_in_n_moduli():
    for dtype in DTYPES:
        for mode in ("fast", "accu"):
            bounds = [rel_bound(dtype, mode, nm, K) for nm in range(2, 12)]
            assert bounds == sorted(bounds, reverse=True), (dtype, mode)


def test_rel_bound_validates_inputs():
    with pytest.raises(ValueError):
        rel_bound("float32", "fast", 0, K)
    with pytest.raises(ValueError):
        rel_bound("float32", "fast", 6, 0)
    with pytest.raises(ValueError):
        rel_bound("complex64", "fast", 6, K, formulation="nope")


def test_min_moduli_for_meets_and_is_minimal():
    for dtype in DTYPES:
        for mode in ("fast", "accu"):
            for rtol in (1e-2, 1e-5, 1e-8):
                try:
                    nm = min_moduli_for(rtol, dtype, k=K, mode=mode)
                except ValueError:
                    continue  # unreachable for this dtype: its own test below
                assert rel_bound(dtype, mode, nm, K) <= rtol
                if nm > 1:
                    assert rel_bound(dtype, mode, nm - 1, K) > rtol


def test_min_moduli_for_monotone_in_rtol():
    # reachable tolerances only: float32 bottoms out at its rounding floor
    for dtype, rtols in (
        ("float32", (1e-1, 1e-3, 1e-5, 1e-6)),
        ("float64", (1e-1, 1e-5, 1e-9, 1e-13)),
    ):
        ns = [min_moduli_for(r, dtype, k=K) for r in rtols]
        assert ns == sorted(ns)  # tighter tolerance never needs fewer moduli


def test_min_moduli_for_unreachable_raises():
    with pytest.raises(ValueError, match="unreachable"):
        min_moduli_for(1e-30, "float32", k=K)


def test_probe_stats_tighten_the_bound(rng):
    """Concrete-operand stats give a bound no looser than the static one."""
    a = phi_matrix(rng, (M, K), 0.5, np.float64)
    b = phi_matrix(rng, (K, N), 0.5, np.float64)
    stats = probe_operands(jnp.asarray(a), jnp.asarray(b))
    assert isinstance(stats, GemmStats) and stats.k == K
    for mode in ("fast", "accu"):
        probed = rel_bound("float64", mode, 8, K, stats=stats)
        static = rel_bound("float64", mode, 8, K)
        assert probed <= static


def test_probe_returns_none_for_tracers():
    out = []

    def f(a, b):
        out.append(probe_operands(a, b))
        return a @ b

    jax.make_jaxpr(f)(jnp.zeros((4, 8)), jnp.zeros((8, 2)))
    assert out == [None]


# ===================================================== property suite
#
# Only this section needs hypothesis (an optional dependency, installed in
# CI); everything else in the file must run without it.
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


if not HAVE_HYPOTHESIS:

    @pytest.mark.skip(reason="optional dependency: property tests need hypothesis")
    def test_property_suite_requires_hypothesis():
        pass


if HAVE_HYPOTHESIS:
    SET = settings(max_examples=15, deadline=None)

    @given(
        dtype=st.sampled_from(DTYPES),
        mode=st.sampled_from(["fast", "accu"]),
        n_extra=st.integers(min_value=0, max_value=3),
        phi=st.floats(min_value=0.0, max_value=2.5),
        m=st.integers(min_value=1, max_value=24),
        k=st.integers(min_value=1, max_value=96),
        n=st.integers(min_value=1, max_value=16),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    @SET
    def test_error_never_exceeds_bound(dtype, mode, n_extra, phi, m, k, n, seed):
        """The headline soundness property: measured componentwise error <=
        the probe-informed bound <= the static bound, across random shapes,
        dynamic ranges (phi), dtypes, modes and moduli counts."""
        rng = np.random.default_rng(seed)
        # small-but-working moduli counts around the tier-1 profile
        nm = {"float32": 4, "float64": 6, "complex64": 4, "complex128": 6}[dtype]
        nm += n_extra
        a = phi_matrix(rng, (m, k), phi, np.dtype(dtype))
        b = phi_matrix(rng, (k, n), phi, np.dtype(dtype))
        pol = GemmPolicy(backend=BACKEND_FOR_DTYPE[dtype], n_moduli=nm, mode=mode)
        c = _emulated(a, b, pol)
        ref = _ref_product(a, b)
        err = rel_error(c, ref, a, b)
        stats = probe_operands(jnp.asarray(a), jnp.asarray(b))
        probed = rel_bound(
            dtype, mode, nm, k, formulation=pol.formulation, stats=stats
        )
        static = rel_bound(dtype, mode, nm, k, formulation=pol.formulation)
        assert err <= probed <= static

    @given(
        formulation=st.sampled_from(["karatsuba", "block_a", "block_b"]),
        mode=st.sampled_from(["fast", "accu"]),
        nm=st.integers(min_value=4, max_value=7),
        phi=st.floats(min_value=0.0, max_value=1.5),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    @SET
    def test_error_within_bound_per_formulation(formulation, mode, nm, phi, seed):
        """Every complex product strategy (paper Fig. 1) stays within its
        formulation-factored bound."""
        rng = np.random.default_rng(seed)
        a = phi_matrix(rng, (16, 48), phi, np.complex64)
        b = phi_matrix(rng, (48, 12), phi, np.complex64)
        pol = GemmPolicy(
            backend="ozaki2_c64", n_moduli=nm, mode=mode, formulation=formulation
        )
        err = rel_error(_emulated(a, b, pol), _ref_product(a, b), a, b)
        assert err <= rel_bound("complex64", mode, nm, 48, formulation=formulation)

    @given(
        exp_a=st.integers(min_value=-8, max_value=8),
        exp_b=st.integers(min_value=-8, max_value=8),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    @SET
    def test_error_within_bound_across_scales(exp_a, exp_b, seed):
        """Exact-scaling invariance: wildly different operand magnitudes stay
        within the (scale-free) componentwise bound."""
        rng = np.random.default_rng(seed)
        a = phi_matrix(rng, (8, 32), 0.5, np.float32) * np.float32(10.0**exp_a)
        b = phi_matrix(rng, (32, 8), 0.5, np.float32) * np.float32(10.0**exp_b)
        pol = GemmPolicy(backend="ozaki2_f32", n_moduli=5)
        err = rel_error(_emulated(a, b, pol), _ref_product(a, b), a, b)
        assert err <= rel_bound("float32", "fast", 5, 32)

    @given(
        rtol=st.floats(min_value=1e-12, max_value=1e-2),
        k=st.integers(min_value=1, max_value=4096),
    )
    @SET
    def test_min_moduli_consistent_with_forward_bound(rtol, k):
        for dtype in ("float32", "complex128"):
            try:
                nm = min_moduli_for(rtol, dtype, k=k)
            except ValueError:
                continue  # tolerance unreachable at this k: allowed outcome
            assert rel_bound(dtype, "fast", nm, k) <= rtol


# ===================================================== golden bands (tier 1)


@pytest.fixture(scope="module")
def smoke_records():
    from benchmarks.bench_accuracy import SMOKE_SHAPE, SMOKE_SWEEP, sweep

    return sweep(SMOKE_SHAPE, SMOKE_SWEEP)


def test_smoke_sweep_within_pinned_bands(smoke_records):
    """The promoted Figs. 4-5 matrix: every cell below its static bound AND
    inside its pinned golden band; adaptive rows within their rtol."""
    from benchmarks.bench_accuracy import check_records

    assert check_records(smoke_records) == []


def test_smoke_records_keyed_like_throughput(smoke_records):
    """BENCH_accuracy.json shares bench_throughput's record-key contract."""
    from benchmarks.bench_throughput import merge_records, record_key

    keys = [record_key(r) for r in smoke_records]
    assert all(k is not None for k in keys)
    assert len(set(keys)) == len(keys)  # distinct trajectories per cell
    # merging a re-run replaces exactly the re-measured keys
    merged = merge_records(smoke_records, smoke_records[:3])
    assert len(merged) == len(smoke_records)


def test_committed_accuracy_trajectory_is_fresh():
    """The tracked BENCH_accuracy.json must hold the smoke sweep's keys and
    pass the same certification the live sweep does."""
    import json
    from pathlib import Path

    from benchmarks.bench_accuracy import check_records

    path = Path(__file__).resolve().parents[1] / "BENCH_accuracy.json"
    records = json.loads(path.read_text())["records"]
    assert records, "BENCH_accuracy.json has no records"
    assert check_records(records) == []


# ===================================================== adaptive policies


def test_rtol_policy_measurably_meets_tolerance(rng):
    for dtype, rtol in (("float32", 1e-4), ("complex128", 1e-9)):
        a = phi_matrix(rng, (M, K), 0.5, np.dtype(dtype))
        b = phi_matrix(rng, (K, N), 0.5, np.dtype(dtype))
        pol = GemmPolicy(backend=BACKEND_FOR_DTYPE[dtype], rtol=rtol)
        resolved = pol.resolve_adaptive(M, K, N)
        assert rel_bound(
            dtype, resolved.mode, resolved.n_moduli, K,
            formulation=resolved.formulation,
        ) <= rtol
        err = rel_error(_emulated(a, b, pol), _ref_product(a, b), a, b)
        assert err <= rtol


def test_mode_auto_resolves_cheapest_and_meets_rtol(rng):
    a = phi_matrix(rng, (M, K), 0.5, np.float64)
    b = phi_matrix(rng, (K, N), 0.5, np.float64)
    pol = GemmPolicy(backend="ozaki2_f64", mode="auto", rtol=1e-6)
    resolved = pol.resolve_adaptive(M, K, N)
    assert resolved.mode in ("fast", "accu")
    assert not resolved.is_adaptive  # fixed point: resolution is idempotent
    assert resolved.resolve_adaptive(M, K, N) is resolved
    err = rel_error(_emulated(a, b, pol), _ref_product(a, b), a, b)
    assert err <= 1e-6
    # a looser tolerance never needs more moduli
    looser = dataclasses.replace(pol, rtol=1e-3).resolve_adaptive(M, K, N)
    assert looser.n_moduli <= resolved.n_moduli


def test_mode_auto_requires_rtol():
    with pytest.raises(ValueError, match="rtol"):
        GemmPolicy(backend="ozaki2_f32", mode="auto")


def test_adaptive_eager_vs_jit_identical(rng):
    a = jnp.asarray(phi_matrix(rng, (M, K), 0.5, np.float64))
    b = jnp.asarray(phi_matrix(rng, (K, N), 0.5, np.float64))
    pol = GemmPolicy(backend="ozaki2_f64", rtol=1e-9)
    eager = np.asarray(linalg.matmul(a, b, policy=pol))
    jitted = np.asarray(jax.jit(
        lambda x, w: linalg.matmul(x, w, policy=pol)
    )(a, b))
    # under jit the probe sees tracers and falls back to the static
    # resolution; both paths must still meet the tolerance
    ref = _ref_product(np.asarray(a), np.asarray(b))
    assert rel_error(eager, ref, np.asarray(a), np.asarray(b)) <= 1e-9
    assert rel_error(jitted, ref, np.asarray(a), np.asarray(b)) <= 1e-9


def test_matmul_rtol_kwarg_equals_policy_field(rng):
    a = jnp.asarray(phi_matrix(rng, (M, K), 0.5, np.float32))
    b = jnp.asarray(phi_matrix(rng, (K, N), 0.5, np.float32))
    base = GemmPolicy(backend="ozaki2_f32")
    via_kwarg = np.asarray(linalg.matmul(a, b, policy=base, rtol=1e-4))
    via_field = np.asarray(linalg.matmul(
        a, b, policy=dataclasses.replace(base, rtol=1e-4)
    ))
    np.testing.assert_array_equal(via_kwarg, via_field)


def test_adaptive_grad_does_not_revalidate_backward_shapes(rng):
    """The VJP's cotangent products contract over different lengths; an
    adaptive policy must not raise (or re-resolve) during the backward
    pass — resolution pins n_moduli before the custom-VJP boundary."""
    a = jnp.asarray(phi_matrix(rng, (M, K), 0.5, np.float64))
    b = jnp.asarray(phi_matrix(rng, (K, N), 0.5, np.float64))
    pol = GemmPolicy(backend="ozaki2_f64", rtol=1e-9)
    g = jax.grad(lambda x: linalg.matmul(x, b, policy=pol).sum())(a)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_unreachable_rtol_raises_with_reason():
    pol = GemmPolicy(backend="ozaki2_f32", rtol=1e-30)
    with pytest.raises(ValueError, match="no \\(mode, n_moduli\\)"):
        pol.resolve_adaptive(M, K, N)


# ===================================================== bitwise-unchanged


def test_non_adaptive_policies_bitwise_unchanged(rng):
    """Policies without rtol / mode='auto' must be numerically untouched by
    the adaptive machinery: same plan as make_plan, bitwise-equal results
    whether or not the (inert) rtol metadata is stamped."""
    for dtype in ("float32", "complex64"):
        a = jnp.asarray(phi_matrix(rng, (M, K), 0.5, np.dtype(dtype)))
        b = jnp.asarray(phi_matrix(rng, (K, N), 0.5, np.dtype(dtype)))
        pol = GemmPolicy(backend=BACKEND_FOR_DTYPE[dtype], n_moduli=5)
        assert not pol.is_adaptive
        plan = pol.plan_for(M, K, N)
        assert plan.rtol is None
        want = make_plan(dtype, 5, "fast",
                         formulation=plan.formulation, n_block=plan.n_block)
        assert plan == want
        y = np.asarray(linalg.matmul(a, b, policy=pol))
        # pinned n_moduli + rtol: NOT adaptive — runs the exact same plan,
        # only the declared contract (certified statically) differs
        pinned = dataclasses.replace(pol, rtol=1e-2)
        assert not pinned.is_adaptive
        y_pinned = np.asarray(linalg.matmul(a, b, policy=pinned))
        np.testing.assert_array_equal(y, y_pinned)
        assert pinned.plan_for(M, K, N).rtol == 1e-2


# ===================================================== prepared-operand drift


def test_prepared_operand_records_mode_and_moduli(rng):
    w = jnp.asarray(phi_matrix(rng, (K, N), 0.5, np.float64))
    for mode in ("fast", "accu"):
        pol = GemmPolicy(backend="ozaki2_f64", n_moduli=6, mode=mode)
        prepped = prepare_weights({"w": w}, pol)["w"]
        assert prepped.mode == mode
        assert prepped.n_moduli == 6
        assert f"mode={mode!r}" in repr(prepped)


def test_prepared_drift_raises_not_silent(rng):
    """The bugfix: a prepared weight served under a policy that resolves a
    different plan must raise a clear ValueError, never silently compute
    at the wrong accuracy."""
    x = jnp.asarray(phi_matrix(rng, (M, K), 0.5, np.float64))
    w = jnp.asarray(phi_matrix(rng, (K, N), 0.5, np.float64))
    pol = GemmPolicy(backend="ozaki2_f64", rtol=1e-6)
    prepped = prepare_weights({"w": w}, pol)["w"]
    # same policy: prepare-time and serve-time resolution agree
    y = policy_matmul(x, prepped, pol)
    assert rel_error(
        np.asarray(y), _ref_product(np.asarray(x), np.asarray(w)),
        np.asarray(x), np.asarray(w),
    ) <= 1e-6
    # rtol edited between prepare and serve: moduli-count drift
    with pytest.raises(ValueError, match="re-prepare"):
        policy_matmul(x, prepped, dataclasses.replace(pol, rtol=1e-14))
    # mode drift (auto resolving to a different mode than prepared)
    accu_pol = GemmPolicy(
        backend="ozaki2_f64",
        n_moduli=prepped.n_moduli,
        mode="accu" if prepped.mode == "fast" else "fast",
    )
    with pytest.raises(ValueError, match="mode"):
        policy_matmul(x, prepped, accu_pol)


def test_serve_engine_prepared_drift_regression(rng):
    """End to end through ServeEngine(prepare=True): serving weights
    prepared under one rtol with a model pinning a different rtol raises,
    and serving under the matching policy works."""
    from repro.configs import get_reduced
    from repro.models import Model
    from repro.serve.engine import ServeEngine

    pol = GemmPolicy(backend="ozaki2_f32", rtol=1e-2, execution="reference")
    with repro.use_policy(pol):
        cfg = dataclasses.replace(
            get_reduced("starcoder2-3b"),
            gemm_policy=None,  # pins the ambient (adaptive) policy
            dtype="float32",
            n_layers=1,
        )
    assert cfg.gemm_policy == pol
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    batch = {"tokens": tokens}
    eng = ServeEngine(model, params, cache_len=16, batch_size=1, prepare=True)
    toks = eng.generate(batch, max_new_tokens=2)
    assert toks.shape == (1, 2)
    # restart with a tighter tolerance but the already-prepared planes:
    # the resolution drifts and serving must refuse, not mis-serve
    cfg_tight = dataclasses.replace(
        cfg, gemm_policy=dataclasses.replace(pol, rtol=1e-6)
    )
    eng_tight = ServeEngine(
        Model(cfg_tight), eng.params, cache_len=16, batch_size=1
    )
    with pytest.raises(ValueError, match="re-prepare"):
        eng_tight.generate(batch, max_new_tokens=2)


# ===================================================== analysis pass


def test_accuracy_pass_certifies_and_flags():
    from repro.analysis import AccuracyPass

    ok_plan = make_plan("float64", 10, "fast", rtol=1e-9)
    assert AccuracyPass(plan=ok_plan, k=K).run(None) == []
    # a declaration the bound cannot meet is a finding
    bad_plan = make_plan("float64", 4, "fast", rtol=1e-9)
    findings = AccuracyPass(plan=bad_plan, k=K).run(None)
    assert len(findings) == 1
    assert "bound" in findings[0].message
    # no declared contract: trivially certified
    assert AccuracyPass(plan=make_plan("float64", 4, "fast"), k=K).run(None) == []


def test_passes_for_backend_includes_accuracy_for_declared_plans():
    pol = GemmPolicy(backend="ozaki2_f32", rtol=1e-4)
    resolved = pol.resolve_adaptive(M, K, N)
    plan = resolved.plan_for(M, K, N)
    assert plan.rtol == 1e-4
    backend = resolved.execution_backend()
    names = [p.name for p in backend.analyze(plan, (M, K, N))]
    assert "accuracy" in names
    # shape-free suites cannot pin a contraction length: no accuracy pass
    names_free = [p.name for p in backend.analyze(plan, None)]
    assert "accuracy" not in names_free


def test_rtol_cli_surface_is_linted():
    """Every execution CLI exposes --rtol (pinned by lint_policy_surface)."""
    from pathlib import Path

    from repro.analysis import EXECUTION_CLIS
    from repro.analysis.lint import has_flag

    root = Path(__file__).resolve().parents[1]
    for rel in EXECUTION_CLIS:
        assert has_flag(root / rel, "--rtol"), rel
