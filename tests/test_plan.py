"""The plan/executor layer: one pipeline behind every public entry point.

Equivalence guarantees checked here:
  * `run_plan` on a reference-backend plan is bit-for-bit what the public
    wrappers (`ozaki2_gemm` / `ozaki2_cgemm`) return, across
    {f32, f64, c64, c128} x {fast, accu} x all three complex formulations —
    i.e. the wrappers really are thin and there is only one pipeline.
  * each combination stays inside the paper's accuracy band vs a
    long-double reference (guards the executor itself, not just wiring),
  * `PreparedOperand` (both sides, real and complex, batched) is
    bit-identical to the direct fast-mode pipeline,
  * the policy stack runs complex emulation forward+backward under jit with
    cotangents matching native `jnp.matmul`,
  * the serve engine's prepared weights reproduce unprepared generation
    exactly,
  * the perfmodel-driven 'auto' selections return valid, sensible choices.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import FAST_K, FAST_M, FAST_N, phi_matrix
from repro.core import (
    GemmPolicy,
    PreparedOperand,
    gemm_prepared,
    make_plan,
    ozaki2_cgemm,
    ozaki2_gemm,
    policy_matmul,
    prepare_weights,
    run_plan,
)
from repro.core.executor import REFERENCE
from repro.core.plan import DEFAULT_N_BLOCK
from repro.core import perfmodel

M, K, N = FAST_M, FAST_K, FAST_N

REAL_DTYPES = [np.float32, np.float64]
COMPLEX_DTYPES = [np.complex64, np.complex128]
N_MODULI = {"float32": 8, "float64": 14, "complex64": 7, "complex128": 14}
BAND = {"float32": 2e-4, "float64": 1e-12, "complex64": 2e-3, "complex128": 1e-11}


def _ref(a, b):
    hp = np.clongdouble if np.iscomplexobj(a) else np.longdouble
    return a.astype(hp) @ b.astype(hp)


def _maxrel(c, ref):
    return float(np.max(np.abs(c - ref)) / np.max(np.abs(ref)))


@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", REAL_DTYPES)
def test_real_plan_matches_wrapper_bitwise(rng, dtype, mode):
    a = phi_matrix(rng, (M, K), 0.5, dtype)
    b = phi_matrix(rng, (K, N), 0.5, dtype)
    nm = N_MODULI[np.dtype(dtype).name]
    plan = make_plan(dtype, n_moduli=nm, mode=mode)
    got = np.asarray(run_plan(plan, jnp.asarray(a), jnp.asarray(b), REFERENCE))
    want = np.asarray(ozaki2_gemm(jnp.asarray(a), jnp.asarray(b), nm, mode))
    np.testing.assert_array_equal(got, want)
    assert _maxrel(got, _ref(a, b)) < BAND[np.dtype(dtype).name]


@pytest.mark.parametrize("formulation", ["karatsuba", "block_a", "block_b"])
@pytest.mark.parametrize("mode", ["fast", "accu"])
@pytest.mark.parametrize("dtype", COMPLEX_DTYPES)
def test_complex_plan_matches_wrapper_bitwise(rng, dtype, mode, formulation):
    a = phi_matrix(rng, (M, K), 0.5, dtype)
    b = phi_matrix(rng, (K, N), 0.5, dtype)
    nm = N_MODULI[np.dtype(dtype).name]
    plan = make_plan(dtype, n_moduli=nm, mode=mode, formulation=formulation)
    got = np.asarray(run_plan(plan, jnp.asarray(a), jnp.asarray(b), REFERENCE))
    want = np.asarray(
        ozaki2_cgemm(jnp.asarray(a), jnp.asarray(b), nm, mode, formulation=formulation)
    )
    np.testing.assert_array_equal(got, want)
    assert _maxrel(got, _ref(a, b)) < BAND[np.dtype(dtype).name]


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
def test_n_blocked_plan_is_bitwise_equal(rng, dtype):
    a = phi_matrix(rng, (M, K), 0.5, dtype)
    b = phi_matrix(rng, (K, N), 0.5, dtype)
    nm = N_MODULI[np.dtype(dtype).name]
    fn = ozaki2_cgemm if np.iscomplexobj(a) else ozaki2_gemm
    full = np.asarray(fn(jnp.asarray(a), jnp.asarray(b), nm))
    blocked = np.asarray(fn(jnp.asarray(a), jnp.asarray(b), nm, n_block=7))
    np.testing.assert_array_equal(full, blocked)


# ------------------------------------------------------- prepared operands


def test_prepared_right_side_matches_direct(rng):
    """Satellite regression: the formerly-NotImplemented side='right' path
    is bit-compatible with the direct fast-mode `ozaki2_gemm`."""
    b = phi_matrix(rng, (K, N), 1.0, np.float64)
    prep = PreparedOperand(jnp.asarray(b), 14, side="right")
    for seed in range(3):
        a = phi_matrix(np.random.default_rng(seed), (M, K), 1.0, np.float64)
        c1 = np.asarray(gemm_prepared(prep, jnp.asarray(a)))
        c2 = np.asarray(ozaki2_gemm(jnp.asarray(a), jnp.asarray(b), 14, "fast"))
        np.testing.assert_array_equal(c1, c2)


@pytest.mark.parametrize("side", ["left", "right"])
def test_prepared_complex_matches_direct(rng, side):
    a = phi_matrix(rng, (M, K), 1.0, np.complex128)
    b = phi_matrix(rng, (K, N), 1.0, np.complex128)
    fixed, other = (a, b) if side == "left" else (b, a)
    prep = PreparedOperand(jnp.asarray(fixed), 14, side=side)
    c1 = np.asarray(gemm_prepared(prep, jnp.asarray(other)))
    c2 = np.asarray(ozaki2_cgemm(jnp.asarray(a), jnp.asarray(b), 14, "fast"))
    np.testing.assert_array_equal(c1, c2)
    # the policy knobs apply to the prepared path too: output-column
    # blocking slices the same residues, so the result is still bitwise equal
    c3 = np.asarray(gemm_prepared(prep, jnp.asarray(other), n_block=7))
    np.testing.assert_array_equal(c3, c2)


def test_prepared_batched_weights_slice_like_scan(rng):
    """Stacked (L, k, n) weights prepare to (L, N, k, n) residues that scan
    slices per layer — the layout the serve engine relies on."""
    w = np.stack(
        [phi_matrix(rng, (K, N), 0.5, np.float64) for _ in range(3)]
    )
    prep = PreparedOperand(jnp.asarray(w), 14, side="right")
    assert prep.residues[0].shape == (3, 14, K, N)
    sliced = jax.tree.map(lambda x: x[1], prep)
    a = phi_matrix(rng, (M, K), 0.5, np.float64)
    c1 = np.asarray(gemm_prepared(sliced, jnp.asarray(a)))
    c2 = np.asarray(ozaki2_gemm(jnp.asarray(a), jnp.asarray(w[1]), 14, "fast"))
    np.testing.assert_array_equal(c1, c2)


# ---------------------------------------------------------- policy stack


@pytest.mark.parametrize("backend,dtype", [
    ("ozaki2_c64", np.complex64),
    ("ozaki2_c128", np.complex128),
])
def test_policy_complex_forward_backward_jit(rng, backend, dtype):
    """Acceptance: complex emulated matmul runs fwd+bwd under jit and its
    cotangents match native `jnp.matmul` (non-conjugating transpose)."""
    pol = GemmPolicy(backend=backend, n_moduli=N_MODULI[np.dtype(dtype).name])
    x = jnp.asarray(phi_matrix(rng, (M, K), 0.5, dtype))
    w = jnp.asarray(phi_matrix(rng, (K, N), 0.5, dtype))
    g = jnp.asarray(phi_matrix(rng, (M, N), 0.5, dtype))

    @jax.jit
    def fwd(x, w):
        return policy_matmul(x, w, pol)

    y, vjp = jax.vjp(fwd, x, w)
    dx, dw = vjp(g)
    yn, vjpn = jax.vjp(jnp.matmul, x, w)
    dxn, dwn = vjpn(g)
    tol = 1e-4 if dtype == np.complex64 else 1e-12
    scale = float(jnp.max(jnp.abs(yn)))
    assert float(jnp.max(jnp.abs(y - yn))) / scale < tol
    assert float(jnp.max(jnp.abs(dx - dxn))) / float(jnp.max(jnp.abs(dxn))) < tol
    assert float(jnp.max(jnp.abs(dw - dwn))) / float(jnp.max(jnp.abs(dwn))) < tol


def test_model_with_complex_policy_trains(rng):
    """Acceptance: a model configured with a complex GemmPolicy backend runs
    forward+backward through the emulated complex path under jit."""
    from repro.configs import get_reduced
    from repro.models import Model

    cfg = dataclasses.replace(
        get_reduced("starcoder2-3b"),
        gemm_policy=GemmPolicy(backend="ozaki2_c64", n_moduli=6),
        dtype="float32",
        n_layers=1,
    )
    cfg_native = dataclasses.replace(cfg, gemm_policy=GemmPolicy())
    m_em, m_nat = Model(cfg), Model(cfg_native)
    params = m_em.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    batch = {"tokens": tokens}

    @jax.jit
    def loss_and_grad(p):
        return jax.value_and_grad(lambda q: m_em.loss(q, batch)[0])(p)

    l_em, g = loss_and_grad(params)
    l_nat, _ = m_nat.loss(params, batch)
    np.testing.assert_allclose(float(l_em), float(l_nat), rtol=1e-3)
    assert all(
        np.isfinite(np.asarray(x, np.float32)).all() for x in jax.tree.leaves(g)
    )


def test_serve_engine_prepared_weights_match(rng):
    """Engine-level weight preparation is bit-transparent: generated tokens
    match the unprepared emulated engine."""
    from repro.configs import get_reduced
    from repro.models import Model
    from repro.serve.engine import ServeEngine

    cfg = dataclasses.replace(
        get_reduced("starcoder2-3b"),
        gemm_policy=GemmPolicy(backend="ozaki2_f32", n_moduli=6),
        dtype="float32",
        n_layers=1,
    )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    batch = {"tokens": tokens}
    plain = ServeEngine(model, params, cache_len=16, batch_size=1)
    prepped = ServeEngine(model, params, cache_len=16, batch_size=1, prepare=True)
    t1 = np.asarray(plain.generate(batch, max_new_tokens=2))
    t2 = np.asarray(prepped.generate(batch, max_new_tokens=2))
    np.testing.assert_array_equal(t1, t2)


def test_chunked_residue_matmul_exact_beyond_limit(rng):
    """The single shared K-chunk loop (executor.chunked_residue_matmul)
    reduces mod p between int32-exact chunks: bit-exact vs int64 for
    k > K_CHUNK_LIMIT."""
    from repro.core.executor import REFERENCE
    from repro.core.moduli import K_CHUNK_LIMIT, make_crt_context

    ctx = make_crt_context(2)
    k = K_CHUNK_LIMIT + 513
    ares = rng.integers(-127, 128, size=(2, 4, k)).astype(np.int8)
    bres = rng.integers(-127, 128, size=(2, k, 3)).astype(np.int8)
    got = np.asarray(
        REFERENCE.residue_matmul(jnp.asarray(ares), jnp.asarray(bres), ctx)
    )
    exact = np.einsum("nmk,nkj->nmj", ares.astype(np.int64), bres.astype(np.int64))
    for l, p in enumerate(ctx.moduli):
        r = exact[l] % p
        r = np.where(r > (p - 1) // 2, r - p, r)
        np.testing.assert_array_equal(got[l], r)


# ------------------------------------------------------- auto selection


def test_auto_formulation_and_n_block():
    # tiny product: launch overhead dominates -> a block embedding wins
    tiny = make_plan(np.complex128, n_moduli=14, formulation="auto",
                     shape=(64, 64, 64))
    assert tiny.formulation in ("block_a", "block_b")
    # large product: Karatsuba's 6N vs 8N op count dominates
    big = make_plan(np.complex128, n_moduli=14, formulation="auto",
                    shape=(8192, 8192, 8192))
    assert big.formulation == "karatsuba"
    # block_a favoured when m < n, block_b when m > n (embedding traffic)
    assert perfmodel.select_formulation(64, 4096, 64, 14) == "block_a"
    assert perfmodel.select_formulation(4096, 64, 64, 14) == "block_b"
    # auto n_block: off below the paper's 8192, balanced blocks above
    assert make_plan(np.complex64, n_moduli=7, n_block="auto",
                     shape=(256, 256, 4096)).n_block is None
    nb = make_plan(np.complex64, n_moduli=7, n_block="auto",
                   shape=(256, 256, 20000)).n_block
    assert nb is not None and nb <= DEFAULT_N_BLOCK


def test_plan_is_static_and_hashable():
    p1 = make_plan(np.complex64, n_moduli=7)
    p2 = make_plan(np.complex64, n_moduli=7)
    assert p1 == p2 and hash(p1) == hash(p2)
    assert p1.is_complex and p1.real_out_dtype == jnp.float32
    with pytest.raises(ValueError):
        make_plan(np.complex64, formulation="nope")
    with pytest.raises(ValueError):
        make_plan(np.float32, mode="nope")
