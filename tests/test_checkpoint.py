"""Checkpoint: roundtrip, atomicity, async, GC, resume, elastic reshard."""
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer, latest_step


def _tree(rng):
    return {
        "a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "groups": [
            {"w": jnp.asarray(rng.standard_normal((2, 3)), jnp.bfloat16)},
            {"w": jnp.asarray(rng.integers(0, 5, (7,)), jnp.int32)},
        ],
        "step": jnp.int32(7),
    }


def test_roundtrip(tmp_path, rng):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(rng)
    ck.save(10, tree)
    out = ck.restore(10, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float64), np.asarray(b, np.float64)
        )
        assert a.dtype == b.dtype


def test_latest_and_gc(tmp_path, rng):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = _tree(rng)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert latest_step(str(tmp_path)) == 4
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_3", "step_4"]


def test_async_save(tmp_path, rng):
    ck = Checkpointer(str(tmp_path))
    tree = _tree(rng)
    ck.save(5, tree, blocking=False)
    ck.wait()
    assert latest_step(str(tmp_path)) == 5
    out = ck.restore(5, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


def test_no_tmp_left_behind(tmp_path, rng):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(rng))
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_meta(tmp_path, rng):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _tree(rng), extra_meta={"mesh": [16, 16]})
    assert ck.meta(3)["mesh"] == [16, 16]


def test_restore_into_shapestructs(tmp_path, rng):
    """Elastic path: restore without live arrays (ShapeDtypeStruct 'like')."""
    ck = Checkpointer(str(tmp_path))
    tree = _tree(rng)
    ck.save(2, tree)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out = ck.restore(2, like)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
