# NOTE: no xla_force_host_platform_device_count here — unit tests and
# benches must see the single real CPU device (the 512-device production
# mesh exists only inside launch/dryrun.py).  Multi-device behaviour is
# tested via subprocesses (tests/test_distributed.py).
import numpy as np
import pytest

import repro  # noqa: F401  (enables x64 for the numeric core)

# Fast default profile (see pytest.ini): shared small GEMM shapes so tier-1
# finishes in minutes on a CPU host.  Large-shape coverage lives in tests
# marked `slow` (deselected by default, run in CI's slow job).
FAST_M, FAST_K, FAST_N = 32, 96, 24


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def phi_matrix(rng, shape, phi, dtype):
    """The paper's SIV-A test-matrix generator: (rand-0.5)*exp(randn*phi)."""
    u = rng.random(shape)
    g = rng.standard_normal(shape)
    m = (u - 0.5) * np.exp(g * phi)
    if np.issubdtype(np.dtype(dtype), np.complexfloating):
        u2 = rng.random(shape)
        g2 = rng.standard_normal(shape)
        m = m + 1j * (u2 - 0.5) * np.exp(g2 * phi)
    return m.astype(dtype)
