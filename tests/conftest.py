# NOTE: no xla_force_host_platform_device_count here — unit tests and
# benches must see the single real CPU device (the 512-device production
# mesh exists only inside launch/dryrun.py).  Multi-device behaviour is
# tested via subprocesses (tests/test_distributed.py).
import os

import numpy as np
import pytest

import repro  # noqa: F401  (enables x64 for the numeric core)

# Fast default profile (see pytest.ini): shared small GEMM shapes so tier-1
# finishes in minutes on a CPU host.  Large-shape coverage lives in tests
# marked `slow` (deselected by default, run in CI's slow job).
FAST_M, FAST_K, FAST_N = 32, 96, 24

# Every random operand draw in the suite goes through the `rng` fixture
# seeded here, so any failure reproduces from the seed in the test header:
#     REPRO_TEST_SEED=<seed> python -m pytest ...
SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


def pytest_report_header(config):
    return f"repro: REPRO_TEST_SEED={SEED} (operand-generation seed)"


@pytest.fixture
def rng():
    return np.random.default_rng(SEED)


try:  # optional: property tests select a profile via HYPOTHESIS_PROFILE
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("fast", max_examples=15, deadline=None)
    _hyp_settings.register_profile("ci", max_examples=50, deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "fast"))
except ImportError:  # hypothesis not installed: property tests skip anyway
    pass


def phi_matrix(rng, shape, phi, dtype):
    """The paper's SIV-A test-matrix generator: (rand-0.5)*exp(randn*phi)."""
    u = rng.random(shape)
    g = rng.standard_normal(shape)
    m = (u - 0.5) * np.exp(g * phi)
    if np.issubdtype(np.dtype(dtype), np.complexfloating):
        u2 = rng.random(shape)
        g2 = rng.standard_normal(shape)
        m = m + 1j * (u2 - 0.5) * np.exp(g2 * phi)
    return m.astype(dtype)
