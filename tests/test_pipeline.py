"""Pipeline parallelism: pipelined loss/grads == sequential (4 host devices)."""
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow  # 4-host-device SPMD subprocess: minutes of compile on CPU
def test_pipeline_matches_sequential():
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import dataclasses
        import numpy as np
        import jax, jax.numpy as jnp
        import repro
        from repro.configs import get_reduced
        from repro.models import Model
        from repro.distributed.pipeline import pipeline_loss

        cfg = dataclasses.replace(
            get_reduced("qwen2.5-32b"), n_layers=4, dtype="float32", remat=False
        )
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)}
        mesh = jax.make_mesh((4,), ("pp",))

        ref_loss, _ = model.loss(params, batch)
        pl = jax.jit(lambda p, b: pipeline_loss(model, p, b, mesh, "pp", n_micro=4))
        pipe_loss = pl(params, batch)
        assert abs(float(ref_loss) - float(pipe_loss)) < 1e-5, (
            float(ref_loss), float(pipe_loss))

        g_ref = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        g_pipe = jax.grad(lambda p: pl(p, batch))(params)
        for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pipe)):
            a = np.asarray(a, np.float32)
            b = np.asarray(b, np.float32)
            # global-scale comparison (per-element rtol is meaningless for
            # near-zero entries under f32 reduction-order noise)
            d = float(np.max(np.abs(a - b)))
            assert d <= max(1e-5, 1e-3 * float(np.max(np.abs(a)))), d
        print("PIPELINE_OK", float(pipe_loss))
        """
    )
    res = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=420,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd="/root/repo",
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "PIPELINE_OK" in res.stdout
