"""Shared timing + CSV helpers for the benchmark harness."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (jit-compiled fns)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def phi_matrix(rng, shape, phi, dtype):
    u = rng.random(shape)
    g = rng.standard_normal(shape)
    m = (u - 0.5) * np.exp(g * phi)
    if np.issubdtype(np.dtype(dtype), np.complexfloating):
        u2 = rng.random(shape)
        g2 = rng.standard_normal(shape)
        m = m + 1j * (u2 - 0.5) * np.exp(g2 * phi)
    return m.astype(dtype)
