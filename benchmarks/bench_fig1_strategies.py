"""Paper Fig. 1: the four INT8 complex-multiplication strategies.

  block_a    — one (2h, 2h) x (2h, h) real GEMM per modulus (eq. 7)
  block_b    — one (h, 2h) x (2h, 2h) real GEMM per modulus (eq. 8)
  karatsuba  — three (h, h, h) GEMMs per modulus (eq. 10)
  karatsuba8k— same with n-blocking (paper: blocks of 8192; scaled here)

We measure wall time on this host (CPU) and report the derived effective
INT8 ops/s plus the algorithmic op counts (which is what Fig. 1's ranking
follows on a saturated matrix engine: Karatsuba does 3h^3 multiplies vs
4h^3 for the block embeddings).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core.cgemm import ozaki2_cgemm
from repro.core.perfmodel import TPU_V5E, select_formulation

from .common import emit, phi_matrix, time_fn


def run(h: int = 512, n_moduli: int = 4):
    rng = np.random.default_rng(0)
    a = jnp.asarray(phi_matrix(rng, (h, h), 0.5, np.complex64))
    b = jnp.asarray(phi_matrix(rng, (h, h), 0.5, np.complex64))
    picked = select_formulation(h, h, h, n_moduli, hw=TPU_V5E, prec="c")
    emit(f"fig1/auto_pick/h{h}", 0.0, f"perfmodel_choice={picked}")
    results = {}
    for name, kwargs in [
        ("block_a", dict(formulation="block_a")),
        ("block_b", dict(formulation="block_b")),
        ("karatsuba", dict(formulation="karatsuba")),
        ("karatsuba_blocked", dict(formulation="karatsuba", n_block=max(128, h // 4))),
    ]:
        fn = functools.partial(
            ozaki2_cgemm, n_moduli=n_moduli, mode="fast", **kwargs
        )
        us = time_fn(fn, a, b)
        int8_muls = (4 if name.startswith("block") else 3) * n_moduli * h**3
        results[name] = us
        emit(
            f"fig1/{name}/h{h}",
            us,
            f"int8_mul_ops={int8_muls:.3e};eff_ops_per_s={int8_muls/(us*1e-6):.3e}",
        )
    return results


if __name__ == "__main__":
    run()
