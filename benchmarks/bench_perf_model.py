"""Paper Figs. 2-3: performance-model heatmaps (predicted TFLOPS over the
(memory bandwidth x int8 throughput) plane at m=n=k=16384, c=N).

Printed as CSV rows (one per bandwidth) so the heatmap can be re-plotted;
also reports the paper's GH200 spot check: ZGEMM accu ~120 TFLOPS at
b=2-4 TB/s, p=1500 TOPS.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.perfmodel import HW, complex_tflops

from .common import emit


def run(size: int = 16384):
    bws = np.linspace(0.5e12, 8e12, 6)
    opss = np.linspace(250e12, 4500e12, 6)
    for prec, nm in (("c", 6), ("z", 13)):
        for mode in ("fast", "accu"):
            for b in bws:
                row = []
                for p in opss:
                    hw = HW("grid", b, p, 0, 0)
                    row.append(complex_tflops(size, size, size, nm, hw, mode, prec, c=nm))
                emit(
                    f"fig23/{prec}gemm/{mode}-{nm}/bw{b/1e12:.1f}TBs",
                    0.0,
                    "tflops_vs_ops=" + "/".join(f"{t:.0f}" for t in row),
                )
    spot = complex_tflops(
        size, size, size, 13, HW("gh200-spot", 3e12, 1500e12, 0, 0), "accu", "z", c=13
    )
    emit("fig23/spotcheck/gh200_zgemm_accu", 0.0,
         f"tflops={spot:.0f};paper_prediction~120")


if __name__ == "__main__":
    run()
