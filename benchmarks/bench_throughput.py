"""Paper Figs. 6-13: emulated CGEMM/ZGEMM throughput.

Two outputs per configuration:
  * the paper's performance-model projection (SIII-C) on TPU v5e and on the
    paper's four GPUs — these reproduce the shape of Figs. 6-13 (TFLOPS vs
    size vs N) and the speedup-over-native claims;
  * measured wall-time of the actual emulation on this host (CPU) at small
    sizes, demonstrating the harness end-to-end.

Key reproduced claims (checked in the derived column):
  - B200 fast-N speedups over native ZGEMM of ~4-5.6x at N in [13,18];
  - Ozaki-II with N moduli beats Ozaki-I with S~N slices by ~S(S+1)/2/N x;
  - on v5e there is NO native ZGEMM — emulation is the only route (DESIGN).
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

from repro.core import ozaki2_cgemm
from repro.core.perfmodel import (
    B200,
    GH200,
    HARDWARE,
    TPU_V5E,
    complex_tflops,
    ozaki1_complex_time_s,
    complex_time_s,
)

from .common import emit, phi_matrix, time_fn


def model_tables():
    sizes = (1024, 2048, 4096, 8192, 16384)
    for hw in (TPU_V5E, B200, GH200):
        for prec, n_range in (("c", (6, 7, 8, 9)), ("z", (13, 14, 16, 18))):
            for nm in n_range:
                tf = [complex_tflops(s, s, s, nm, hw, "fast", prec) for s in sizes]
                native = hw.native_c64 if prec == "c" else hw.native_c128
                speed = tf[-1] * 1e12 / native if native else float("inf")
                emit(
                    f"fig6_13/model/{hw.name}/{prec}gemm/fast-{nm}",
                    0.0,
                    "tflops=" + "/".join(f"{t:.0f}" for t in tf)
                    + f";speedup_vs_native@16k={speed:.2f}",
                )
    # Ozaki-I comparison (GH200, z, 16384): paper SIV-B
    for s in (7, 8, 9):
        t1 = ozaki1_complex_time_s(16384, 16384, 16384, s, GH200)
        t2 = complex_time_s(16384, 16384, 16384, 13, GH200, "fast", "z")
        emit(
            f"fig10/ozaki1_vs_2/slices{s}",
            0.0,
            f"ozakiII_speedup={t1 / t2:.2f}x;paper_band=2.5-5.5x",
        )


def ozaki1_measured(s: int = 192):
    """Both schemes measured on OUR implementations at equal accuracy."""
    import numpy as np

    from repro.core import ozaki2_cgemm
    from repro.core.ozaki1 import int8_gemm_count, ozaki1_cgemm

    rng = np.random.default_rng(3)
    a = jnp.asarray(phi_matrix(rng, (s, s), 1.0, np.complex128))
    b = jnp.asarray(phi_matrix(rng, (s, s), 1.0, np.complex128))
    ref = np.asarray(a).astype(np.clongdouble) @ np.asarray(b).astype(np.clongdouble)

    def err(c):
        return float(np.max(np.abs(np.asarray(c) - ref) / np.abs(ref).max()))

    c1 = ozaki1_cgemm(a, b, 9)
    c2 = ozaki2_cgemm(a, b, 14, "fast")
    emit(
        f"fig10/measured/ozaki1_s9/{s}",
        0.0,
        f"maxrel={err(c1):.2e};int8_gemms={3 * int8_gemm_count(9)}",
    )
    emit(
        f"fig10/measured/ozaki2_n14/{s}",
        0.0,
        f"maxrel={err(c2):.2e};int8_gemms={3 * 14};"
        f"gemm_ratio={3 * int8_gemm_count(9) / (3 * 14):.2f}x",
    )


def measured(sizes=(256, 512)):
    rng = np.random.default_rng(1)
    for s in sizes:
        a = jnp.asarray(phi_matrix(rng, (s, s), 0.5, np.complex64))
        b = jnp.asarray(phi_matrix(rng, (s, s), 0.5, np.complex64))
        for nm in (6, 8):
            fn = functools.partial(ozaki2_cgemm, n_moduli=nm, mode="fast")
            us = time_fn(fn, a, b)
            emit(
                f"fig6_13/measured_cpu/cgemm/fast-{nm}/{s}",
                us,
                f"tflops={8 * s**3 / (us * 1e-6) * 1e-12:.4f}",
            )
        us_n = time_fn(jnp.matmul, a, b)
        emit(
            f"fig6_13/measured_cpu/cgemm/native/{s}",
            us_n,
            f"tflops={8 * s**3 / (us_n * 1e-6) * 1e-12:.4f}",
        )


def run():
    model_tables()
    measured()
    ozaki1_measured()


if __name__ == "__main__":
    run()
