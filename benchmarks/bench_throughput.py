"""Paper Figs. 6-13: emulated CGEMM/ZGEMM throughput.

Two outputs per configuration:
  * the paper's performance-model projection (SIII-C) on TPU v5e and on the
    paper's four GPUs — these reproduce the shape of Figs. 6-13 (TFLOPS vs
    size vs N) and the speedup-over-native claims;
  * measured wall-time of the actual emulation on this host (CPU) at small
    sizes, demonstrating the harness end-to-end.

Key reproduced claims (checked in the derived column):
  - B200 fast-N speedups over native ZGEMM of ~4-5.6x at N in [13,18];
  - Ozaki-II with N moduli beats Ozaki-I with S~N slices by ~S(S+1)/2/N x;
  - on v5e there is NO native ZGEMM — emulation is the only route (DESIGN).

CLI (the tracked-throughput harness; `benchmarks.run` still calls `run()`):

    PYTHONPATH=src python -m benchmarks.bench_throughput \
        [--smoke] [--execution reference|kernel|sharded|fp8|fused] \
        [--residue R] [--mesh DxM] [--json BENCH_throughput.json] [--force] \
        [--calibrate off|load|run] [--compare BASELINE.json]

`--execution` picks the residue backend the measured section times
(`sharded` builds a host mesh — run under
XLA_FLAGS=--xla_force_host_platform_device_count=N to span N devices;
`fp8` runs the e4m3 digit-GEMM engine; `fused` the one-launch megakernel)
and every measured record reports BOTH aggregate and per-device GEMM
throughput, written to the `--json` file keyed by the full measurement
config (execution, mesh, devices, name) plus the active calibration-cache
stamp — re-running replaces exactly the re-measured keys, so
BENCH_throughput.json accumulates the kernel-vs-fused (and fp8/sharded,
and tuned-vs-default-block) trajectories side by side; records it cannot
key-match are never dropped without `--force`.

`--calibrate load|run` activates a `repro.tune` calibration cache before
measuring, so the Pallas executions launch the autotuned block shapes
(records are stamped with the cache hash).  `--compare baseline.json`
diffs this run against a previous run's records by measurement config and
exits nonzero when any per-device throughput regresses more than
`--tolerance` (default 15%) — the CI guard that tuned blocks never ship
slower than the static defaults.
"""
from __future__ import annotations

import argparse
import functools
import json

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ozaki2_cgemm
from repro.core.perfmodel import (
    B200,
    GH200,
    HARDWARE,
    TPU_V5E,
    complex_tflops,
    engine_time_s,
    ozaki1_complex_time_s,
    complex_time_s,
    select_engine,
)

from .common import emit, phi_matrix, time_fn


def model_tables():
    sizes = (1024, 2048, 4096, 8192, 16384)
    for hw in (TPU_V5E, B200, GH200):
        for prec, n_range in (("c", (6, 7, 8, 9)), ("z", (13, 14, 16, 18))):
            for nm in n_range:
                tf = [complex_tflops(s, s, s, nm, hw, "fast", prec) for s in sizes]
                native = hw.native_c64 if prec == "c" else hw.native_c128
                speed = tf[-1] * 1e12 / native if native else float("inf")
                emit(
                    f"fig6_13/model/{hw.name}/{prec}gemm/fast-{nm}",
                    0.0,
                    "tflops=" + "/".join(f"{t:.0f}" for t in tf)
                    + f";speedup_vs_native@16k={speed:.2f}",
                )
    # int8-vs-fp8 engine projections (arXiv:2603.10634 comparison): the fp8
    # engine runs 4 digit-GEMM volumes at the e4m3 rate, so it wins only
    # where the rate advantage or memory-boundedness beats the 4x volume
    for hw in (TPU_V5E, B200, GH200):
        for s in (2048, 16384):
            t_i8 = engine_time_s("int8", s, s, s, 14, hw, "fast", "z")
            t_f8 = engine_time_s("fp8", s, s, s, 14, hw, "fast", "z")
            emit(
                f"engine/model/{hw.name}/zgemm/fast-14/{s}",
                0.0,
                f"int8_s={t_i8:.2e};fp8_s={t_f8:.2e};"
                f"fp8_over_int8={t_f8 / t_i8:.2f}x;"
                f"selected={select_engine(s, s, s, 14, hw, 'fast', 'z')}",
            )
    # Ozaki-I comparison (GH200, z, 16384): paper SIV-B
    for s in (7, 8, 9):
        t1 = ozaki1_complex_time_s(16384, 16384, 16384, s, GH200)
        t2 = complex_time_s(16384, 16384, 16384, 13, GH200, "fast", "z")
        emit(
            f"fig10/ozaki1_vs_2/slices{s}",
            0.0,
            f"ozakiII_speedup={t1 / t2:.2f}x;paper_band=2.5-5.5x",
        )


def ozaki1_measured(s: int = 192):
    """Both schemes measured on OUR implementations at equal accuracy."""
    import numpy as np

    from repro.core import ozaki2_cgemm
    from repro.core.ozaki1 import int8_gemm_count, ozaki1_cgemm

    rng = np.random.default_rng(3)
    a = jnp.asarray(phi_matrix(rng, (s, s), 1.0, np.complex128))
    b = jnp.asarray(phi_matrix(rng, (s, s), 1.0, np.complex128))
    ref = np.asarray(a).astype(np.clongdouble) @ np.asarray(b).astype(np.clongdouble)

    def err(c):
        return float(np.max(np.abs(np.asarray(c) - ref) / np.abs(ref).max()))

    c1 = ozaki1_cgemm(a, b, 9)
    c2 = ozaki2_cgemm(a, b, 14, "fast")
    emit(
        f"fig10/measured/ozaki1_s9/{s}",
        0.0,
        f"maxrel={err(c1):.2e};int8_gemms={3 * int8_gemm_count(9)}",
    )
    emit(
        f"fig10/measured/ozaki2_n14/{s}",
        0.0,
        f"maxrel={err(c2):.2e};int8_gemms={3 * 14};"
        f"gemm_ratio={3 * int8_gemm_count(9) / (3 * 14):.2f}x",
    )


def measured(sizes=(256, 512)):
    rng = np.random.default_rng(1)
    for s in sizes:
        a = jnp.asarray(phi_matrix(rng, (s, s), 0.5, np.complex64))
        b = jnp.asarray(phi_matrix(rng, (s, s), 0.5, np.complex64))
        for nm in (6, 8):
            fn = functools.partial(ozaki2_cgemm, n_moduli=nm, mode="fast")
            us = time_fn(fn, a, b)
            emit(
                f"fig6_13/measured_cpu/cgemm/fast-{nm}/{s}",
                us,
                f"tflops={8 * s**3 / (us * 1e-6) * 1e-12:.4f}",
            )
        us_n = time_fn(jnp.matmul, a, b)
        emit(
            f"fig6_13/measured_cpu/cgemm/native/{s}",
            us_n,
            f"tflops={8 * s**3 / (us_n * 1e-6) * 1e-12:.4f}",
        )


def _bench_mesh(execution: str, residue: int, mesh_arg: str | None):
    """The mesh a sharded measured section spans (None off the sharded path)."""
    if execution != "sharded":
        return None
    from repro.launch.mesh import make_host_mesh

    if mesh_arg:
        d, m = map(int, mesh_arg.split("x"))
        return jax.make_mesh(
            (d, m, max(residue, 1)), ("data", "model", "residue")
        )
    return make_host_mesh(
        1, 1, residue=residue if residue > 1 else len(jax.devices())
    )


# (blas-prefix, backend, numpy dtype, flops per m*n*k) measured per mode —
# one real and one complex class keeps the tracked trajectory per dtype x
# mode without quadrupling bench wall-time (f64/c128 follow the same code
# paths at higher N).
_MEASURED_CLASSES = (
    ("s", "ozaki2_f32", np.float32, 2.0),
    ("c", "ozaki2_c64", np.complex64, 8.0),
)


def measured_policy(
    sizes=(256, 512),
    execution: str = "reference",
    residue: int = 1,
    mesh_arg: str | None = None,
    records: list | None = None,
    rtol: float | None = None,
):
    """Measured wall-time of the policy-routed emulation on this host.

    Covers dtype class x scaling mode (sgemm/cgemm x fast/accu) so the
    tracked records pin the whole measured surface per execution.  Reports
    aggregate TFLOPS (whole-GEMM flops / wall time) and per-device TFLOPS
    (aggregate / devices the mesh spans) for every configuration — the
    number that must stay flat as the mesh grows is per-device, and the one
    that must grow is aggregate.

    With `rtol` the policies run accuracy-adaptive (`GemmPolicy(rtol=...)`:
    fewest moduli provably meeting the tolerance instead of the per-dtype
    defaults); the records carry an `/rtol...` name suffix so the adaptive
    trajectory coexists with the default one in the tracked JSON.
    """
    import repro
    from repro import linalg
    from repro.core import GemmPolicy
    from repro.tune.cache import calibration_hash, current_calibration

    cal = current_calibration()
    cal_stamp = calibration_hash(cal) if cal is not None else None
    mesh = _bench_mesh(execution, residue, mesh_arg)
    n_dev = int(np.prod(list(mesh.shape.values()))) if mesh is not None else 1
    mesh_name = (
        "x".join(str(s) for s in mesh.shape.values()) if mesh is not None else "1"
    )
    rng = np.random.default_rng(1)
    for s in sizes:
        for prec, backend, dt, flop in _MEASURED_CLASSES:
            a = jnp.asarray(phi_matrix(rng, (s, s), 0.5, dt))
            b = jnp.asarray(phi_matrix(rng, (s, s), 0.5, dt))
            for mode in ("fast", "accu"):
                pol = GemmPolicy(
                    backend=backend, mode=mode, execution=execution,
                    mesh=mesh, rtol=rtol,
                )
                suffix = "" if rtol is None else f"/rtol{rtol:g}"
                us = time_fn(
                    functools.partial(linalg.matmul_jit, policy=pol), a, b
                )
                agg = flop * s**3 / (us * 1e-6) * 1e-12
                emit(
                    f"fig6_13/measured_cpu/{prec}gemm/{execution}"
                    f"/mesh{mesh_name}/{mode}/{s}{suffix}",
                    us,
                    f"tflops_aggregate={agg:.4f}"
                    f";tflops_per_device={agg / n_dev:.4f}",
                )
                if records is not None:
                    records.append({
                        "name": f"{prec}gemm/{mode}/{s}{suffix}",
                        "execution": execution,
                        "mesh": mesh_name,
                        "devices": n_dev,
                        "us_per_call": us,
                        "tflops_aggregate": agg,
                        "tflops_per_device": agg / n_dev,
                        "calibration": cal_stamp,
                    })


def run():
    model_tables()
    measured()
    ozaki1_measured()


def record_key(r):
    """Dedupe key of one tracked record, or None if unreadable.

    The measurement config (execution, mesh, devices, name) plus the
    calibration-cache stamp — tuned and untuned runs of the same config are
    distinct trajectories and must coexist in the JSON.
    """
    try:
        key = (r["execution"], r["mesh"], r["devices"], r["name"])
    except (KeyError, TypeError):
        return None
    return key + (r.get("calibration"),)


def merge_records(old, new, *, force: bool = False):
    """Merge `new` measured records into the `old` tracked list.

    A record is replaced only when this run re-measured its exact
    `record_key` — a kernel run must not clobber the fused/fp8/sharded
    runs, a 2x2-mesh run must not clobber the 1x8 trajectory of the same
    execution, and a calibrated run must not clobber the untuned baseline.
    Old records are also deduped among themselves (same key: last one
    wins), so a file that accumulated duplicates is repaired on rewrite.
    Records whose key cannot be read (foreign or pre-key schema) are never
    dropped silently: that raises with a hint unless `force`.
    """
    unkeyed = [r for r in old if record_key(r) is None]
    if unkeyed and not force:
        raise SystemExit(
            f"--json target holds {len(unkeyed)} records without an "
            "(execution, mesh, devices, name) key; refusing to silently "
            "overwrite them — re-run with --force to drop, or point "
            "--json at a fresh file"
        )
    new_keys = {record_key(r) for r in new}
    kept: dict = {}
    for r in old:
        k = record_key(r)
        if k is not None and k not in new_keys:
            kept[k] = r
    return list(kept.values()) + list(new)


def compare_records(records, baseline, *, tolerance: float = 0.15):
    """Regression strings for records slower than the baseline run.

    Matches by measurement config (execution, mesh, devices, name) —
    deliberately ignoring the calibration stamp, so a tuned run is held to
    the untuned baseline's bar — and takes the best (max) per-device
    throughput over baseline duplicates.  A record is a regression when
    its tflops_per_device drops more than `tolerance` (fractional) below
    that.  Configs absent from the baseline are skipped (new coverage is
    not a regression).
    """
    best: dict = {}
    for r in baseline:
        k = record_key(r)
        if k is None:
            continue
        v = r.get("tflops_per_device")
        if v is None or not np.isfinite(v) or v <= 0:
            continue
        k = k[:4]
        best[k] = max(best.get(k, 0.0), float(v))
    regressions = []
    for r in records:
        k = record_key(r)
        if k is None or k[:4] not in best:
            continue
        base = best[k[:4]]
        cur = float(r["tflops_per_device"])
        if cur < (1.0 - tolerance) * base:
            regressions.append(
                f"{'/'.join(map(str, k[:4]))}: {cur:.4f} tflops/device vs "
                f"baseline {base:.4f} ({cur / base - 1.0:+.1%}, "
                f"tolerance -{tolerance:.0%})"
            )
    return regressions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes (CI: proves the path end-to-end)")
    ap.add_argument("--execution", default="reference",
                    choices=["reference", "kernel", "per_modulus_kernel",
                             "sharded", "fp8", "fused"],
                    help="residue backend the measured section times "
                         "(fp8: the e4m3 digit-GEMM engine; fused: the "
                         "one-launch megakernel)")
    ap.add_argument("--force", action="store_true",
                    help="allow --json to drop existing records it cannot "
                         "key-match (foreign/older record schema)")
    ap.add_argument("--residue", type=int, default=1,
                    help="residue mesh-axis size (sharded execution)")
    ap.add_argument("--mesh", default=None,
                    help="DxM data/model layout for the sharded mesh")
    ap.add_argument("--rtol", type=float, default=None,
                    help="measure accuracy-adaptive policies "
                         "(GemmPolicy(rtol=...): fewest moduli provably "
                         "meeting this componentwise tolerance) instead of "
                         "the per-dtype moduli defaults")
    ap.add_argument("--json", default="BENCH_throughput.json",
                    help="write measured records here (tracked throughput)")
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="after measuring, diff this run's records against "
                         "the records in BASELINE.json by (execution, mesh, "
                         "devices, name) and exit nonzero when any "
                         "per-device throughput regresses more than "
                         "--tolerance (the JSON is still written first)")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="fractional throughput drop --compare tolerates "
                         "before failing (default 0.15)")
    from repro.tune.cli import add_calibration_args, apply_calibration_args

    add_calibration_args(ap)
    args = ap.parse_args()
    apply_calibration_args(args, smoke=args.smoke)

    sizes = (48, 96) if args.smoke else (256, 512)
    records: list = []
    if not args.smoke:
        model_tables()
    measured_policy(
        sizes, args.execution, args.residue, args.mesh, records,
        rtol=args.rtol,
    )
    if args.json:
        try:
            with open(args.json) as f:
                old = json.load(f).get("records", [])
        except FileNotFoundError:
            old = []
        except (OSError, ValueError) as e:
            raise SystemExit(
                f"--json target {args.json!r} exists but is unreadable "
                f"({e}); refusing to overwrite — fix or remove it, or "
                f"point --json elsewhere"
            )
        with open(args.json, "w") as f:
            json.dump(
                {"records": merge_records(old, records, force=args.force)},
                f, indent=1,
            )
    # CI contract: the run must produce finite nonzero throughput records
    # (an explicit raise, not an assert — CI must fail under python -O too)
    bad = [
        r for r in records
        if not (np.isfinite(r["tflops_aggregate"])
                and np.isfinite(r["tflops_per_device"])
                and r["tflops_per_device"] > 0)
    ]
    if not records or bad:
        raise SystemExit(
            f"bench_throughput produced no usable records: {bad or 'empty'}"
        )
    if args.compare:
        try:
            with open(args.compare) as f:
                baseline = json.load(f).get("records", [])
        except (OSError, ValueError) as e:
            raise SystemExit(f"--compare baseline {args.compare!r}: {e}")
        regressions = compare_records(
            records, baseline, tolerance=args.tolerance
        )
        for line in regressions:
            print(f"REGRESSION {line}")
        matched = sum(
            1 for r in records
            if record_key(r) is not None
            and record_key(r)[:4] in {
                record_key(b)[:4] for b in baseline
                if record_key(b) is not None
            }
        )
        print(
            f"bench_throughput --compare: {matched}/{len(records)} records "
            f"matched against {args.compare}; {len(regressions)} "
            f"regression(s) beyond -{args.tolerance:.0%}"
        )
        if regressions:
            raise SystemExit(2)


if __name__ == "__main__":
    main()
