"""Paper SIV-C: real-valued DGEMM emulation supplement.

  * measured: Ozaki-II real f64 emulation fast/accu, with and without
    n-blocking, on this host at small sizes (correctness-bearing timing);
  * model: blocked-vs-unblocked and Ozaki-I slice comparison at 16384^3
    on GH200 constants (paper: blocked fast-N 72-93 TFLOPS vs Ozaki-I
    20-39 TFLOPS vs native DGEMM 61 TFLOPS).
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

from repro.core import ozaki2_gemm
from repro.core.perfmodel import GH200, real_tflops

from .common import emit, phi_matrix, time_fn


def run(s: int = 384):
    rng = np.random.default_rng(2)
    a = jnp.asarray(phi_matrix(rng, (s, s), 1.0, np.float64))
    b = jnp.asarray(phi_matrix(rng, (s, s), 1.0, np.float64))
    ref = np.asarray(a, np.float64).astype(np.longdouble) @ np.asarray(
        b, np.float64
    ).astype(np.longdouble)
    for mode in ("fast", "accu"):
        for nb in (None, 128):
            nm = 16 if mode == "fast" else 15
            fn = functools.partial(ozaki2_gemm, n_moduli=nm, mode=mode, n_block=nb)
            us = time_fn(fn, a, b)
            c = np.asarray(fn(a, b))
            err = float(np.max(np.abs(c - ref) / np.maximum(np.abs(ref), 1e-300)))
            emit(
                f"sIVC/measured/dgemm/{mode}-{nm}/block{nb or 0}",
                us,
                f"maxrel={err:.2e};tflops={2 * s**3 / (us * 1e-6) * 1e-12:.4f}",
            )
    for nm in (14, 16, 18):
        tf = real_tflops(16384, 16384, 16384, nm, GH200, "fast")
        emit(f"sIVC/model/gh200/fast-{nm}", 0.0,
             f"tflops={tf:.0f};paper_range=63-93;native_dgemm=61")


if __name__ == "__main__":
    run()
