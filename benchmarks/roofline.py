"""Roofline analysis (deliverable g): derive the three roofline terms for
every (arch x shape) cell from the dry-run artifacts in experiments/dryrun.

    compute    = HLO_flops_per_device                  / peak_flops
    memory     = HLO_bytes_per_device                  / hbm_bw
    collective = collective_bytes_per_device           / ici_bw

TPU v5e constants: 197 TFLOP/s bf16 per chip (394 TOPS int8), 819 GB/s HBM,
~50 GB/s/link ICI.  flops/bytes use the loop-corrected values (the dry-run
lowers a scan-unrolled twin of each cell because XLA cost analysis counts
while-loop bodies once — EXPERIMENTS.md SDry-run).

Also reports MODEL_FLOPS (6*N_active*D for training, 2*N_active*D for
prefill/decode) and the MODEL/HLO ratio (recompute/overhead waste), the
dominant term, and a what-would-move-it suggestion per cell.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.models import Model
from repro.models.params import _iter_leaves

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9


def active_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts from the abstract param tree."""
    model = Model(cfg)
    total = 0
    active = 0
    for path, meta in _iter_leaves(model.abstract_params()):
        import numpy as np

        n = int(np.prod(meta.shape))
        total += n
        if cfg.mlp == "moe" and len(path) >= 2 and path[-2] == "mlp" and path[-1] in (
            "gate",
            "up",
            "down",
        ):
            e = cfg.moe_experts
            n = n * cfg.moe_topk // e
        active += n
    return total, active


def model_flops(cfg, shape_name: str, n_chips: int) -> float:
    spec = SHAPES[shape_name]
    _, act = active_params(cfg)
    tokens = spec.global_batch * (spec.seq_len if spec.kind == "train" else 1)
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
    factor = 6.0 if spec.kind == "train" else 2.0
    return factor * act * tokens / n_chips


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    arch, shape = rec["arch"], rec["shape"]
    n_chips = 512 if len(rec["mesh"]) == 3 else 256
    peak = PEAK_INT8 if rec.get("backend", "native") != "native" else PEAK_BF16
    flops = rec.get("flops_per_device_corrected") or rec["flops_per_device"]
    bytes_ = rec.get("bytes_per_device_corrected") or rec["bytes_per_device"]
    coll = rec.get("collective_bytes_corrected") or rec["collectives"]["total"]
    t_c = flops / peak
    t_m = bytes_ / HBM_BW
    t_x = coll / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda x: x[1])
    mf = model_flops(get_config(arch), shape, n_chips)
    bound = t_c + t_m + t_x  # pessimistic no-overlap bound
    frac = (mf / peak) / max(bound, 1e-30)  # roofline fraction on useful flops
    hints = {
        "compute": "reduce recompute (remat policy) / fuse elementwise into the "
        "matmuls / int8 path doubles peak",
        "memory": "fuse or shrink intermediates (chunked-vocab CE, fused kernels), "
        "larger per-op tiles, bf16 intermediates",
        "collective": "reshard to cut all-gathers (SP/EP layout), overlap "
        "collectives with compute, gradient compression on DP axis",
    }
    return {
        "cell": rec["cell"],
        "arch": arch,
        "shape": shape,
        "mesh": "x".join(map(str, rec["mesh"])),
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_x,
        "dominant": dom[0],
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": mf / max(flops, 1e-30),
        "roofline_fraction": frac,
        "hint": hints[dom[0]],
        "mem_gib": rec["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30,
        "backend": rec.get("backend", "native"),
        "tags": "+sp" * int(bool(rec.get("seq_shard"))) +
                (f"+ga{rec['grad_accum']}" if rec.get("grad_accum", 1) > 1 else ""),
    }


def load_all(dirname: str = "experiments/dryrun") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            rec = json.load(fh)
        row = analyze(rec)
        if row:
            rows.append(row)
    return rows


def markdown_table(rows, single_pod_only=True) -> str:
    out = [
        "| cell | compute s | memory s | collective s | dominant | "
        "MODEL/HLO | roofline frac | temp GiB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if single_pod_only and r["mesh"] != "16x16":
            continue
        out.append(
            f"| {r['cell']} | {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{r['mem_gib']:.1f} |"
        )
    return "\n".join(out)


def run():
    rows = load_all()
    for r in rows:
        if r["mesh"] == "16x16":
            print(
                f"roofline/{r['cell']},0.0,"
                f"tc={r['t_compute_s']:.3e};tm={r['t_memory_s']:.3e};"
                f"tx={r['t_collective_s']:.3e};dom={r['dominant']};"
                f"frac={r['roofline_fraction']:.3f}"
            )
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.md", "w") as f:
        f.write(markdown_table(rows) + "\n")
    return rows


if __name__ == "__main__":
    run()
