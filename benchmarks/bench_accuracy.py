"""Paper Figs. 4-5: max relative error of CGEMM/ZGEMM emulation vs N and phi.

Reference products use extended precision (longdouble on x86 = 80-bit, below
double-double but far beyond the f64/f32 targets).  Native (jnp matmul)
errors are reported on the same scale so the 'comparable accuracy' bands of
the paper can be read off directly (red/italic entries in Figs. 4-5).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import ozaki2_cgemm

from .common import emit, phi_matrix


def _maxrel(c, ref):
    rr = np.maximum(np.abs(np.real(ref)), 1e-300)
    ri = np.maximum(np.abs(np.imag(ref)), 1e-300)
    return float(
        max(
            np.max(np.abs(np.real(c) - np.real(ref)) / rr),
            np.max(np.abs(np.imag(c) - np.imag(ref)) / ri),
        )
    )


def run(m: int = 128, n: int = 128, k: int = 2048):
    rng = np.random.default_rng(7)
    rows = []
    for prec, phis, n_range in [
        (np.complex64, (0.0, 0.5, 1.0, 1.5), range(3, 10)),
        (np.complex128, (0.5, 1.0, 2.0, 4.0), range(9, 18)),
    ]:
        pname = "c64" if prec == np.complex64 else "c128"
        for phi in phis:
            a = phi_matrix(rng, (m, k), phi, prec)
            b = phi_matrix(rng, (k, n), phi, prec)
            ref = a.astype(np.clongdouble) @ b.astype(np.clongdouble)
            nat = _maxrel(np.asarray(jnp.asarray(a) @ jnp.asarray(b)), ref)
            emit(f"fig45/{pname}/native/phi{phi}", 0.0, f"maxrel={nat:.3e}")
            for mode in ("fast", "accu"):
                for nm in n_range:
                    c = np.asarray(
                        ozaki2_cgemm(jnp.asarray(a), jnp.asarray(b), nm, mode)
                    )
                    err = _maxrel(c, ref)
                    rows.append((pname, phi, mode, nm, err, nat))
                    emit(
                        f"fig45/{pname}/{mode}-{nm}/phi{phi}",
                        0.0,
                        f"maxrel={err:.3e};native={nat:.3e};"
                        f"at_native_level={int(err <= nat * 4)}",
                    )
    return rows


if __name__ == "__main__":
    run()
