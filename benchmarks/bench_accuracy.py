"""Paper Figs. 4-5: max componentwise error of the emulation vs N and phi —
promoted to a tracked accuracy harness.

Reference products use extended precision (longdouble on x86 = 80-bit,
below double-double but far beyond the f64/f32 targets).  Native (jnp
matmul) errors are reported on the same scale so the 'comparable accuracy'
bands of the paper can be read off directly (red/italic entries in
Figs. 4-5).

Every row is measured through the policy-routed deployment path
(`repro.linalg.matmul` under a `GemmPolicy`) on the certified error metric
`core.accuracy.rel_error` — max_ij |C - C_emul|_ij / (k * amax_i * bmax_j),
the metric the static `core.accuracy.rel_bound` provably bounds — and each
record carries that bound next to the measurement.  Adaptive rows
(`GemmPolicy(mode="auto", rtol=...)`) additionally record the tolerance the
policy resolved for, which the measurement must meet.

CLI (mirrors bench_throughput's tracked-JSON contract):

    PYTHONPATH=src python -m benchmarks.bench_accuracy \
        [--smoke] [--execution reference|kernel|...] \
        [--json BENCH_accuracy.json] [--force]

Records are keyed by (execution, mesh, devices, name) plus the calibration
stamp — `merge_records` / `record_key` are shared with bench_throughput —
so re-running replaces exactly the re-measured keys and BENCH_accuracy.json
accumulates the per-execution accuracy trajectory alongside the perf one.

`check_records` asserts the three invariants CI pins (tests/test_accuracy.py
runs the smoke sweep through it):

  * every measured error <= its static `rel_bound` (the paper-bound
    certificate, end to end);
  * every adaptive row's error <= its requested rtol;
  * every (dtype, mode, n_moduli) cell stays inside its pinned golden
    band (`BANDS`) — a regression alarm ~8x above the currently measured
    error, far below the static bound.
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax.numpy as jnp

from repro import linalg
from repro.core import GemmPolicy, rel_bound, rel_error
from repro.core.policy import BACKEND_FOR_DTYPE

from .common import emit, phi_matrix

#: (dtype, phis, moduli counts) — the full Figs. 4-5 sweep plus the real
#: dtype classes the policy stack also serves
FULL_SWEEP = (
    ("float32", (0.5, 1.5), (4, 6, 8)),
    ("float64", (0.5, 2.0), (8, 12, 16)),
    ("complex64", (0.0, 0.5, 1.0, 1.5), tuple(range(3, 10))),
    ("complex128", (0.5, 1.0, 2.0, 4.0), tuple(range(9, 18))),
)
FULL_SHAPE = (128, 2048, 128)  # (m, k, n)

#: the tier-1 profile: small shapes, the band-pinned moduli counts
SMOKE_SWEEP = (
    ("float32", (0.5, 1.5), (4, 6)),
    ("float64", (0.5, 2.0), (8, 12)),
    ("complex64", (0.5, 1.5), (4, 6, 8)),
    ("complex128", (0.5, 2.0), (10, 14)),
)
SMOKE_SHAPE = (32, 96, 24)

#: adaptive rows: requested componentwise tolerance per dtype (mode="auto")
ADAPTIVE_RTOL = {
    "float32": 1e-4,
    "float64": 1e-9,
    "complex64": 1e-4,
    "complex128": 1e-9,
}

#: pinned golden error bands for the smoke sweep, per (dtype, mode,
#: n_moduli): the worst `rel_error` measured across the smoke phis with
#: ~8x headroom.  A measurement above its band is a regression finding even
#: when it still sits below the (much looser) static bound.
BANDS = {
    ("float32", "fast", 4): 2.0e-04,
    ("float32", "fast", 6): 1.0e-06,
    ("float32", "accu", 4): 1.2e-04,
    ("float32", "accu", 6): 5.0e-07,
    ("float64", "fast", 8): 3.0e-09,
    ("float64", "fast", 12): 6.5e-14,
    ("float64", "accu", 8): 1.5e-09,
    ("float64", "accu", 12): 4.5e-14,
    ("complex64", "fast", 4): 3.0e-04,
    ("complex64", "fast", 6): 1.2e-06,
    ("complex64", "fast", 8): 1.2e-08,
    ("complex64", "accu", 4): 1.8e-04,
    ("complex64", "accu", 6): 6.0e-07,
    ("complex64", "accu", 8): 1.2e-08,
    ("complex128", "fast", 10): 2.5e-11,
    ("complex128", "fast", 14): 5.5e-16,
    ("complex128", "accu", 10): 1.5e-11,
    ("complex128", "accu", 14): 2.6e-16,
}


def _longdouble_ref(a, b):
    ld = (
        np.clongdouble
        if np.issubdtype(a.dtype, np.complexfloating)
        else np.longdouble
    )
    return a.astype(ld) @ b.astype(ld)


def sweep(
    shape=SMOKE_SHAPE,
    profile=SMOKE_SWEEP,
    execution: str = "reference",
    seed: int = 7,
) -> list:
    """Measure the profile through the policy-routed path; returns records.

    One record per (dtype, mode, n_moduli, phi) cell plus one adaptive
    (mode="auto", rtol) row per dtype, each carrying the measured
    `rel_error`, the static `rel_bound` and (adaptive rows) the rtol.
    """
    from repro.tune.cache import calibration_hash, current_calibration

    cal = current_calibration()
    cal_stamp = calibration_hash(cal) if cal is not None else None
    m, k, n = shape
    rng = np.random.default_rng(seed)
    records: list = []

    def record(name, dtype_name, mode, nm, phi, err, bound, **extra):
        rec = {
            "name": name,
            "execution": execution,
            "mesh": "1",
            "devices": 1,
            "dtype": dtype_name,
            "mode": mode,
            "n_moduli": nm,
            "phi": phi,
            "k": k,
            "err": err,
            "bound": bound,
            "calibration": cal_stamp,
        }
        rec.update(extra)
        records.append(rec)
        return rec

    for dtype_name, phis, n_range in profile:
        dt = np.dtype(dtype_name)
        backend = BACKEND_FOR_DTYPE[dtype_name]
        for phi in phis:
            a = phi_matrix(rng, (m, k), phi, dt)
            b = phi_matrix(rng, (k, n), phi, dt)
            ref = _longdouble_ref(a, b)
            nat = rel_error(np.asarray(jnp.asarray(a) @ jnp.asarray(b)), ref, a, b)
            emit(
                f"fig45/{dtype_name}/native/phi{phi:g}", 0.0, f"err={nat:.3e}"
            )
            for mode in ("fast", "accu"):
                for nm in n_range:
                    pol = GemmPolicy(
                        backend=backend, n_moduli=nm, mode=mode,
                        execution=execution,
                    )
                    c = np.asarray(
                        linalg.matmul(jnp.asarray(a), jnp.asarray(b), policy=pol)
                    )
                    err = rel_error(c, ref, a, b)
                    bound = rel_bound(
                        dtype_name, mode, nm, k, formulation=pol.formulation
                    )
                    record(
                        f"fig45/{dtype_name}/{mode}-N{nm}/phi{phi:g}",
                        dtype_name, mode, nm, phi, err, bound,
                        native_err=nat,
                    )
                    emit(
                        f"fig45/{dtype_name}/{mode}-N{nm}/phi{phi:g}",
                        0.0,
                        f"err={err:.3e};bound={bound:.3e};native={nat:.3e};"
                        f"at_native_level={int(err <= nat * 4)}",
                    )

    # adaptive rows: mode="auto" + rtol; the resolved plan must measure
    # within the requested tolerance
    for dtype_name, _, _ in profile:
        rtol = ADAPTIVE_RTOL[dtype_name]
        dt = np.dtype(dtype_name)
        a = phi_matrix(rng, (m, k), 0.5, dt)
        b = phi_matrix(rng, (k, n), 0.5, dt)
        ref = _longdouble_ref(a, b)
        pol = GemmPolicy(
            backend=BACKEND_FOR_DTYPE[dtype_name], mode="auto", rtol=rtol,
            execution=execution,
        )
        resolved = pol.resolve_adaptive(m, k, n)
        c = np.asarray(linalg.matmul(jnp.asarray(a), jnp.asarray(b), policy=pol))
        err = rel_error(c, ref, a, b)
        bound = rel_bound(
            dtype_name, resolved.mode, resolved.n_moduli, k,
            formulation=resolved.formulation,
        )
        record(
            f"fig45/{dtype_name}/auto-rtol{rtol:g}/phi0.5",
            dtype_name, resolved.mode, resolved.n_moduli, 0.5, err, bound,
            rtol=rtol,
        )
        emit(
            f"fig45/{dtype_name}/auto-rtol{rtol:g}/phi0.5",
            0.0,
            f"err={err:.3e};bound={bound:.3e};rtol={rtol:g};"
            f"resolved={resolved.mode}/N{resolved.n_moduli}",
        )
    return records


def check_records(records, bands=None) -> list:
    """The CI invariants over measured records; returns violation strings.

    Empty list = certified: every error below its static bound, every
    adaptive row within its rtol, every pinned (dtype, mode, n_moduli)
    cell inside its golden band.
    """
    bands = BANDS if bands is None else bands
    violations = []
    for r in records:
        name = r.get("name", "?")
        err = r.get("err")
        if err is None:
            continue
        bound = r.get("bound")
        if bound is not None and err > bound:
            violations.append(
                f"{name}: err={err:.3e} EXCEEDS static bound {bound:.3e}"
            )
        rtol = r.get("rtol")
        if rtol is not None and err > rtol:
            violations.append(
                f"{name}: err={err:.3e} exceeds requested rtol={rtol:g}"
            )
        band = bands.get((r.get("dtype"), r.get("mode"), r.get("n_moduli")))
        if band is not None and rtol is None and err > band:
            violations.append(
                f"{name}: err={err:.3e} outside pinned band {band:.3e}"
            )
    return violations


def run(m: int = 128, n: int = 128, k: int = 2048):
    """Legacy harness entry (benchmarks.run): the full Figs. 4-5 sweep."""
    return sweep(shape=(m, k, n), profile=FULL_SWEEP)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tier-1 profile: small shapes, band-pinned cells")
    ap.add_argument("--execution", default="reference",
                    choices=["reference", "kernel", "per_modulus_kernel",
                             "sharded", "fp8", "fused"],
                    help="residue backend the sweep measures through")
    ap.add_argument("--json", default="BENCH_accuracy.json",
                    help="write measured records here (tracked accuracy)")
    ap.add_argument("--force", action="store_true",
                    help="allow --json to drop existing records it cannot "
                         "key-match (foreign/older record schema)")
    args = ap.parse_args()

    if args.smoke:
        records = sweep(SMOKE_SHAPE, SMOKE_SWEEP, execution=args.execution)
    else:
        records = sweep(FULL_SHAPE, FULL_SWEEP, execution=args.execution)
    if args.json:
        from .bench_throughput import merge_records

        try:
            with open(args.json) as f:
                old = json.load(f).get("records", [])
        except FileNotFoundError:
            old = []
        except (OSError, ValueError) as e:
            raise SystemExit(
                f"--json target {args.json!r} exists but is unreadable "
                f"({e}); refusing to overwrite — fix or remove it, or "
                f"point --json elsewhere"
            )
        with open(args.json, "w") as f:
            json.dump(
                {"records": merge_records(old, records, force=args.force)},
                f, indent=1,
            )
    violations = check_records(records, BANDS if args.smoke else {})
    for v in violations:
        print(f"VIOLATION {v}")
    print(
        f"bench_accuracy: {len(records)} records, "
        f"{len(violations)} violation(s)"
    )
    if violations:
        raise SystemExit(2)


if __name__ == "__main__":
    main()
