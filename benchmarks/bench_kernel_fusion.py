"""SPerf hillclimb 3 (kernel level): fused-Karatsuba vs separate-GEMM
modular complex multiply — HLO bytes-accessed comparison.

The paper launches D/E/F as separate int8 GEMM kernels with int32
intermediates in HBM; our Pallas kernel (kernels/karatsuba_fused.py) forms
(AR+AI) mod p in VMEM and writes the CR/CI residues directly.  On CPU we
can't time the TPU kernel, but the *bytes* story is structural: we count
HLO bytes of both pipelines at the same shape and derive the memory-term
reduction, plus the exact per-modulus HBM traffic model.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.moduli import make_crt_context
from repro.kernels import karatsuba_mod_gemm
from repro.kernels import ref as kref

from .common import emit


def analytic(m, n, k):
    """Bytes/modulus moved to/from HBM by each schedule (DESIGN/SPerf)."""
    base = (
        2 * (m * k + k * n)        # AR,AI + BR,BI int8 reads
        + (m * k + k * n)          # (AR+AI), (BR+BI) int8 write+read
        + 3 * 4 * m * n * 2        # D,E,F int32 write + read back
        + 2 * m * n                # CR, CI int8 writes
    )
    fused = 2 * (m * k + k * n) + 2 * m * n
    return base, fused


def run(m: int = 256, n: int = 256, k: int = 512, p: int = 251):
    rng = np.random.default_rng(0)
    h = (p - 1) // 2
    mats = [
        jnp.asarray(rng.integers(-h, h + 1, size=s).astype(np.int8))
        for s in [(m, k), (m, k), (k, n), (k, n)]
    ]

    def unfused(ar, ai, br, bi):
        return kref.karatsuba_mod_gemm_ref(ar, ai, br, bi, p=p)

    def fused(ar, ai, br, bi):
        return karatsuba_mod_gemm(ar, ai, br, bi, p=p, interpret=True)

    cost_u = jax.jit(unfused).lower(*mats).compile().cost_analysis()
    bytes_u = float(cost_u.get("bytes accessed", 0))
    flops_u = float(cost_u.get("flops", 0))
    base, fmodel = analytic(m, n, k)
    emit(
        f"kernel_fusion/unfused/{m}x{n}x{k}",
        0.0,
        f"hlo_bytes={bytes_u:.3e};hlo_flops={flops_u:.3e};"
        f"model_hbm_bytes={base:.3e}",
    )
    emit(
        f"kernel_fusion/fused/{m}x{n}x{k}",
        0.0,
        f"model_hbm_bytes={fmodel:.3e};reduction={base / fmodel:.2f}x"
        f";note=pallas kernel shares A/B tiles in VMEM, no int32 HBM roundtrip",
    )
    # correctness of the fused kernel at this shape (bit-exact)
    cu = unfused(*mats)
    cf = fused(*mats)
    ok = bool(jnp.all(cu[0] == cf[0]) and jnp.all(cu[1] == cf[1]))
    emit(f"kernel_fusion/exactness/{m}x{n}x{k}", 0.0, f"bit_exact={int(ok)}")


if __name__ == "__main__":
    run()
