"""SPerf hillclimb 3 (kernel level): fused-Karatsuba vs separate-GEMM
modular complex multiply — HLO bytes-accessed comparison — plus the
modulus-batched launch-count check.

The paper launches D/E/F as separate int8 GEMM kernels with int32
intermediates in HBM; our Pallas kernel (kernels/karatsuba_fused.py) forms
(AR+AI) mod p in VMEM and writes the CR/CI residues directly, and the
batched grid runs all N moduli in ONE `pallas_call`.  On CPU we can't time
the TPU kernel, but two structural properties are checkable anywhere:

  * the *bytes* story — HLO bytes of both pipelines at the same shape and
    the exact per-modulus HBM traffic model;
  * the *launch* story — `pallas_call` counts of the full batched pipeline
    traced to jaxpr must match `perfmodel.kernel_launch_count` (2 casts +
    1 product + 1 reconstruction at any N).  A mismatch exits non-zero, so
    the CI smoke run (`--smoke`, tiny shapes, interpret mode) fails on
    launch-count regressions instead of waiting for hardware.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import GemmPolicy, perfmodel
from repro.core.moduli import make_crt_context
from repro.kernels import count_pallas_launches, karatsuba_mod_gemm
from repro.kernels import ref as kref
from repro import linalg

from .common import emit


def analytic(m, n, k):
    """Bytes/modulus moved to/from HBM by each schedule (DESIGN/SPerf)."""
    base = (
        2 * (m * k + k * n)        # AR,AI + BR,BI int8 reads
        + (m * k + k * n)          # (AR+AI), (BR+BI) int8 write+read
        + 3 * 4 * m * n * 2        # D,E,F int32 write + read back
        + 2 * m * n                # CR, CI int8 writes
    )
    fused = 2 * (m * k + k * n) + 2 * m * n
    return base, fused


def check_launch_counts(m: int, n: int, k: int, n_moduli: int) -> int:
    """Count `pallas_call`s of the full batched pipelines and compare with
    the perfmodel; returns the number of mismatches (0 = pass)."""
    rng = np.random.default_rng(0)
    a = jnp.asarray((rng.random((m, k)) - 0.5).astype(np.float32))
    b = jnp.asarray((rng.random((k, n)) - 0.5).astype(np.float32))
    ca = jnp.asarray(
        ((rng.random((m, k)) - 0.5) + 1j * (rng.random((m, k)) - 0.5)).astype(
            np.complex64
        )
    )
    cb = jnp.asarray(
        ((rng.random((k, n)) - 0.5) + 1j * (rng.random((k, n)) - 0.5)).astype(
            np.complex64
        )
    )
    def kpol(backend, **kw):
        return GemmPolicy(
            backend=backend, n_moduli=n_moduli, execution="kernel",
            interpret=True, **kw,
        )

    def fpol(backend, **kw):
        return GemmPolicy(
            backend=backend, n_moduli=n_moduli, execution="fused",
            interpret=True, **kw,
        )

    cases = [
        (
            "real",
            lambda x, y: linalg.matmul(x, y, policy=kpol("ozaki2_f32")),
            (a, b),
            perfmodel.kernel_launch_count(n_moduli, "real"),
        ),
        (
            "karatsuba",
            lambda x, y: linalg.matmul(x, y, policy=kpol("ozaki2_c64")),
            (ca, cb),
            perfmodel.kernel_launch_count(n_moduli, "karatsuba"),
        ),
        (
            "block_a",
            lambda x, y: linalg.matmul(
                x, y, policy=kpol("ozaki2_c64", formulation="block_a")
            ),
            (ca, cb),
            perfmodel.kernel_launch_count(n_moduli, "block_a"),
        ),
        # the megakernel: cast + products + Garner share ONE pallas_call —
        # the whole point of execution='fused' (4 -> 1 vs the kernel path)
        (
            "fused_real",
            lambda x, y: linalg.matmul(x, y, policy=fpol("ozaki2_f32")),
            (a, b),
            perfmodel.kernel_launch_count(n_moduli, "real", fused=True),
        ),
        (
            "fused_karatsuba",
            lambda x, y: linalg.matmul(x, y, policy=fpol("ozaki2_c64")),
            (ca, cb),
            perfmodel.kernel_launch_count(n_moduli, "karatsuba", fused=True),
        ),
    ]
    bad = 0
    for name, fn, operands, expect in cases:
        got = count_pallas_launches(fn, *operands)
        ok = got == expect
        if name.startswith("fused"):
            # the fused path must actually *reduce* launches, not merely
            # match its own model row
            ok = ok and got == 1 and got < perfmodel.kernel_launch_count(
                n_moduli, name.removeprefix("fused_")
            )
        bad += not ok
        emit(
            f"kernel_fusion/launches/{name}/{m}x{n}x{k}/N={n_moduli}",
            0.0,
            f"pallas_calls={got};model={expect};ok={int(ok)}",
        )
    return bad


def run(m: int = 256, n: int = 256, k: int = 512, p: int = 251,
        n_moduli: int = 5):
    rng = np.random.default_rng(0)
    h = (p - 1) // 2
    mats = [
        jnp.asarray(rng.integers(-h, h + 1, size=s).astype(np.int8))
        for s in [(m, k), (m, k), (k, n), (k, n)]
    ]

    def unfused(ar, ai, br, bi):
        return kref.karatsuba_mod_gemm_ref(ar, ai, br, bi, p=p)

    def fused(ar, ai, br, bi):
        return karatsuba_mod_gemm(ar, ai, br, bi, p=p, interpret=True)

    cost_u = jax.jit(unfused).lower(*mats).compile().cost_analysis()
    if isinstance(cost_u, (list, tuple)):  # jax < 0.4.34 returns one per device
        cost_u = cost_u[0] if cost_u else {}
    bytes_u = float(cost_u.get("bytes accessed", 0))
    flops_u = float(cost_u.get("flops", 0))
    base, fmodel = analytic(m, n, k)
    emit(
        f"kernel_fusion/unfused/{m}x{n}x{k}",
        0.0,
        f"hlo_bytes={bytes_u:.3e};hlo_flops={flops_u:.3e};"
        f"model_hbm_bytes={base:.3e}",
    )
    emit(
        f"kernel_fusion/fused/{m}x{n}x{k}",
        0.0,
        f"model_hbm_bytes={fmodel:.3e};reduction={base / fmodel:.2f}x"
        f";note=pallas kernel shares A/B tiles in VMEM, no int32 HBM roundtrip",
    )
    # correctness of the fused kernel at this shape (bit-exact)
    cu = unfused(*mats)
    cf = fused(*mats)
    ok = bool(jnp.all(cu[0] == cf[0]) and jnp.all(cu[1] == cf[1]))
    emit(f"kernel_fusion/exactness/{m}x{n}x{k}", 0.0, f"bit_exact={int(ok)}")
    bad = check_launch_counts(m, n, k, n_moduli)
    if not ok or bad:
        raise SystemExit(
            f"kernel_fusion regression: bit_exact={ok}, launch mismatches={bad}"
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny shapes for the CI interpret-mode launch-count check",
    )
    args = ap.parse_args()
    if args.smoke:
        run(m=32, n=24, k=64, p=251, n_moduli=4)
    else:
        run()
