"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call == 0.0 for model-based
rows).  Usage:  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller sweeps")
    args = ap.parse_args()

    import repro  # noqa: F401 (x64 for the numeric core)

    from . import (
        bench_accuracy,
        bench_fig1_strategies,
        bench_kernel_fusion,
        bench_perf_model,
        bench_real_supplement,
        bench_throughput,
        roofline,
    )

    print("name,us_per_call,derived")
    t0 = time.time()
    sections = [
        ("fig1", lambda: bench_fig1_strategies.run(h=256 if args.quick else 512)),
        ("fig2-3", bench_perf_model.run),
        (
            "fig4-5",
            lambda: bench_accuracy.run(k=512 if args.quick else 2048),
        ),
        ("fig6-13", bench_throughput.run),
        ("sIV-C", bench_real_supplement.run),
        ("kernel-fusion", bench_kernel_fusion.run),
        ("roofline", roofline.run),
    ]
    for name, fn in sections:
        print(f"# --- {name} ---", file=sys.stderr)
        try:
            fn()
        except Exception as e:  # pragma: no cover
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
